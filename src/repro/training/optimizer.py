"""AdamW + schedules, from scratch (no optax in this container).

Optimizer state is a pytree mirroring params (m, v in f32 regardless of
param dtype — the sharding policy shards it like the params), so FSDP'd
params get FSDP'd optimizer state for free.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to 10%."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat, vhat = m / b1c, v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), \
        {"grad_norm": gnorm, "lr": lr}
