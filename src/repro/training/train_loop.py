"""Training step factory: loss -> grads -> AdamW, one jittable function.

``make_train_step(model, opt_cfg)`` returns
``step(params, opt_state, batch) -> (params, opt_state, metrics)`` — the
function launch/dryrun.py lowers for train_4k and launch/train.py runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.training import optimizer


def make_train_step(model, opt_cfg: optimizer.AdamWConfig, jit=True,
                    microbatches: int = 1):
    """microbatches > 1 enables gradient accumulation: the global batch is
    split on its leading dim and scanned, dividing the activation
    high-water by the microbatch count (grads accumulate in f32 with the
    params' sharding)."""

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss)(params, batch)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape(microbatches, a.shape[0] // microbatches,
                                    *a.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)

            def body(carry, b):
                acc, lsum = carry
                loss, g = grads_of(params, b)
                acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                   acc, g)
                return (acc, lsum + loss), None

            (grads, lsum), _ = jax.lax.scan(body, (zero, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = lsum / microbatches
        params, opt_state, stats = optimizer.update(opt_cfg, grads,
                                                    opt_state, params)
        metrics = {"loss": loss, **stats}
        return params, opt_state, metrics

    return jax.jit(step, donate_argnums=(0, 1)) if jit else step


def make_eval_step(model, jit=True):
    def step(params, batch):
        return model.loss(params, batch)
    return jax.jit(step) if jit else step


def train(model, params, batches, *, steps: int,
          opt_cfg: optimizer.AdamWConfig | None = None, log_every: int = 10,
          log_fn=print):
    """Simple host-loop trainer used by examples and smoke tests."""
    opt_cfg = opt_cfg or optimizer.AdamWConfig(total_steps=steps)
    opt_state = optimizer.init(params)
    step_fn = make_train_step(model, opt_cfg)
    history = []
    for i, batch in zip(range(steps), batches):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            loss = float(metrics["loss"])
            history.append((i, loss))
            log_fn(f"step {i:5d} loss {loss:.4f} "
                   f"gnorm {float(metrics['grad_norm']):.3f}")
    return params, opt_state, history
