"""Deterministic synthetic data pipelines.

No external datasets ship with this container, so the pipelines generate
reproducible synthetic streams with learnable structure:

  * ``MarkovTokenDataset`` — tokens follow a fixed random bigram table, so a
    language model's loss drops measurably below the uniform entropy within
    a few hundred steps (used by examples/quickstart.py as the end-to-end
    learning signal).
  * ``VisionStub`` / ``AudioStub`` — the assignment's modality-frontend
    carve-out: precomputed patch/frame embeddings of the right shape.

Batches are plain dicts matching the models' batch contract, optionally
device_put with a NamedSharding for multi-chip runs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class MarkovTokenDataset:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 4          # out-degree of the bigram graph

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.table = rng.integers(0, self.vocab_size,
                                  size=(self.vocab_size, self.branching))

    def batches(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed + 1)
        while True:
            tok = np.empty((self.batch_size, self.seq_len), np.int32)
            tok[:, 0] = rng.integers(0, self.vocab_size, self.batch_size)
            choices = rng.integers(0, self.branching,
                                   (self.batch_size, self.seq_len))
            for t in range(1, self.seq_len):
                tok[:, t] = self.table[tok[:, t - 1], choices[:, t]]
            yield {"tokens": jnp.asarray(tok)}

    @property
    def entropy_floor(self) -> float:
        """Cross-entropy of the true bigram process (uniform over branches)."""
        return float(np.log(self.branching))


def vision_stub(batch: int, cfg: ModelConfig, seed: int = 0) -> jax.Array:
    """Precomputed ViT patch embeddings (the assignment carve-out)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((batch, cfg.cross_attn_states, cfg.vision_dim),
                            dtype=np.float32)
    return jnp.asarray(x, jnp.dtype(cfg.dtype))


def audio_stub(batch: int, cfg: ModelConfig, seed: int = 0) -> jax.Array:
    """Precomputed conv-frontend frame embeddings."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((batch, cfg.encoder_frames, cfg.d_model),
                            dtype=np.float32)
    return jnp.asarray(x, jnp.dtype(cfg.dtype))


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> dict:
    """A full model batch (tokens + modality stubs) for any arch."""
    rng = np.random.default_rng(seed)
    out = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)}
    if cfg.family == "vlm":
        out["vision_embeds"] = vision_stub(batch, cfg, seed)
    if cfg.is_encdec:
        out["frames"] = audio_stub(batch, cfg, seed)
    return out


def shard_batch(batch: dict, sharding) -> dict:
    return jax.tree.map(lambda a: jax.device_put(a, sharding), batch)
