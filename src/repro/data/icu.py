"""Synthetic MIMIC-III-like ICU time series (the paper's data substrate).

MIMIC-III requires credentialed access, so we generate a statistically
similar stand-in following the Harutyunyan et al. clinical benchmark format
the paper uses: 48 hourly timesteps x 76 features (17 vitals + one-hot
masks), with label-dependent drift so the paper's three LSTM tasks are
learnable:

  * short-of-breath alerts     — binary, respiratory features drift up
  * life-death prediction      — binary (in-hospital mortality)
  * phenotype classification   — 25 independent binary labels

Byte sizes per record are matched to the paper's Table IV real sizes so the
transmission-time model sees realistic payloads.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.configs.icu_lstm import ICULSTMConfig

# paper Table IV: real dataset bytes per (workload, size-unit) — KB / units
PAPER_BYTES_PER_UNIT = {
    "short-of-breath-alerts": 700 * 1024 / 64,          # ~10.9 KiB/unit
    "life-death-prediction": 479 * 1024 / 64,           # ~7.5 KiB/unit
    "patient-phenotype-classification": 836 * 1024 / 64,  # ~13.1 KiB/unit
}


def generate(cfg: ICULSTMConfig, n: int, seed: int = 0
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (features (n, T, input_dim) f32, labels).

    Binary tasks: labels (n,) int32. Phenotype: (n, 25) multi-hot."""
    rng = np.random.default_rng(seed)
    t, f = cfg.seq_len, cfg.input_dim
    x = rng.standard_normal((n, t, f)).astype(np.float32)
    drift = np.linspace(0.0, 1.0, t, dtype=np.float32)[None, :, None]

    if cfg.num_classes == 25:  # phenotype multi-label
        y = (rng.random((n, 25)) < 0.3).astype(np.int32)
        # each phenotype k adds signal on features 3k..3k+2
        for k in range(25):
            sel = y[:, k].astype(np.float32)[:, None, None]
            x[..., 3 * k % f:(3 * k % f) + 3] += 0.8 * sel * drift
        return x, y

    y = (rng.random(n) < 0.35).astype(np.int32)
    sel = y.astype(np.float32)[:, None, None]
    x[..., : max(4, f // 4)] += 1.0 * sel * drift      # vitals deteriorate
    return x, y


def record_bytes(cfg: ICULSTMConfig) -> float:
    """Bytes per data unit, matched to the paper's Table IV sizes."""
    return PAPER_BYTES_PER_UNIT[cfg.name]
