"""Sharded-friendly checkpointing to .npz (no orbax in this container).

Leaves are addressed by their pytree key-path string, so restore is
structure-checked. On a multi-host run each host would save its addressable
shards (path includes the process index); in this single-process container
that degenerates to one file, but the layout is the production one.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def save(ckpt_dir: str, step: int, tree: Any, *, process_index: int = 0):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_path_str(p): np.asarray(v) for p, v in flat}
    fn = os.path.join(ckpt_dir, f"step_{step:08d}.proc{process_index}.npz")
    np.savez(fn, **arrays)
    meta = {"step": step, "leaves": len(arrays)}
    with open(os.path.join(ckpt_dir, "latest.json"), "w") as f:
        json.dump(meta, f)
    return fn


def latest_step(ckpt_dir: str) -> int:
    with open(os.path.join(ckpt_dir, "latest.json")) as f:
        return json.load(f)["step"]


def restore(ckpt_dir: str, template: Any, step: int | None = None, *,
            process_index: int = 0) -> Any:
    """Restore into the structure of `template` (shapes/dtypes checked)."""
    step = latest_step(ckpt_dir) if step is None else step
    fn = os.path.join(ckpt_dir, f"step_{step:08d}.proc{process_index}.npz")
    data = np.load(fn)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, tmpl in paths:
        key = _path_str(p)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {tmpl.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
