"""Metro flight recorder (DESIGN.md §15): per-job span tracing,
deadline-miss attribution, and engine self-profiling.

The metrics layer (§10) reports *that* deadlines were missed; this module
records *why*. A `MetroTracer` is a read-only observer the engine consults
when run with ``MetroEngine.run(trace=True)`` (serve ``--trace PATH``):
every job gets one ROOT span covering release → terminal, with child
spans for each attempt phase —

  * ``decision``  — instant marker at the attempt's first policy verdict;
  * ``backoff``   — a crash-retry's exponential-backoff gap;
  * ``wait``      — time between entering the attempt (or re-shipping)
                    and the data being shipped, plus queue wait between
                    data arrival at the tier and service start;
  * ``transmit``  — the uplink window of the commit that actually shipped
                    the data (the in-flight contract: a replan that keeps
                    the tier keeps the original ship instant);
  * ``service``   — slot occupancy [start, end), split into ``service_seg``
                    children at every fail-slow rate-change boundary of
                    the serving slot's `_rate_profile`;
  * ``attempt``   — one per dispatch (crash kills start a NEW attempt,
                    matching the sanitizer's I3 attempt keys), including
                    hedge backups; losers get a ``hedge_loser`` span cut
                    at the winner's completion instant.

Everything is derived from the engine's existing event stream plus
read-only peeks at its commitment state: the tracer never mutates engine
state, never pushes events and never touches the event log, so traced
runs produce BIT-IDENTICAL event-log CRCs to untraced runs (hard-gated by
the ``metro_observability`` bench section). Span/trace identifiers are
deterministic seeded counters in event order — no wall clock, no uuid
(reprolint R002/R003 clean).

Deadline-miss attribution: for every finished job the tracer derives an
EXACT additive decomposition of its response time,

    response = retry_waste + wait + transmit + service + slowdown

where ``retry_waste`` is the time lost before the final attempt entered
the decision path (killed attempts + backoff gaps; for a winning hedge
backup, the straggler window before the backup dispatched),
``transmit`` is the final ship's uplink window, ``wait`` is requeue +
queue time, ``service`` the nominal proc on the serving tier, and
``slowdown`` the fail-slow inflation ``(end - start) - proc`` separated
via the slot's piecewise rate profile. The five terms telescope, so they
sum to the measured response to float rounding (tested at 1e-9).
`blame_table()` aggregates missed/shed jobs per (class, tier) and names
the dominant term — the postmortem report `serve --metro --postmortem`
prints and exports.

Exporters: `to_jsonl` (one span object per line) and `to_chrome`
(Chrome trace-event JSON): wards as process rows, machine slots as
thread rows carrying the service occupancy (non-overlapping by engine
invariant I2) and fleet outage/fail-slow windows, jobs as nestable async
tracks — a metro run opens directly in Perfetto.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.tiers import CC, ED, ES

_INF = float("inf")
# attribution decomposition, in reporting order (DESIGN.md §15)
TERMS = ("retry_waste", "wait", "transmit", "service", "slowdown")
# Chrome trace-event timestamps are microseconds; one trace time unit
# (a simulated minute) renders as one second of trace time
_CHROME_US = 1e6


@dataclass
class Span:
    """One flight-recorder span. `trace` keys the job (``w<ward>j<idx>``,
    or ``fleet`` for pool-level outage/slowdown windows); `span`/`parent`
    are deterministic per-run counters (event order, no wall clock)."""
    trace: str
    span: int
    parent: Optional[int]
    name: str                       # root/attempt/wait/transmit/service/...
    cat: str                        # job | attempt | phase | fleet
    t0: float
    t1: float
    ward: int = -1
    attrs: dict = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"trace": self.trace, "span": self.span,
                "parent": self.parent, "name": self.name, "cat": self.cat,
                "t0": self.t0, "t1": self.t1, "ward": self.ward,
                "attrs": self.attrs}


class _JobState:
    """Per-job tracer bookkeeping between hooks."""
    __slots__ = ("release", "root", "attempt", "attempt_t", "decided",
                 "ship_t", "tier", "arrival", "kill_t", "hedge_t",
                 "hedge_tier", "promoted")

    def __init__(self, release: float, root: int):
        self.release = release
        self.root = root
        self.attempt = 0              # crash-kill count so far
        self.attempt_t = release      # entry instant of the live attempt
        self.decided: Optional[float] = None
        self.ship_t: Optional[float] = None  # when the live data shipped
        self.tier: Optional[str] = None
        self.arrival: Optional[float] = None
        self.kill_t: Optional[float] = None  # open backoff gap start
        self.hedge_t: Optional[float] = None
        self.hedge_tier: Optional[str] = None
        self.promoted = False


class MetroTracer:
    """Read-only flight recorder attached by ``MetroEngine.run`` when
    tracing is armed. One instance observes one run; `finish()` freezes
    it into the `MetroTrace` carried on the `MetroResult`."""

    def __init__(self, engine):
        self.eng = engine
        self._seq = 0                              # deterministic span ids
        self.spans: List[Span] = []
        self.rows: List[dict] = []                 # attribution rows
        self._jobs: Dict[Tuple[int, int], _JobState] = {}
        self._open_roots: Dict[Tuple[int, int], Span] = {}

    # ----------------------------------------------------------- plumbing
    def _span(self, trace: str, parent: Optional[int], name: str,
              cat: str, t0: float, t1: float, ward: int = -1,
              **attrs) -> Span:
        self._seq += 1
        sp = Span(trace, self._seq, parent, name, cat, t0, t1, ward,
                  dict(attrs))
        self.spans.append(sp)
        return sp

    @staticmethod
    def _tid(b: int, i: int) -> str:
        return f"w{b}j{i}"

    def _state(self, b: int, i: int) -> _JobState:
        return self._jobs[(b, i)]

    # -------------------------------------------------- event-log mirror
    def on_log(self, rec: tuple) -> None:
        """Mirror of the engine's event log (called right after every
        append). Kinds that carry everything the tracer needs are handled
        here; kinds that need commitment state use the direct hooks."""
        kind = rec[0]
        if kind == "arrive":
            _, t, b, i, name = rec
            if (b, i) not in self._jobs:           # pragma: no branch
                job = self.eng.jobs[b][i]
                root = self._span(self._tid(b, i), None, "root", "job",
                                  t, t, ward=b, episode=name,
                                  wclass=job.workload or "unclassified",
                                  weight=job.weight,
                                  deadline=job.deadline)
                self._jobs[(b, i)] = _JobState(t, root.span)
                self._open_roots[(b, i)] = root
        elif kind == "retry":
            _, t, b, i, _attempt = rec
            st = self._state(b, i)
            if st.kill_t is not None and t > st.kill_t:
                self._span(self._tid(b, i), st.root, "backoff", "phase",
                           st.kill_t, t, ward=b, attempt=st.attempt)
            st.kill_t = None
            st.attempt_t = t
        elif kind in ("shed", "giveup"):
            t, b, i = rec[1], rec[2], rec[3]
            self._finalize_dropped(kind, t, b, i)
        elif kind == "hedge_promote":
            _, t, b, i, machine = rec
            st = self._state(b, i)
            st.promoted = True
            self._span(self._tid(b, i), st.root, "hedge_promote",
                       "phase", t, t, ward=b, machine=machine)
        elif kind == "fail":
            _, t, tier, ward, k, down_until, kill_flag = rec
            if k >= 0:
                self._span("fleet", None, "outage", "fleet", t,
                           down_until, ward=ward, tier=tier, slot=k,
                           crash=bool(kill_flag))
        elif kind == "slow":
            _, t, tier, ward, k, until, factor = rec
            if k >= 0:
                self._span("fleet", None, "fail_slow", "fleet", t, until,
                           ward=ward, tier=tier, slot=k, rate=factor)
        elif kind == "net":
            _, t, tier, factor, on = rec
            self._span("fleet", None, "net_window", "fleet", t, t,
                       tier=tier, factor=factor, opening=bool(on))
        elif kind == "scale":
            _, t, tier, ward, delta = rec
            self._span("fleet", None, "scale", "fleet", t, t, ward=ward,
                       tier=tier, delta=delta)
        # complete / hcomplete / kill / hedge / hedge_cancel / recover /
        # slowend need no mirror: the direct hooks (or nothing) cover them

    # ------------------------------------------------------ direct hooks
    def on_commit(self, now: float, b: int, i: int, tier: str,
                  arrival: float) -> None:
        """A (re)commit of the primary attempt: track the first decision
        instant of the live attempt and the SHIP record — the commit
        whose uplink window the final transmit span reports. A replan
        that keeps the tier keeps its in-flight ship instant; a re-tier
        (or an arrival clamped forward past already-arrived data)
        re-ships from `now`."""
        st = self._state(b, i)
        if st.decided is None:
            st.decided = now
            self._span(self._tid(b, i), st.root, "decision", "phase",
                       now, now, ward=b, tier=tier, attempt=st.attempt)
        if tier != st.tier or arrival != st.arrival:
            st.ship_t, st.tier, st.arrival = now, tier, arrival

    def on_kill(self, now: float, b: int, i: int, commit,
                wasted: float) -> None:
        """A crash killed the in-flight primary attempt: close its
        attempt span and open the next attempt's bookkeeping."""
        st = self._state(b, i)
        sp = self._span(self._tid(b, i), st.root, "attempt", "attempt",
                        st.attempt_t, now, ward=b, attempt=st.attempt,
                        machine=commit.machine, slot=commit.slot,
                        outcome="killed", wasted=wasted)
        if commit.start <= now:
            self._span(self._tid(b, i), sp.span, "service", "phase",
                       commit.start, now, ward=b, machine=commit.machine,
                       slot=commit.slot, partial=True)
        st.attempt += 1
        st.attempt_t = now
        st.kill_t = now
        st.decided = None
        st.ship_t = st.tier = st.arrival = None

    def on_hedge_dispatch(self, now: float, b: int, i: int,
                          backup) -> None:
        st = self._state(b, i)
        st.hedge_t, st.hedge_tier = now, backup.machine
        self._span(self._tid(b, i), st.root, "hedge", "phase", now, now,
                   ward=b, backup=backup.machine)

    def on_hedge_cancel(self, now: float, b: int, i: int, loser,
                        wasted: float, role: str) -> None:
        """The losing attempt of a hedge race (or a crash-killed backup)
        was cancelled at `now`: record the loser span, cut at the
        winner's instant per the §13 cancellation rule."""
        st = self._state(b, i)
        started = loser.start <= now
        t0 = loser.start if started else \
            (st.hedge_t if role == "backup" and st.hedge_t is not None
             else loser.planned_at)
        self._span(self._tid(b, i), st.root, "hedge_loser", "attempt",
                   min(t0, now), now, ward=b, machine=loser.machine,
                   slot=loser.slot, role=role, started=started,
                   wasted=wasted, outcome="cancelled")

    def on_finish(self, now: float, b: int, i: int, commit,
                  hedge_win: bool) -> None:
        """The job completed on `commit` (primary, or the winning/
        promoted backup): emit the final attempt's phase spans, close the
        root, and derive the exact attribution decomposition."""
        st = self._state(b, i)
        job = commit.job
        win_backup = hedge_win or st.promoted
        if win_backup:
            # the backup's whole life runs from its dispatch instant; the
            # pre-dispatch window is time lost to the straggling primary
            entry = st.hedge_t if st.hedge_t is not None else st.attempt_t
            ship_t = entry
        else:
            entry = st.attempt_t
            ship_t = st.ship_t if st.ship_t is not None \
                and st.tier == commit.machine else commit.planned_at
        arrival, start, end = commit.arrival, commit.start, commit.end
        proc = job.proc[commit.machine]
        terms = {
            "retry_waste": entry - st.release,
            "wait": (ship_t - entry) + (start - arrival),
            "transmit": arrival - ship_t,
            "service": proc,
            "slowdown": (end - start) - proc,
        }
        tid = self._tid(b, i)
        sp = self._span(tid, st.root, "attempt", "attempt", entry, end,
                        ward=b, attempt=st.attempt,
                        machine=commit.machine, slot=commit.slot,
                        outcome="complete", hedge_win=win_backup)
        if ship_t > entry:
            self._span(tid, sp.span, "wait", "phase", entry, ship_t,
                       ward=b, phase="requeue")
        if arrival > ship_t:
            self._span(tid, sp.span, "transmit", "phase", ship_t,
                       arrival, ward=b, tier=commit.machine)
        if start > arrival:
            self._span(tid, sp.span, "wait", "phase", arrival, start,
                       ward=b, phase="queue")
        svc = self._span(tid, sp.span, "service", "phase", start, end,
                         ward=b, machine=commit.machine,
                         slot=commit.slot, proc=proc,
                         slowdown=terms["slowdown"])
        windows = self._slot_windows(b, commit)
        if windows and end > start:
            # split service at every fail-slow rate-change boundary so
            # the straggler window is visible inside the span, not just
            # as a summary number
            from repro.metro.engine import _rate_profile
            segs = [(a, z, f)
                    for a, z, f in _rate_profile(windows, start, end)]
            if len(segs) > 1 or (segs and segs[0][2] != 1.0):
                for a, z, f in segs:
                    self._span(tid, svc.span, "service_seg", "phase",
                               a, z, ward=b, rate=f)
        root = self._open_roots.pop((b, i))
        root.t1 = now
        root.attrs.update(outcome="complete",
                          missed=bool(end - st.release > job.deadline))
        self._row(b, i, job, commit.machine, "complete",
                  end - st.release, terms, hedge_win=win_backup)

    # -------------------------------------------------------- finalizing
    def _slot_windows(self, b: int, commit):
        if commit.machine == ED or commit.slot < 0:
            return ()
        pool = self.eng.cloud if commit.machine == CC \
            else self.eng.edges[b]
        if not 0 <= commit.slot < len(pool.slots):  # pragma: no cover
            return ()
        return pool.slots[commit.slot].slowdowns

    def _finalize_dropped(self, kind: str, now: float, b: int,
                          i: int) -> None:
        """A shed or retry-exhausted giveup: the job never completed, so
        its 'response' is the drop instant — all of it waiting or lost
        to retries, none of it service."""
        st = self._state(b, i)
        job = self.eng.jobs[b][i]
        terms = {"retry_waste": st.attempt_t - st.release,
                 "wait": now - st.attempt_t,
                 "transmit": 0.0, "service": 0.0, "slowdown": 0.0}
        root = self._open_roots.pop((b, i))
        root.t1 = now
        root.attrs.update(outcome=kind, missed=True)
        self._row(b, i, job, "none", kind, now - st.release, terms,
                  hedge_win=False)

    def _row(self, b: int, i: int, job, tier: str, outcome: str,
             response: float, terms: dict, hedge_win: bool) -> None:
        eng = self.eng
        dominant = max(TERMS, key=lambda k: terms[k])
        self.rows.append({
            "ward": b, "index": i, "job": job.name,
            "wclass": job.workload or "unclassified",
            "weight": job.weight, "tier": tier, "outcome": outcome,
            "release": job.release, "deadline": job.deadline,
            "response": response,
            "missed": outcome != "complete" or response > job.deadline,
            "attempts": eng.kills[b][i] + 1,
            "hedged": eng.hedged[b][i], "hedge_win": hedge_win,
            "terms": terms, "dominant": dominant,
        })

    def finish(self) -> "MetroTrace":
        return MetroTrace(spans=self.spans, rows=self.rows)


@dataclass
class MetroTrace:
    """Frozen flight-recorder output carried on `MetroResult.trace`."""
    spans: List[Span]
    rows: List[dict]

    # ---------------------------------------------------------- analysis
    def attribution(self, missed_only: bool = True) -> List[dict]:
        """Per-job response-time decompositions (module docstring), in
        event order. ``missed_only`` keeps missed/shed/giveup jobs."""
        return [r for r in self.rows if r["missed"] or not missed_only]

    def blame_table(self) -> List[dict]:
        """Deadline-miss blame aggregated per (class, tier): counts, mean
        decomposition terms and the dominant term by total time — the
        postmortem table. Sorted by total missed time, heaviest first."""
        agg: Dict[Tuple[str, str], dict] = {}
        for r in self.attribution(missed_only=True):
            key = (r["wclass"], r["tier"])
            row = agg.get(key)
            if row is None:
                row = agg[key] = {
                    "wclass": key[0], "tier": key[1], "misses": 0,
                    "shed": 0, "response": 0.0,
                    "terms": {t: 0.0 for t in TERMS}}
            row["misses"] += 1
            row["shed"] += int(r["outcome"] != "complete")
            row["response"] += r["response"]
            for t in TERMS:
                row["terms"][t] += r["terms"][t]
        out = []
        for row in sorted(agg.values(), key=lambda x: -x["response"]):
            n = row["misses"]
            out.append({
                "wclass": row["wclass"], "tier": row["tier"],
                "misses": n, "shed": row["shed"],
                "mean_response": row["response"] / n,
                "mean_terms": {t: row["terms"][t] / n for t in TERMS},
                "total_terms": dict(row["terms"]),
                "dominant": max(TERMS, key=lambda t: row["terms"][t]),
            })
        return out

    def format_postmortem(self, policy: str = "?",
                          profile: Optional[dict] = None,
                          compiled_shapes: Optional[dict] = None) -> str:
        """Human-readable postmortem block (serve --metro --postmortem):
        the blame table plus the engine self-profile and compiled-shape
        cache counters when available."""
        lines = [f"postmortem[{policy}]: {len(self.attribution())} "
                 f"missed/shed jobs of {len(self.rows)} finished"]
        table = self.blame_table()
        if table:
            lines.append(
                f"  {'class':28s} {'tier':6s} {'miss':>5s} {'shed':>5s} "
                f"{'resp':>7s} " +
                " ".join(f"{t:>11s}" for t in TERMS) + "  dominant")
            for row in table:
                lines.append(
                    f"  {row['wclass']:28s} {row['tier']:6s} "
                    f"{row['misses']:5d} {row['shed']:5d} "
                    f"{row['mean_response']:7.1f} " +
                    " ".join(f"{row['mean_terms'][t]:11.2f}"
                             for t in TERMS) +
                    f"  {row['dominant']}")
        else:
            lines.append("  no deadline misses — nothing to attribute")
        if profile:
            busy = {k: v for k, v in profile.items()
                    if isinstance(v, float) and k != "seconds_total"}
            lines.append(
                "  engine profile: " +
                " ".join(f"{k}={v*1e3:.1f}ms"
                         for k, v in sorted(busy.items(),
                                            key=lambda kv: -kv[1])) +
                f" (total {profile.get('seconds_total', 0.0)*1e3:.1f}ms, "
                f"{profile.get('events', 0)} events)")
        if compiled_shapes:
            lines.append(
                f"  shape cache: size={compiled_shapes.get('size', 0)} "
                f"hits={compiled_shapes.get('hits', 0)} "
                f"misses={compiled_shapes.get('misses', 0)} "
                f"evictions={compiled_shapes.get('evictions', 0)}")
        return "\n".join(lines)

    def postmortem_json(self, policy: str = "?",
                        profile: Optional[dict] = None,
                        compiled_shapes: Optional[dict] = None) -> dict:
        return {"policy": policy, "finished": len(self.rows),
                "missed": self.attribution(missed_only=True),
                "blame": self.blame_table(),
                "profile": profile or {},
                "compiled_shapes": compiled_shapes or {}}

    # ---------------------------------------------------------- exporters
    def to_jsonl(self, path: str) -> int:
        """One span object per line; -> span count."""
        with open(path, "w") as f:
            for sp in self.spans:
                f.write(json.dumps(sp.to_dict()) + "\n")
        return len(self.spans)

    def to_chrome(self, path: str) -> int:
        """Chrome trace-event JSON (opens directly in Perfetto/
        chrome://tracing): wards as process rows, machine slots as
        thread rows (service occupancy + fleet outage/fail-slow
        windows), jobs as nestable async tracks. -> event count."""
        ev: List[dict] = []

        def meta(name, pid, tid=None, label=""):
            rec = {"ph": "M", "name": name, "pid": pid,
                   "args": {"name": label}}
            if tid is not None:
                rec["tid"] = tid
            ev.append(rec)

        meta("process_name", 0, label="cloud pool")
        wards = {sp.ward for sp in self.spans if sp.ward >= 0}
        for b in sorted(wards):
            meta("process_name", 1 + b, label=f"ward {b}")

        def pool_pid(tier, ward):
            return 0 if tier == CC else 1 + ward

        named_tids = set()

        def slot_tid(pid, slot, windows=False):
            tid = (1000 if windows else 0) + slot
            if (pid, tid) not in named_tids:
                named_tids.add((pid, tid))
                meta("thread_name", pid, tid,
                     f"slot {slot}" + (" windows" if windows else ""))
            return tid

        for sp in self.spans:
            if sp.cat == "fleet":
                tier = sp.attrs.get("tier")
                if sp.name in ("outage", "fail_slow"):
                    pid = pool_pid(tier, sp.ward)
                    ev.append({
                        "ph": "X", "pid": pid,
                        "tid": slot_tid(pid, sp.attrs["slot"],
                                        windows=True),
                        "name": sp.name, "cat": "fleet",
                        "ts": sp.t0 * _CHROME_US,
                        "dur": max(sp.duration, 0.0) * _CHROME_US,
                        "args": sp.attrs})
                else:
                    ev.append({"ph": "i", "pid": 0, "tid": 0, "s": "g",
                               "name": sp.name, "cat": "fleet",
                               "ts": sp.t0 * _CHROME_US,
                               "args": sp.attrs})
                continue
            # service occupancy rides the machine-slot thread rows; the
            # engine's I2 invariant guarantees they never overlap per slot
            if sp.name in ("service", "hedge_loser") and \
                    sp.attrs.get("machine") in (CC, ES) and \
                    sp.attrs.get("slot", -1) >= 0 and \
                    (sp.name != "hedge_loser" or sp.attrs["started"]):
                pid = pool_pid(sp.attrs["machine"], sp.ward)
                ev.append({
                    "ph": "X", "pid": pid,
                    "tid": slot_tid(pid, sp.attrs["slot"]),
                    "name": sp.trace, "cat": "occupancy",
                    "ts": sp.t0 * _CHROME_US,
                    "dur": max(sp.duration, 0.0) * _CHROME_US,
                    "args": sp.attrs})
            # every job span is an async b/e pair under its ward row —
            # async tracks nest by timestamp, so concurrent jobs never
            # collide the way same-tid X slices would
            pid = 1 + sp.ward if sp.ward >= 0 else 0
            base = {"pid": pid, "tid": 0, "id": sp.trace, "cat": sp.cat,
                    "name": f"{sp.trace}:{sp.name}"
                    if sp.name == "root" else sp.name}
            if sp.duration <= 0.0:
                ev.append({"ph": "n", "ts": sp.t0 * _CHROME_US,
                           "args": sp.attrs, **base})
            else:
                ev.append({"ph": "b", "ts": sp.t0 * _CHROME_US,
                           "args": sp.attrs, **base})
                ev.append({"ph": "e", "ts": sp.t1 * _CHROME_US, **base})
        with open(path, "w") as f:
            json.dump({"traceEvents": ev,
                       "displayTimeUnit": "ms",
                       "otherData": {"source": "repro.metro.tracing",
                                     "time_unit": "1 trace minute = 1s"}},
                      f)
        return len(ev)

    def write(self, path: str, fmt: str = "jsonl") -> int:
        if fmt == "chrome":
            return self.to_chrome(path)
        if fmt == "jsonl":
            return self.to_jsonl(path)
        raise ValueError(f"unknown trace format {fmt!r}; "
                         f"expected 'jsonl' or 'chrome'")


class EngineProfile:
    """Engine self-profiling accumulators (armed by
    ``MetroEngine.run(profile=True)``): wall-clock phase timers for the
    replay, policy calls, the sanitizer and the hedge hook, per-event-kind
    handler times, and heap/bookkeeping residual. Pure measurement — the
    profiler never influences event timing (simulated time lives in the
    heap), so profiled runs stay bit-identical."""

    __slots__ = ("replay", "policy", "sanitize", "hedge_hook",
                 "handlers", "heap_pushes", "decide_calls",
                 "shapes_before")

    def __init__(self, shapes_before: Optional[dict] = None):
        self.replay = 0.0
        self.policy = 0.0
        self.sanitize = 0.0
        self.hedge_hook = 0.0
        self.handlers: Dict[str, float] = {}
        self.heap_pushes = 0
        self.decide_calls = 0
        self.shapes_before = dict(shapes_before or {})

    def add_handler(self, kind: str, dt: float) -> None:
        self.handlers[kind] = self.handlers.get(kind, 0.0) + dt

    def summary(self, seconds_total: float, events: int,
                shapes_after: Optional[dict] = None) -> dict:
        handled = sum(self.handlers.values())
        out = {
            "seconds_total": seconds_total,
            "events": events,
            "replay": self.replay,
            "policy": self.policy,
            "sanitize": self.sanitize,
            "hedge_hook": self.hedge_hook,
            "heap_and_dispatch": max(0.0, seconds_total - handled),
            "handlers_by_kind": dict(sorted(self.handlers.items())),
            "heap_pushes": self.heap_pushes,
            "decide_calls": self.decide_calls,
        }
        if shapes_after is not None:
            before = self.shapes_before
            out["compiled_shapes"] = dict(shapes_after)
            out["compiled_shapes_delta"] = {
                k: shapes_after.get(k, 0) - before.get(k, 0)
                for k in ("hits", "misses", "evictions")}
        return out
