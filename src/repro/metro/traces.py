"""Patient-episode traffic generators for the metro engine (DESIGN.md §10).

A patient EPISODE is the paper's three-app cascade in clinical order —
short-of-breath alert, then the phenotype classification it triggers,
then the life-death threat assessment — released as a correlated burst
(each stage follows the previous by a small random lag). Episode start
times come from a nonhomogeneous Poisson process whose intensity carries
a diurnal swing plus optional mass-casualty surge windows; sampling is by
thinning, so a given `rng` yields a bit-identical trace.

Costs reuse `problems.metro_costs` (the Table VI metro regime the §9
contention benchmark is built on) scaled per stage: the life-death model
is tiny (paper Table IV: 7.5k FLOPs), the phenotype classifier heavy
(347k). Deadlines are per-workload-class response budgets carried on
`JobSpec.deadline`; one trace time unit reads as one minute.

Also provides the fleet-event streams the engine consumes — Poisson
machine failures (drain or crash mode) with repair times, degraded-
network windows, fail-slow slowdown windows, surge-following elastic
scale events — and the seeded chaos scenario-pack registry
(`SCENARIO_PACKS` / `make_scenario`): named (traces, failures, scales,
network, slowdowns) bundles that serve, the benchmarks and the
per-scenario regression floors all share, so a pack name plus a seed
pins one bit-identical chaos run (DESIGN.md §11).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.problems import metro_costs
from repro.core.simulator import JobSpec
from repro.core.tiers import CC, ES
from repro.metro.engine import (FailureEvent, NetworkEvent, ScaleEvent,
                                SlowdownEvent)

DAY = 1440.0                      # minutes


@dataclass(frozen=True)
class EpisodeStage:
    """One app of the episode cascade: who it is, how urgent, how big."""
    workload: str                 # ICULSTMConfig name (serving engine key)
    short: str                    # job-name suffix
    weight: float                 # paper Table IV priority
    deadline: float               # response SLA budget (time units)
    cost_scale: float             # metro_costs scale (FLOPs-proportional)
    lag: Tuple[float, float]      # uniform delay after the previous stage


# Paper Table IV: alerts w=2 (105k FLOPs), phenotype w=1 (347k),
# life-death w=2 (7.5k). Deadlines tighten with clinical urgency.
EPISODE_STAGES: Tuple[EpisodeStage, ...] = (
    EpisodeStage("short-of-breath-alerts", "alert",
                 weight=2.0, deadline=35.0, cost_scale=0.6,
                 lag=(0.0, 0.0)),
    EpisodeStage("patient-phenotype-classification", "phenotype",
                 weight=1.0, deadline=120.0, cost_scale=1.4,
                 lag=(0.5, 2.0)),
    EpisodeStage("life-death-prediction", "threat",
                 weight=2.0, deadline=18.0, cost_scale=0.25,
                 lag=(0.5, 2.0)),
)


def intensity(t: float, base_rate: float, *, diurnal_amp: float = 0.5,
              day_offset: float = 8 * 60.0,
              surges: Sequence[Tuple[float, float, float]] = ()) -> float:
    """Episode arrival intensity at trace time t (episodes per unit).

    Diurnal swing peaks six hours after `day_offset` (start-of-trace
    clock time); each surge (t0, t1, boost) multiplies the rate by
    1 + boost inside its window — the ER mass-casualty regime."""
    lam = base_rate * (1.0 + diurnal_amp
                       * math.sin(2.0 * math.pi * (t + day_offset) / DAY))
    for t0, t1, boost in surges:
        if t0 <= t < t1:
            lam *= 1.0 + boost
    return max(lam, 0.0)


def episode_times(rng: np.random.Generator, horizon: float,
                  base_rate: float, **kw) -> List[float]:
    """Nonhomogeneous Poisson episode starts in [0, horizon) by thinning."""
    surges = kw.get("surges", ())
    # envelope over ALL surge windows at once: intensity() multiplies the
    # (1 + boost) factors of every window containing t, so overlapping
    # windows compound — the product is the only sound thinning bound
    boost = 1.0
    for _, _, b in surges:
        boost *= 1.0 + b
    lam_max = base_rate * (1.0 + kw.get("diurnal_amp", 0.5)) * boost
    if lam_max <= 0:
        raise ValueError(f"nonpositive peak intensity {lam_max}")
    out, t = [], 0.0
    while True:
        t += float(rng.exponential(1.0 / lam_max))
        if t >= horizon:
            return out
        if float(rng.uniform()) * lam_max <= intensity(t, base_rate, **kw):
            out.append(t)


def ward_trace(rng: np.random.Generator, ward: int, horizon: float, *,
               base_rate: float = 0.15, diurnal_amp: float = 0.5,
               day_offset: float = 8 * 60.0,
               surges: Sequence[Tuple[float, float, float]] = (),
               stages: Sequence[EpisodeStage] = EPISODE_STAGES
               ) -> List[JobSpec]:
    """One ward's job stream: every episode expands into the staged
    cascade (stages releasing past `horizon` still emit — an admitted
    patient is followed to the end). Sorted by release; stable job
    naming (`w<ward>p<episode>-<stage>`) keys the event log."""
    jobs: List[JobSpec] = []
    for ep, t0 in enumerate(episode_times(
            rng, horizon, base_rate, diurnal_amp=diurnal_amp,
            day_offset=day_offset, surges=surges)):
        t = t0
        for stage in stages:
            lo, hi = stage.lag
            t += float(rng.uniform(lo, hi)) if hi > lo else lo
            proc, trans = metro_costs(rng, scale=stage.cost_scale)
            jobs.append(JobSpec(
                name=f"w{ward}p{ep}-{stage.short}", release=t,
                weight=stage.weight, proc=proc, trans=trans,
                workload=stage.workload, deadline=stage.deadline))
    jobs.sort(key=lambda j: (j.release, j.name))
    return jobs


def metro_traces(rng: np.random.Generator, wards: int, horizon: float,
                 **kw) -> List[List[JobSpec]]:
    """Per-ward traces off one rng stream (ward draws are sequential, so
    the whole fleet's traffic is one seed)."""
    return [ward_trace(rng, b, horizon, **kw) for b in range(wards)]


def failure_events(rng: np.random.Generator, horizon: float, *,
                   tier: str = CC, ward: int | None = None,
                   mtbf: float = 60.0,
                   mttr: Tuple[float, float] = (8.0, 20.0),
                   kill_running: bool = False,
                   window: Tuple[float, float] | None = None
                   ) -> List[FailureEvent]:
    """Poisson machine failures on one pool: exponential inter-failure
    times (`mtbf`), uniform repair durations (`mttr`). Cloud failures
    (ward=None) hit the shared pool and so replan every ward at one
    event count — the batched-replan trigger (DESIGN.md §10).
    `kill_running=True` makes them crashes (in-flight job lost and
    retried, DESIGN.md §11); `window=(t0, t1)` confines the process to
    one chaos window instead of the whole [0, horizon)."""
    lo, hi = window if window is not None else (0.0, horizon)
    out, t = [], lo
    while True:
        t += float(rng.exponential(mtbf))
        if t >= hi:
            return out
        out.append(FailureEvent(time=t, tier=tier, ward=ward,
                                duration=float(rng.uniform(*mttr)),
                                kill_running=kill_running))


def network_events(rng: np.random.Generator, horizon: float, *,
                   tier: str = CC, windows: int = 2,
                   duration: Tuple[float, float] = (10.0, 25.0),
                   factor: Tuple[float, float] = (2.0, 5.0)
                   ) -> List[NetworkEvent]:
    """`windows` degraded-uplink windows on one shared tier: starts
    uniform over the horizon (sorted), durations and slowdown factors
    uniform over their ranges. Windows may overlap — the engine
    compounds their factors."""
    starts = sorted(float(rng.uniform(0.0, 0.85 * horizon))
                    for _ in range(windows))
    return [NetworkEvent(time=t, tier=tier,
                         duration=float(rng.uniform(*duration)),
                         factor=float(rng.uniform(*factor)))
            for t in starts]


def slowdown_events(rng: np.random.Generator, horizon: float, *,
                    tier: str = CC, ward: int | None = None,
                    windows: int = 3,
                    duration: Tuple[float, float] = (10.0, 25.0),
                    factor: Tuple[float, float] = (0.15, 0.4),
                    span: Tuple[float, float] | None = None
                    ) -> List[SlowdownEvent]:
    """`windows` fail-slow windows on one pool: starts uniform over the
    (optionally confined) span, durations and rate factors uniform over
    their ranges. Each strikes the busiest machine at its onset;
    overlapping windows on one machine compound (DESIGN.md §13)."""
    lo, hi = span if span is not None else (0.0, 0.85 * horizon)
    starts = sorted(float(rng.uniform(lo, hi)) for _ in range(windows))
    return [SlowdownEvent(time=t, tier=tier, ward=ward,
                          duration=float(rng.uniform(*duration)),
                          factor=float(rng.uniform(*factor)))
            for t in starts]


def default_scenario(seed: int, wards: int = 4, horizon: float = 120.0, *,
                     base_rate: float = 0.12,
                     surges: Sequence[Tuple[float, float, float]] | None
                     = None,
                     mtbf: float = 35.0, elastic: bool = True):
    """The canonical metro benchmark scenario (serve --metro and
    benchmarks/scheduler_scale.py share it): `wards` wards at a diurnal
    base rate with one mid-run mass-casualty surge, Poisson cloud
    machine failures, and elastic cloud capacity tracking the surge.
    -> (ward_traces, failure_events, scale_events)."""
    if surges is None:
        surges = ((0.375 * horizon, 0.625 * horizon, 3.0),)
    tr = metro_traces(np.random.default_rng(seed), wards, horizon,
                      base_rate=base_rate, surges=surges)
    fails = failure_events(np.random.default_rng(seed + 1), horizon,
                           mtbf=mtbf)
    scales = surge_scale_events(surges) if elastic else []
    return tr, fails, scales


def surge_scale_events(surges: Sequence[Tuple[float, float, float]], *,
                       tier: str = CC, machines: int = 1
                       ) -> List[ScaleEvent]:
    """Elastic capacity tracking the surge windows: +machines at each
    surge start, -machines at its end (the scaled-down servers retire
    once their running job drains)."""
    out: List[ScaleEvent] = []
    for t0, t1, _ in surges:
        out.append(ScaleEvent(time=t0, tier=tier, ward=None,
                              delta=machines))
        out.append(ScaleEvent(time=t1, tier=tier, ward=None,
                              delta=-machines))
    return out


# --------------------------------------------------------- scenario packs
@dataclass(frozen=True)
class Scenario:
    """One named chaos scenario: everything a MetroEngine run consumes,
    a pure function of (pack name, seed, wards, horizon)."""
    name: str
    traces: List[List[JobSpec]]
    failures: List[FailureEvent] = field(default_factory=list)
    scales: List[ScaleEvent] = field(default_factory=list)
    network: List[NetworkEvent] = field(default_factory=list)
    slowdowns: List[SlowdownEvent] = field(default_factory=list)

    @property
    def jobs(self) -> int:
        return sum(len(t) for t in self.traces)


def _pack_default(seed: int, wards: int, horizon: float) -> Scenario:
    tr, fails, scales = default_scenario(seed, wards, horizon)
    return Scenario("default", tr, fails, scales)


def _pack_edge_brownout(seed: int, wards: int, horizon: float) -> Scenario:
    """Every ward's edge pool takes CRASH failures through a mid-run
    brownout window at a heavy base rate: in-flight edge inference is
    lost and must retry — usually failing over to the (healthy) shared
    cloud."""
    tr = metro_traces(np.random.default_rng(seed), wards, horizon,
                      base_rate=0.3)
    fails: List[FailureEvent] = []
    for b in range(wards):
        fails += failure_events(
            np.random.default_rng(seed + 101 + b), horizon,
            tier=ES, ward=b, mtbf=0.2 * horizon, mttr=(6.0, 15.0),
            kill_running=True, window=(0.3 * horizon, 0.7 * horizon))
    fails.sort(key=lambda e: e.time)
    return Scenario("edge_brownout", tr, fails)


def _pack_mass_casualty_crash(seed: int, wards: int,
                              horizon: float) -> Scenario:
    """A mass-casualty surge (4x arrivals) colliding with crash failures
    on the shared cloud pool inside the surge window, while elastic
    capacity tracks the surge — the saturation regime load shedding is
    built for."""
    surges = ((0.35 * horizon, 0.7 * horizon, 4.0),)
    tr = metro_traces(np.random.default_rng(seed), wards, horizon,
                      base_rate=0.12, surges=surges)
    fails = failure_events(
        np.random.default_rng(seed + 1), horizon,
        mtbf=0.15 * horizon, mttr=(8.0, 18.0), kill_running=True,
        window=surges[0][:2])
    return Scenario("mass_casualty_crash", tr, fails,
                    scales=surge_scale_events(surges))


def _pack_degraded_network(seed: int, wards: int,
                           horizon: float) -> Scenario:
    """Cloud uplink degradation windows (transmission times scaled 2-5x)
    plus sparse drain failures at a heavy base rate: replans made inside
    a window must price the slow uplink and keep work at the edge."""
    tr = metro_traces(np.random.default_rng(seed), wards, horizon,
                      base_rate=0.3)
    fails = failure_events(np.random.default_rng(seed + 1), horizon,
                           mtbf=horizon, mttr=(8.0, 15.0))
    net = network_events(np.random.default_rng(seed + 2), horizon,
                         windows=2, duration=(0.1 * horizon,
                                              0.25 * horizon),
                         factor=(2.0, 5.0))
    return Scenario("degraded_network", tr, fails, network=net)


def _pack_diurnal_day(seed: int, wards: int, horizon: float) -> Scenario:
    """A full simulated day at low base rate with a strong diurnal swing
    and occasional drain failures — the long-haul streaming-metrics
    regime (windowed quantiles actually roll)."""
    tr = metro_traces(np.random.default_rng(seed), wards, horizon,
                      base_rate=0.035, diurnal_amp=0.8)
    fails = failure_events(np.random.default_rng(seed + 1), horizon,
                           mtbf=360.0, mttr=(10.0, 30.0))
    return Scenario("diurnal_day", tr, fails)


def _pack_fail_slow_tail(seed: int, wards: int,
                         horizon: float) -> Scenario:
    """Fail-slow machines without a single fail-stop event: deep
    slowdown windows (machines crawling at 3-8% speed — a failing disk
    or thermal throttle, not an outage) strike the ward edge pools,
    the workhorse tier, while the metropolitan cloud stays healthy.
    Nothing crashes and nothing is lost — in-flight edge work just
    silently stretches — which is exactly the regime deadline-aware
    hedging is built for: an unhedged run eats the stretched tail (a
    started commitment is immutable, C2, so replanning cannot save it),
    a hedged run races a healthy-tier backup against the straggler and
    cancels the loser (DESIGN.md §13)."""
    tr = metro_traces(np.random.default_rng(seed), wards, horizon,
                      base_rate=0.15)
    rng = np.random.default_rng(seed + 201)
    slows: List[SlowdownEvent] = []
    for b in range(wards):
        slows.extend(slowdown_events(
            rng, horizon, tier=ES, ward=b, windows=6,
            duration=(0.1 * horizon, 0.2 * horizon),
            factor=(0.03, 0.08)))
    slows.sort(key=lambda e: e.time)
    return Scenario("fail_slow_tail", tr, slowdowns=slows)


# name -> (builder, default wards, default horizon in trace minutes)
SCENARIO_PACKS: Dict[str, Tuple[
    Callable[[int, int, float], Scenario], int, float]] = {
    "default": (_pack_default, 4, 120.0),
    "edge_brownout": (_pack_edge_brownout, 4, 90.0),
    "mass_casualty_crash": (_pack_mass_casualty_crash, 4, 90.0),
    "degraded_network": (_pack_degraded_network, 4, 90.0),
    "diurnal_day": (_pack_diurnal_day, 2, DAY),
    "fail_slow_tail": (_pack_fail_slow_tail, 4, 180.0),
}


def make_scenario(name: str, seed: int = 0, *,
                  wards: Optional[int] = None,
                  horizon: Optional[float] = None) -> Scenario:
    """Build a registered chaos pack. `wards`/`horizon` default to the
    pack's canonical shape (the one the committed per-scenario floors
    were measured on); overriding them is fine for smokes but produces
    a different — still deterministic — run."""
    try:
        builder, d_wards, d_horizon = SCENARIO_PACKS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario pack {name!r}; registered: "
            f"{sorted(SCENARIO_PACKS)}") from None
    return builder(seed, wards if wards is not None else d_wards,
                   horizon if horizon is not None else d_horizon)
