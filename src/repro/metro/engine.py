"""Discrete-event metro traffic engine (DESIGN.md §10).

Event loop over job arrivals, completions, machine failures/recoveries
and elastic scale events for B hospital wards sharing one metropolitan
cloud pool (per-ward edge pools, private devices — the §9 fleet model,
now under streaming load instead of a finite scored-once job list).

Ground truth lives HERE, not in the policy: machines are explicit slots
with identity (so a failure can strike a specific machine and elastic
scale-down can retire one), and after every decision the engine replays
each pool's unstarted commitments through the same FIFO-by-arrival
dispatch `simulate` defines (C1–C5). Policies only pick tiers; the
replay prices their choices on the real fleet — a ward-local plan that
double-books the shared cloud gets delayed by the merged queue, exactly
as in `simulate_fleet`.

Commitment semantics follow `online_schedule` (DESIGN.md §7): a job
whose machine slot has begun (start <= now) is immutable (C2); every
other commitment may be re-tiered by the policy and is re-timed by the
replay. A *drain* failure (the default) never drops a running job — the
machine finishes it, then goes down for the repair duration, delaying
its queue successors. A *crash* failure (`kill_running=True`) kills the
struck machine's in-flight job: its commitment is invalidated, the
partial run's machine-seconds are recorded as wasted, and the job
returns to the pending set to be re-dispatched through the normal
decision path (retries count as fresh arrivals, so search policies may
fail it over to another tier). Policies may also return the SHED
sentinel for a movable job — the engine drops it with a ``shed`` event
and scores it as an explicit deadline miss (DESIGN.md §11). With B = 1
wards, no failures and the tabu policy, the engine's event sequence IS
`online_schedule(replan="tabu")` and the committed schedules match
bit-for-bit (tests/test_metro.py).

Degraded-network windows (`NetworkEvent`) multiply a shared tier's
transmission times while active: every decision made inside the window
prices the degraded uplink (the §7 shifted specs carry scaled
transmission for any tier the job would re-ship to), while data already
in flight toward a committed tier keeps its committed arrival.

Fail-slow windows (`SlowdownEvent`, DESIGN.md §13) degrade a machine
without killing anything: the struck slot serves `factor < 1` work
units per wall second for the window, in-flight completions and queued
successors are re-timed through the piecewise rate profile, and
`capacity_integral` discounts the forgone service. Tail tolerance rides
on top: with `hedge_factor` set and a policy exposing a `hedge()` hook
(see `HedgingPolicy`), a watchdog event fires when an in-flight job has
run `hedge_factor x` its committed proc time — or its committed end
already misses the deadline — and the policy may dispatch ONE backup
attempt on another tier. First completion wins; the loser is cancelled
at the winner's completion instant and its consumed machine-seconds are
scored as `hedge_waste`. Crash retries are bounded: `retry_backoff`
delays re-decision exponentially per attempt and `max_attempts` (global
or per-class) sheds-with-record instead of dispatching a storm. All
four knobs default OFF, reproducing the PR 6 engine event-for-event.

Completion events are scheduled from commitment end times and validated
lazily on pop (a replan that re-times a commitment simply strands the
stale event), the standard DES invalidation scheme — so the event log is
a deterministic function of (traces, fleet events, policy) and of the
`scheduler.search` dispatch state: search-based policies inherit the
§3.3 compiled-shape cache, so a process that force-compiled a shape
before the run may legitimately commit a different (equally exact)
local optimum than a fresh process. Pin `jax_threshold` on the policy
for call-order-independent runs; the committed benchmarks run in a
fresh process with a fixed section order.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, replace
from typing import (Dict, List, Mapping, Optional, Sequence, Set, Tuple,
                    Union)

from repro.core import online
from repro.core.simulator import JobSpec, Schedule, ScheduledJob
from repro.core.tiers import CC, ED, ES
from repro.metro.metrics import MetroMetrics
from repro.metro.policies import SHED, HedgeRequest, Policy, ReplanRequest

_INF = float("inf")
# same-instant ordering: completions first (a machine freeing at t is
# visible to a replan at t), then fleet/network events (slowdown onsets
# with failures, window closes with recoveries), then hedge watchdogs
# (they must see the post-event fleet), then arrivals/backoff retries
(_P_COMPLETE, _P_FAIL, _P_SLOW, _P_SCALE, _P_RECOVER, _P_SLOWEND,
 _P_NET, _P_HEDGE, _P_ARRIVE) = range(9)
# decisions a policy may return per movable job (validated centrally
# in _decide — not ad hoc per commit branch)
_DECISIONS = frozenset((CC, ES, ED, SHED))


@dataclass(frozen=True)
class FailureEvent:
    """A machine in `tier`'s pool (ward-local for edge, fleet-wide for
    cloud) breaks at `time` for `duration`.

    Drain mode (default): the earliest-free machine is struck, finishes
    any running job, then stays down until repaired — nothing is lost.

    Crash mode (``kill_running=True``): the BUSIEST (latest-free)
    machine is struck and dies immediately; its in-flight job is LOST —
    the partial run is wasted machine-seconds, the commitment is
    invalidated and the job re-dispatches through the normal decision
    path (DESIGN.md §11)."""
    time: float
    tier: str = CC
    ward: Optional[int] = None           # None = the shared cloud pool
    duration: float = 10.0
    kill_running: bool = False


@dataclass(frozen=True)
class NetworkEvent:
    """Degraded-network window: transmission times toward `tier` are
    multiplied by `factor` during [time, time + duration). Overlapping
    windows compound. Decisions made inside the window price the
    degraded uplink; data already shipped toward a committed tier keeps
    its committed arrival (the in-flight contract, DESIGN.md §11)."""
    time: float
    duration: float = 30.0
    tier: str = CC
    factor: float = 4.0


@dataclass(frozen=True)
class SlowdownEvent:
    """Fail-slow window (DESIGN.md §13): the BUSIEST (latest-free)
    non-retired machine in `tier`'s pool runs at `factor` (< 1) of
    nominal speed during [time, time + duration). The struck machine's
    in-flight job keeps its placement (C2) but its completion — and
    every queued successor — is re-timed through the piecewise-constant
    rate profile; overlapping windows on one machine compound by factor
    product (like network factors). Unlike a failure nothing is lost:
    the machine delivers `factor` service units per wall second, and
    `capacity_integral` shaves the forgone (1 - factor) fraction off
    every up interval the window covers."""
    time: float
    tier: str = CC
    ward: Optional[int] = None           # None = the shared cloud pool
    duration: float = 20.0
    factor: float = 0.25


@dataclass(frozen=True)
class ScaleEvent:
    """Elastic capacity: delta > 0 adds machines to the pool at `time`;
    delta < 0 retires the earliest-free ones (each finishes its running
    job, then leaves the pool for good)."""
    time: float
    tier: str = CC
    ward: Optional[int] = None
    delta: int = 1


@dataclass
class _Commit:
    """One job's current commitment. Attribute names match
    `online._Commit` so `online._replan_spec` builds the replan view."""
    job: JobSpec
    machine: str
    arrival: float
    start: float
    end: float
    slot: int = -1
    planned_at: float = 0.0


class _Slot:
    """One machine with identity: when it joined the pool, until when it
    is down (inf = retired), its recorded outage intervals (exact
    utilisation accounting), and its fail-slow windows
    (t0, t1, factor)."""
    __slots__ = ("created", "down", "outages", "slowdowns", "retired_at")

    def __init__(self, created: float = 0.0):
        self.created = created
        self.down = created          # not dispatchable before it exists
        self.outages: List[Tuple[float, float]] = []
        self.slowdowns: List[Tuple[float, float, float]] = []
        self.retired_at: Optional[float] = None


def _rate_profile(windows: Sequence[Tuple[float, float, float]],
                  lo: float, hi: float):
    """Piecewise-constant service rate of one machine over [lo, hi):
    yields (seg_start, seg_end, rate) where rate is the product of every
    fail-slow factor whose window covers the segment. Cut points include
    all window boundaries inside (lo, hi), so each segment is entirely
    inside or outside each window."""
    pts = {lo, hi}
    for t0, t1, _ in windows:
        if lo < t0 < hi:
            pts.add(t0)
        if lo < t1 < hi:
            pts.add(t1)
    cuts = sorted(pts)
    for a, b in zip(cuts, cuts[1:]):
        f = 1.0
        for t0, t1, fac in windows:
            if t0 <= a and b <= t1:
                f *= fac
        yield a, b, f


def _work_done(windows: Sequence[Tuple[float, float, float]],
               t0: float, t1: float) -> float:
    """Service units a machine delivers over wall interval [t0, t1).
    With no fail-slow windows this is exactly `t1 - t0` (bit-identical
    to the pre-fail-slow wall-clock accounting)."""
    if t1 <= t0:
        return 0.0
    if not windows:
        return t1 - t0
    return sum(f * (b - a) for a, b, f in _rate_profile(windows, t0, t1))


def _finish_time(windows: Sequence[Tuple[float, float, float]],
                 start: float, work: float) -> float:
    """Wall-clock instant at which `work` service units started at
    `start` complete on a machine with the given fail-slow windows.
    Inverse of `_work_done`; exactly `start + work` when no window
    exists or all windows closed before `start`."""
    if not windows or start == _INF or work == _INF:
        return start + work
    hi = max(t1 for _, t1, _ in windows)
    if start >= hi:
        return start + work
    for a, b, f in _rate_profile(windows, start, hi):
        seg = f * (b - a)
        if work <= seg:
            return a + work / f
        work -= seg
    return hi + work


class _Pool:
    def __init__(self, tier: str, machines: int):
        if machines < 1:
            raise ValueError(f"{tier} pool needs >= 1 machine")
        self.tier = tier
        self.slots = [_Slot() for _ in range(machines)]
        # per-machine free times with every queued commitment dispatched —
        # the greedy policy's reserved view; refreshed by each replay
        self.reserved: List[float] = [0.0] * machines

    def capacity_integral(self, t_end: float) -> float:
        """Machine-seconds of SERVICE the pool could have delivered in
        [0, t_end]. Outage intervals may overlap (a crash can strike an
        already-down machine), so they are union-merged before
        subtracting; fail-slow windows then shave the forgone
        (1 - rate) fraction off every up segment they cover — the same
        union-merge treatment, so a window inside an outage is not
        double-subtracted (DESIGN.md §13)."""
        total = 0.0
        for s in self.slots:
            hi = min(s.retired_at if s.retired_at is not None else t_end,
                     t_end)
            span = max(0.0, hi - s.created)
            if span == 0.0:
                total += 0.0
                continue
            clipped = sorted(
                (max(d0, s.created), min(d1, hi))
                for d0, d1 in s.outages if min(d1, hi) > max(d0, s.created))
            merged: List[List[float]] = []
            for d0, d1 in clipped:
                if merged and d0 <= merged[-1][1]:
                    if d1 > merged[-1][1]:
                        merged[-1][1] = d1
                else:
                    merged.append([d0, d1])
            for d0, d1 in merged:
                span -= d1 - d0
            if s.slowdowns:
                for a, b, f in _rate_profile(s.slowdowns, s.created, hi):
                    if f >= 1.0:
                        continue
                    seg = b - a
                    for d0, d1 in merged:
                        ov = min(b, d1) - max(a, d0)
                        if ov > 0:
                            seg -= ov
                    span -= (1.0 - f) * max(0.0, seg)
            total += max(0.0, span)
        return total


@dataclass
class MetroResult:
    """One policy's run: verbatim committed schedules per ward, streaming
    metrics, exact per-tier utilisation, the deterministic event log, and
    the wall-clock throughput of the run. `trace` carries the flight
    recorder's `MetroTrace` when the run was traced (§15), `profile` the
    self-profiling summary dict when profiled — both None otherwise."""
    policy: str
    wards: List[Schedule]
    metrics: MetroMetrics
    utilization: Dict[str, float]
    event_log: List[tuple]
    events: int
    seconds: float
    trace: Optional[object] = None
    profile: Optional[dict] = None

    @property
    def events_per_s(self) -> float:
        return self.events / self.seconds if self.seconds > 0 else 0.0

    def summary(self) -> dict:
        out = self.metrics.summary(self.utilization)
        out.update(policy=self.policy, events=self.events,
                   seconds=self.seconds, events_per_s=self.events_per_s)
        return out


class MetroEngine:
    """See module docstring. One engine instance runs one policy over one
    set of ward traces; `run()` may be called once."""

    def __init__(self, ward_traces: Sequence[Sequence[JobSpec]],
                 policy: Policy, *,
                 machines_per_tier: Mapping[str, int] | None = None,
                 failures: Sequence[FailureEvent] = (),
                 scale_events: Sequence[ScaleEvent] = (),
                 network_events: Sequence[NetworkEvent] = (),
                 slowdowns: Sequence[SlowdownEvent] = (),
                 hedge_factor: Optional[float] = None,
                 retry_backoff: float = 0.0,
                 max_attempts: Union[int, Mapping[str, int], None] = None,
                 metrics: MetroMetrics | None = None):
        mpt = dict(machines_per_tier or {CC: 1, ES: 1})
        self.jobs: List[List[JobSpec]] = [list(t) for t in ward_traces]
        self.B = len(self.jobs)
        if self.B == 0:
            raise ValueError("metro engine needs at least one ward")
        self.policy = policy
        self.cloud = _Pool(CC, mpt.get(CC, 1))
        self.edges = [_Pool(ES, mpt.get(ES, 1)) for _ in range(self.B)]
        self.commits: List[List[Optional[_Commit]]] = [
            [None] * len(t) for t in self.jobs]
        self.finished: List[List[bool]] = [
            [False] * len(t) for t in self.jobs]
        self.pending: List[List[int]] = [[] for _ in range(self.B)]
        # per-job dispatch-loss count (crash kills); attempts = kills + 1
        self.kills: List[List[int]] = [[0] * len(t) for t in self.jobs]
        # hedge state: at most ONE backup attempt per job, ever — the
        # flag persists after resolution so a job is never re-hedged
        self.hedged: List[List[bool]] = [
            [False] * len(t) for t in self.jobs]
        self.hedges: Dict[Tuple[int, int], _Commit] = {}
        # jobs whose backup was promoted to THE commitment by a crash on
        # the primary: their eventual completion still scores as a hedge
        # win (the backup is the machine on the final schedule)
        self.promoted: Set[Tuple[int, int]] = set()
        self._hedge_fn = getattr(policy, "hedge", None)
        if hedge_factor is not None:
            if not hedge_factor > 1.0:
                raise ValueError(f"hedge_factor must be > 1 (a watchdog "
                                 f"at <= 1x proc would fire on healthy "
                                 f"runs), got {hedge_factor}")
            if self._hedge_fn is None:
                raise ValueError(
                    f"hedge_factor set but policy "
                    f"{getattr(policy, 'name', '?')!r} has no hedge() "
                    f"hook; wrap it in HedgingPolicy")
        self.hedge_factor = hedge_factor
        if retry_backoff < 0:
            raise ValueError(f"retry_backoff must be >= 0, "
                             f"got {retry_backoff}")
        self.retry_backoff = retry_backoff
        if isinstance(max_attempts, int):
            if max_attempts < 1:
                raise ValueError(f"max_attempts must be >= 1, "
                                 f"got {max_attempts}")
        elif max_attempts is not None:
            max_attempts = dict(max_attempts)
            bad = {k: v for k, v in max_attempts.items() if v < 1}
            if bad:
                raise ValueError(f"per-class max_attempts must be >= 1, "
                                 f"got {bad}")
        self.max_attempts = max_attempts
        # active degraded-network factors per shared tier
        self._net: Dict[str, List[float]] = {}
        self.metrics = metrics or MetroMetrics()
        self.event_log: List[tuple] = []
        self._heap: List[tuple] = []
        self._seq = 0
        self._events = 0
        self._t_end = 0.0
        self._ran = False
        # read-only invariant observer, attached by run(sanitize=True)
        self._san = None
        # read-only flight recorder / self-profiler, attached by
        # run(trace=True) / run(profile=True) — both None when off, so
        # the off path costs one attribute test per observation
        self._tracer = None
        self._prof = None
        for b, trace in enumerate(self.jobs):
            for i, job in enumerate(trace):
                self._push(job.release, _P_ARRIVE, ("arrive", b, i))
        for ev in failures:
            self._pool(ev.tier, ev.ward)      # validate tier/ward early
            self._push(ev.time, _P_FAIL, ("fail", ev))
        for ev in scale_events:
            self._pool(ev.tier, ev.ward)
            self._push(ev.time, _P_SCALE, ("scale", ev))
        for ev in slowdowns:
            self._pool(ev.tier, ev.ward)      # validate tier/ward early
            if not 0.0 < ev.factor < 1.0:
                raise ValueError(f"fail-slow factor must be in (0, 1) — "
                                 f"1 is healthy, 0 is a failure — "
                                 f"got {ev}")
            if not ev.duration > 0:
                raise ValueError(f"slowdown needs duration > 0, got {ev}")
            self._push(ev.time, _P_SLOW, ("slow", ev))
        for ev in network_events:
            if ev.tier not in (CC, ES):
                raise ValueError(f"network events degrade a shared tier's "
                                 f"uplink, got {ev.tier!r}")
            if not (ev.factor > 0 and ev.duration > 0):
                raise ValueError(f"network event needs factor > 0 and "
                                 f"duration > 0, got {ev}")
            self._push(ev.time, _P_NET, ("net", ev, True))
            self._push(ev.time + ev.duration, _P_NET, ("net", ev, False))

    # ------------------------------------------------------------ plumbing
    def _push(self, t: float, prio: int, payload: tuple) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, prio, self._seq, payload))

    def _log(self, rec: tuple) -> None:
        """Append one event-log record and mirror it to the flight
        recorder. The tracer only ever READS the record — the log bytes
        (and hence the run's CRC) are identical traced or not."""
        self.event_log.append(rec)
        if self._tracer is not None:
            self._tracer.on_log(rec)

    def _pool(self, tier: str, ward: Optional[int]) -> _Pool:
        if tier == CC:
            if ward is not None:
                raise ValueError("the cloud pool is shared: ward must be "
                                 "None for cloud fleet events")
            return self.cloud
        if tier == ES:
            if ward is None or not 0 <= ward < self.B:
                raise ValueError(f"edge fleet events need a ward in "
                                 f"[0, {self.B}), got {ward}")
            return self.edges[ward]
        raise ValueError(f"no machine pool on tier {tier!r}")

    def _pool_entries(self, pool: _Pool) -> List[
            Tuple[int, int, _Commit, bool]]:
        """Every attempt occupying `pool`: primary commitments plus live
        hedge backups, as (ward, index, commit, is_hedge). A backup is a
        first-class pool occupant — it queues, delays successors, and
        can be crash-killed like any commitment."""
        if pool.tier == CC:
            wards: Sequence[int] = range(self.B)
        else:
            wards = [self.edges.index(pool)]
        out = [(b, i, c, False) for b in wards
               for i, c in enumerate(self.commits[b])
               if c is not None and c.machine == pool.tier]
        ws = set(wards)
        out.extend((b, i, h, True) for (b, i), h in self.hedges.items()
                   if h.machine == pool.tier and b in ws)
        return out

    def _slot_frees(self, pool: _Pool, now: float) -> List[float]:
        """Per-slot next-free times from STARTED commitments + outages —
        what a replan at `now` may not dispatch before."""
        free = [max(s.down, 0.0) for s in pool.slots]
        for _, _, c, _ in self._pool_entries(pool):
            if c.start <= now and c.end > free[c.slot]:
                free[c.slot] = c.end
        return free

    def _busy_view(self, pool: _Pool, now: float) -> List[float]:
        """`busy_until` entries for the search policies: occupied-machine
        free times strictly beyond `now` (idle machines are implicit,
        matching `online._busy_vectors` / `machine_free_times`)."""
        return [f for f in self._slot_frees(pool, now) if f > now]

    def _watchdog(self, b: int, i: int, c: _Commit, now: float) -> None:
        """Arm the hedge watchdog for a (re)timed primary commitment:
        fires at `start + hedge_factor x proc` (elapsed-runtime trigger)
        or immediately at `start` when the committed end already misses
        the deadline (negative-slack trigger). Never armed when it could
        not fire before the committed end — a healthy run on a healthy
        machine completes first, so the heap stays quiet. Validated
        lazily on pop like completion events."""
        if self.hedge_factor is None:
            return
        if self.hedged[b][i] or (b, i) in self.hedges:
            return
        job = c.job
        t_w = c.start + self.hedge_factor * job.proc[c.machine]
        if c.end > job.release + job.deadline:
            t_w = c.start
        t_w = max(t_w, now)
        if t_w < c.end:
            self._push(t_w, _P_HEDGE, ("hedge", b, i, c.machine, c.start))

    def _attempt_cap(self, job: JobSpec) -> Optional[int]:
        cap = self.max_attempts
        if isinstance(cap, dict):
            return cap.get(job.workload)
        return cap

    def _elapsed_work(self, b: int, c: _Commit, now: float) -> float:
        """Service units a partially-run attempt consumed in
        [c.start, now) on its slot — wall seconds off fail-slow windows,
        scaled by the active rate inside them."""
        if c.machine == ED or c.slot < 0:
            return max(0.0, now - c.start)
        pool = self.cloud if c.machine == CC else self.edges[b]
        return _work_done(pool.slots[c.slot].slowdowns, c.start, now)

    # ------------------------------------------------------------- replay
    def _replay_pool(self, pool: _Pool, now: float) -> None:
        """Re-dispatch every unstarted commitment of one pool FIFO by
        (arrival, plan time, ward, index) over the slot free times —
        `simulate`'s C5 semantics with machine identity. Started jobs are
        untouched (C2); re-timed jobs get fresh completion events."""
        if self._prof is not None:
            _r0 = time.perf_counter()          # reprolint: disable=R002
        free = self._slot_frees(pool, now)
        queue = []
        for b, i, c, is_hedge in self._pool_entries(pool):
            if c.start > now:
                queue.append((max(now, c.arrival), c.planned_at, b, i,
                              is_hedge))
        queue.sort()
        heap = list(zip(free, range(len(free))))
        heapq.heapify(heap)
        for arr, _, b, i, is_hedge in queue:
            c = self.hedges[(b, i)] if is_hedge else self.commits[b][i]
            avail, k = heapq.heappop(heap)
            start = arr if arr > avail else avail
            end = _finish_time(pool.slots[k].slowdowns, start,
                               c.job.proc[pool.tier])
            if end == _INF:                          # pragma: no cover
                raise ValueError(f"{pool.tier} pool has no dispatchable "
                                 f"machine for {c.job.name}")
            heapq.heappush(heap, (end, k))
            if (start, end, k) != (c.start, c.end, c.slot):
                c.start, c.end, c.slot = start, end, k
                kind = "hcomplete" if is_hedge else "complete"
                self._push(end, _P_COMPLETE, (kind, b, i, end))
                if not is_hedge:
                    self._watchdog(b, i, c, now)
        pool.reserved = sorted(f for f, _ in heap)
        if self._prof is not None:
            self._prof.replay += (
                time.perf_counter() - _r0)     # reprolint: disable=R002
        if self._san is not None:
            if self._prof is not None:
                _s0 = time.perf_counter()      # reprolint: disable=R002
                self._san.check_pool(pool, now)
                self._prof.sanitize += (
                    time.perf_counter() - _s0)  # reprolint: disable=R002
            else:
                self._san.check_pool(pool, now)

    def _replay(self, now: float, edge_wards: Sequence[int] | None = None,
                cloud: bool = True) -> None:
        """Replay the pools an event could have touched: the shared cloud
        (any decision can move jobs on/off it) plus the edge pools of the
        decided/affected wards — never the B-1 untouched edge pools."""
        if cloud:
            self._replay_pool(self.cloud, now)
        for b in (range(self.B) if edge_wards is None else edge_wards):
            self._replay_pool(self.edges[b], now)

    # ------------------------------------------------------------ replans
    def _net_factor(self, tier: str) -> float:
        f = 1.0
        for x in self._net.get(tier, ()):
            f *= x
        return f

    def _shift_spec(self, job: JobSpec, commit: Optional[_Commit],
                    now: float) -> JobSpec:
        """`online._replan_spec` view, with active degraded-network
        factors applied to any shared tier the job would RE-ship to.
        The committed tier's remaining transmission stays untouched:
        that data is already in flight under its committed arrival."""
        spec = online._replan_spec(job, commit, now)
        if not self._net:
            return spec
        keep = commit.machine if commit is not None \
            and commit.machine in (CC, ES) else None
        trans = dict(spec.trans)
        changed = False
        for t in (CC, ES):
            f = self._net_factor(t)
            if f != 1.0 and t != keep and trans.get(t, 0.0) > 0.0:
                trans[t] = trans[t] * f
                changed = True
        return replace(spec, trans=trans) if changed else spec

    def _decide(self, wards: Sequence[int], now: float,
                fresh: Mapping[int, Sequence[int]] = ()) -> None:
        fresh = dict(fresh or {})
        cloud_busy = self._busy_view(self.cloud, now)
        # every ward's unstarted cloud commitments, shifted to `now`:
        # ward b's replan sees the other wards' entries as frozen
        # background (queue-active, immovable — DESIGN.md §9)
        cloud_queue: List[Tuple[int, int, JobSpec]] = []
        for c in range(self.B):
            for j, cm in enumerate(self.commits[c]):
                if cm is not None and cm.machine == CC and cm.start > now:
                    cloud_queue.append(
                        (c, j, self._shift_spec(self.jobs[c][j], cm, now)))
        # live backup attempts queue on the cloud too; they are immovable
        # for EVERY ward (their owner included), hence index -1 so they
        # land in the owner's background as well
        for (c, j), hm in self.hedges.items():
            if hm.machine == CC and hm.start > now:
                cloud_queue.append(
                    (c, -1, self._shift_spec(self.jobs[c][j], hm, now)))
        requests: List[ReplanRequest] = []
        for b in wards:
            movable = [i for i in self.pending[b]
                       if not self.finished[b][i]
                       and (self.commits[b][i] is None
                            or self.commits[b][i].start > now)]
            self.pending[b] = movable
            if not movable:
                continue
            shifted = [self._shift_spec(self.jobs[b][i],
                                        self.commits[b][i], now)
                       for i in movable]
            new = set(fresh.get(b, ()))
            mov = set(movable)
            requests.append(ReplanRequest(
                ward=b, movable=movable, shifted=shifted,
                current=[None if self.commits[b][i] is None
                         else self.commits[b][i].machine for i in movable],
                fresh=[p for p, i in enumerate(movable) if i in new],
                busy={CC: list(cloud_busy),
                      ES: self._busy_view(self.edges[b], now)},
                reserved={CC: list(self.cloud.reserved),
                          ES: list(self.edges[b].reserved)},
                machines_per_tier={CC: len(self.cloud.slots),
                                   ES: len(self.edges[b].slots)},
                background=[spec for c, j, spec in cloud_queue
                            if c != b or j not in mov]))
        if requests:
            if self._prof is not None:
                _p0 = time.perf_counter()      # reprolint: disable=R002
                decisions = self.policy.decide(requests, now)
                self._prof.policy += (
                    time.perf_counter() - _p0)  # reprolint: disable=R002
                self._prof.decide_calls += 1
            else:
                decisions = self.policy.decide(requests, now)
            if len(decisions) != len(requests):
                raise ValueError(f"policy returned {len(decisions)} plans "
                                 f"for {len(requests)} wards")
            for req, tiers in zip(requests, decisions):
                if len(tiers) != len(req.movable):
                    raise ValueError(
                        f"ward {req.ward}: {len(tiers)} tiers for "
                        f"{len(req.movable)} movable jobs")
                bad = sorted(set(t for t in tiers if t not in _DECISIONS))
                if bad:
                    raise ValueError(
                        f"ward {req.ward}: policy returned unknown "
                        f"decisions {bad}; expected a tier in "
                        f"{sorted(_DECISIONS - {SHED})} or {SHED!r}")
                for pos, i in enumerate(req.movable):
                    if tiers[pos] == SHED:
                        self._shed(req.ward, i, now)
                    else:
                        self._commit(req.ward, i, req.shifted[pos],
                                     tiers[pos], now)
        self._replay(now, edge_wards=[req.ward for req in requests])

    def _shed(self, b: int, i: int, now: float) -> None:
        """Drop a movable job on a SHED decision: finished-missed with an
        explicit `shed` event, never dispatched (DESIGN.md §11)."""
        job = self.jobs[b][i]
        self.finished[b][i] = True
        self.commits[b][i] = None
        self.metrics.record_shed(now, job.workload, job.weight)
        self._log(("shed", now, b, i, job.name))
        if self._san is not None:
            self._san.on_terminal(b, i, "shed")

    def _commit(self, b: int, i: int, shifted: JobSpec, tier: str,
                now: float) -> None:
        job = self.jobs[b][i]
        arrival = now + shifted.trans.get(tier, 0.0)
        if self._tracer is not None:
            self._tracer.on_commit(now, b, i, tier, arrival)
        if tier == ED:
            # private device: no queue, times final at commitment (C4)
            end = arrival + job.proc[ED]
            old = self.commits[b][i]
            if old is None or (old.machine, old.end) != (ED, end):
                self._push(end, _P_COMPLETE, ("complete", b, i, end))
            self.commits[b][i] = _Commit(job, ED, arrival, arrival, end,
                                         slot=-1, planned_at=now)
            # device runs never stretch, so only the negative-slack
            # trigger can arm here (projected deadline miss at commit)
            self._watchdog(b, i, self.commits[b][i], now)
            return
        # shared tiers (decision already validated in _decide): the replay
        # assigns slot and times (start > now placeholder keeps it in the
        # unstarted set)
        self.commits[b][i] = _Commit(job, tier, arrival, _INF, _INF,
                                     slot=-1, planned_at=now)

    # ------------------------------------------------------------- events
    def _on_arrive(self, now: float, b: int, i: int) -> None:
        self.pending[b].append(i)
        self._log(("arrive", now, b, i, self.jobs[b][i].name))
        wards = range(self.B) if self.policy.joint else [b]
        self._decide(wards, now, fresh={b: [i]})

    def _on_complete(self, now: float, b: int, i: int, end: float) -> None:
        c = self.commits[b][i]
        if c is None or self.finished[b][i] or c.end != end or \
                c.start > now:
            return                                   # stale (re-timed) event
        self._finish(now, b, i, c, hedge_win=False)

    def _on_hcomplete(self, now: float, b: int, i: int,
                      end: float) -> None:
        """A backup attempt finished first: promote it to THE commitment
        (the final schedule shows the winner), cancel the losing primary
        at this instant, and score the completion as a hedge win."""
        h = self.hedges.get((b, i))
        if h is None or self.finished[b][i] or h.end != end or \
                h.start > now:
            return                                   # stale (re-timed) event
        loser = self.commits[b][i]
        del self.hedges[(b, i)]
        self.commits[b][i] = h
        if loser is not None:                        # pragma: no branch
            self._cancel(now, b, i, loser, role="primary")
        self._finish(now, b, i, h, hedge_win=True)

    def _finish(self, now: float, b: int, i: int, c: _Commit,
                hedge_win: bool) -> None:
        self.finished[b][i] = True
        other = self.hedges.pop((b, i), None)
        if other is not None:
            # primary won the race: cancel the backup deterministically
            # at the winner's completion instant
            self._cancel(now, b, i, other)
        job = c.job
        response = c.end - job.release
        self.metrics.record(now, job.workload, response, job.deadline,
                            c.machine, job.proc[c.machine],
                            attempts=self.kills[b][i] + 1,
                            weight=job.weight,
                            hedged=self.hedged[b][i],
                            hedge_win=hedge_win or
                            (b, i) in self.promoted)
        self._log(
            ("complete", now, b, i, c.machine, c.start, c.end, response,
             int(response > job.deadline), self.kills[b][i] + 1))
        if self._san is not None:
            self._san.on_terminal(b, i, "complete")
        if self._tracer is not None:
            self._tracer.on_finish(now, b, i, c, hedge_win)

    def _cancel(self, now: float, b: int, i: int, loser: _Commit,
                role: str = "backup") -> None:
        """Deterministic cancellation rule (DESIGN.md §13): the losing
        attempt is cut at the WINNER's completion instant — never
        earlier, never by wall clock — its consumed service units are
        recorded as hedge waste, and its pool is replayed so queued
        successors reclaim the freed machine-seconds immediately.
        `role` names which side of the race lost (tracing only)."""
        wasted = self._elapsed_work(b, loser, now) \
            if loser.start <= now else 0.0
        if self._tracer is not None:
            self._tracer.on_hedge_cancel(now, b, i, loser, wasted, role)
        self.metrics.record_hedge_cancel(loser.machine, wasted)
        self._log(
            ("hedge_cancel", now, b, i, loser.machine, wasted))
        if loser.machine != ED:
            self._replay(now, edge_wards=[b] if loser.machine == ES
                         else (), cloud=loser.machine == CC)

    def _strike(self, pool: _Pool, now: float,
                latest: bool = False) -> Optional[int]:
        """Non-retired machine a fleet event takes: the earliest-free one
        for drains/scale-downs, the LATEST-free (busiest) one for crash
        failures (`latest=True` — a crash that spared the idlest machine
        would rarely kill anything). None when the pool has none left."""
        cand = [(f, k) for k, (f, s) in enumerate(
            zip(self._slot_frees(pool, now), pool.slots))
            if s.retired_at is None]
        if not cand:
            return None
        return (max(cand) if latest else min(cand))[1]

    def _on_fail(self, now: float, ev: FailureEvent) -> None:
        pool = self._pool(ev.tier, ev.ward)
        k = self._strike(pool, now, latest=ev.kill_running)
        ward_key = -1 if ev.ward is None else ev.ward
        kill_flag = int(ev.kill_running)
        if k is None:                      # every machine already retired
            self._log(("fail", now, ev.tier, ward_key, -1,
                                   now, kill_flag))
            return
        slot = pool.slots[k]
        killed: List[Tuple[int, int, _Commit, bool]] = []
        if ev.kill_running:
            # crash: the machine dies NOW; its in-flight attempt is lost
            base = now
            killed = [(b, i, c, is_hedge)
                      for b, i, c, is_hedge in self._pool_entries(pool)
                      if not self.finished[b][i] and c.slot == k
                      and c.start <= now < c.end]
        else:
            # drain: the machine finishes its running job first
            base = max(self._slot_frees(pool, now)[k], now)
        down_until = base + ev.duration
        slot.down = max(slot.down, down_until)
        slot.outages.append((base, down_until))
        self._log(("fail", now, ev.tier, ward_key, k,
                               down_until, kill_flag))
        fresh: Dict[int, List[int]] = {}
        for b, i, c, is_hedge in killed:
            wasted = self._elapsed_work(b, c, now)
            if is_hedge:
                # the crash took the backup attempt: the primary still
                # runs, so this is a cancellation, not a job loss
                del self.hedges[(b, i)]
                if self._tracer is not None:
                    self._tracer.on_hedge_cancel(now, b, i, c, wasted,
                                                 "backup")
                self.metrics.record_hedge_cancel(ev.tier, wasted)
                self._log(
                    ("hedge_cancel", now, b, i, ev.tier, wasted))
                continue
            self.kills[b][i] += 1
            self.metrics.record_kill(ev.tier, wasted)
            self._log(("kill", now, b, i, ev.tier, k, wasted,
                                   self.kills[b][i]))
            if self._tracer is not None:
                self._tracer.on_kill(now, b, i, c, wasted)
            backup = self.hedges.pop((b, i), None)
            if backup is not None:
                # the backup attempt survives the crash: promote it to
                # THE commitment — no re-decision, the race is resolved
                self.commits[b][i] = backup
                if backup.end < _INF:        # pragma: no branch
                    self._push(backup.end, _P_COMPLETE,
                               ("complete", b, i, backup.end))
                self._log(
                    ("hedge_promote", now, b, i, backup.machine))
                self.promoted.add((b, i))
                continue
            self.commits[b][i] = None
            cap = self._attempt_cap(c.job)
            if cap is not None and self.kills[b][i] + 1 > cap:
                # retries exhausted: shed-with-record, never another
                # dispatch (bounds crash-wave retry storms)
                self.finished[b][i] = True
                self.metrics.record_shed(now, c.job.workload,
                                         c.job.weight, exhausted=True)
                self._log(("giveup", now, b, i, c.job.name,
                                       self.kills[b][i]))
                if self._san is not None:
                    self._san.on_terminal(b, i, "giveup")
                continue
            if self.retry_backoff > 0.0:
                # exponential backoff: attempt n re-decides after
                # backoff * 2^(n-2), not in the crash instant
                delay = self.retry_backoff * (2.0 ** (self.kills[b][i]
                                                      - 1))
                self._push(now + delay, _P_ARRIVE, ("retry", b, i))
                continue
            if i not in self.pending[b]:
                self.pending[b].append(i)
            fresh.setdefault(b, []).append(i)
        self._push(down_until, _P_RECOVER, ("recover", ev.tier, ev.ward))
        self._after_fleet_event(ev.tier, ev.ward, now, fresh=fresh)

    def _on_retry(self, now: float, b: int, i: int) -> None:
        """A backed-off crash retry matures: the job re-enters the
        normal decision path as a fresh arrival."""
        if self.finished[b][i] or self.commits[b][i] is not None:
            return                               # pragma: no cover (safety)
        self._log(("retry", now, b, i, self.kills[b][i] + 1))
        if i not in self.pending[b]:
            self.pending[b].append(i)
        wards = range(self.B) if self.policy.joint else [b]
        self._decide(wards, now, fresh={b: [i]})

    def _on_slow(self, now: float, ev: SlowdownEvent) -> None:
        """A fail-slow window opens on the busiest machine: record the
        window, stretch the in-flight attempt's completion through the
        new rate profile (placement stays, C2), re-arm its watchdog, and
        replay so queued successors inherit the delay."""
        pool = self._pool(ev.tier, ev.ward)
        k = self._strike(pool, now, latest=True)
        ward_key = -1 if ev.ward is None else ev.ward
        until = now + ev.duration
        if k is None:                      # every machine already retired
            self._log(("slow", now, ev.tier, ward_key, -1,
                                   until, ev.factor))
            return
        slot = pool.slots[k]
        slot.slowdowns.append((now, until, ev.factor))
        self._log(("slow", now, ev.tier, ward_key, k, until,
                               ev.factor))
        for b, i, c, is_hedge in self._pool_entries(pool):
            if self.finished[b][i] or c.slot != k or \
                    not c.start <= now < c.end:
                continue
            end = _finish_time(slot.slowdowns, c.start,
                               c.job.proc[pool.tier])
            if end != c.end:
                c.end = end
                kind = "hcomplete" if is_hedge else "complete"
                self._push(end, _P_COMPLETE, (kind, b, i, end))
                if not is_hedge:
                    self._watchdog(b, i, c, now)
        self._push(until, _P_SLOWEND, ("slowend", ev.tier, ev.ward))
        self._after_fleet_event(ev.tier, ev.ward, now)

    def _on_slowend(self, now: float, tier: str,
                    ward: Optional[int]) -> None:
        """A fail-slow window closes. Timing needs no update — every
        commitment's end already prices the full window — but replanning
        policies get the same revisit hook a recovery grants."""
        self._log(("slowend", now, tier,
                               -1 if ward is None else ward))
        self._after_fleet_event(tier, ward, now)

    def _on_hedge(self, now: float, b: int, i: int, machine: str,
                  start: float) -> None:
        """The watchdog fired for a still-running primary: ask the
        policy's hedge() hook for a backup tier and, if granted,
        dispatch the backup attempt through the normal pool machinery.
        First completion wins; the loser is cancelled at that instant."""
        if self.finished[b][i] or self.hedged[b][i] or \
                (b, i) in self.hedges:
            return
        c = self.commits[b][i]
        if c is None or (c.machine, c.start) != (machine, start) or \
                not c.start <= now < c.end:
            return                               # stale watchdog
        job = c.job
        spec = self._shift_spec(job, None, now)
        req = HedgeRequest(
            ward=b, job=spec, tier=c.machine, projected_end=c.end,
            busy={CC: self._busy_view(self.cloud, now),
                  ES: self._busy_view(self.edges[b], now)},
            reserved={CC: list(self.cloud.reserved),
                      ES: list(self.edges[b].reserved)},
            machines_per_tier={CC: len(self.cloud.slots),
                               ES: len(self.edges[b].slots)})
        if self._prof is not None:
            _h0 = time.perf_counter()          # reprolint: disable=R002
            t = self._hedge_fn(req, now)
            self._prof.hedge_hook += (
                time.perf_counter() - _h0)     # reprolint: disable=R002
        else:
            t = self._hedge_fn(req, now)
        if t is None:
            return
        if t not in _DECISIONS - {SHED} or t == c.machine:
            raise ValueError(
                f"hedge policy returned {t!r}; expected a tier in "
                f"{sorted(_DECISIONS - {SHED})} other than the committed "
                f"{c.machine!r}, or None")
        self.hedged[b][i] = True
        self.metrics.record_hedge(t)
        self._log(("hedge", now, b, i, c.machine, t))
        if self._san is not None:
            self._san.on_hedge(b, i)
        arrival = now + spec.trans.get(t, 0.0)
        if t == ED:
            end = arrival + job.proc[ED]
            self.hedges[(b, i)] = _Commit(job, ED, arrival, arrival, end,
                                          slot=-1, planned_at=now)
            self._push(end, _P_COMPLETE, ("hcomplete", b, i, end))
        else:
            self.hedges[(b, i)] = _Commit(job, t, arrival, _INF, _INF,
                                          slot=-1, planned_at=now)
            self._replay(now, edge_wards=[b] if t == ES else (),
                         cloud=t == CC)
        if self._tracer is not None:
            self._tracer.on_hedge_dispatch(now, b, i, self.hedges[(b, i)])

    def _on_recover(self, now: float, tier: str,
                    ward: Optional[int]) -> None:
        self._log(("recover", now, tier,
                               -1 if ward is None else ward))
        self._after_fleet_event(tier, ward, now)

    def _on_scale(self, now: float, ev: ScaleEvent) -> None:
        pool = self._pool(ev.tier, ev.ward)
        if ev.delta == 0:
            raise ValueError("scale event with delta 0")
        if ev.delta > 0:
            for _ in range(ev.delta):
                pool.slots.append(_Slot(created=now))
        else:
            active = sum(1 for s in pool.slots if s.retired_at is None)
            if active + ev.delta < 1:
                raise ValueError(f"scale-down to {active + ev.delta} "
                                 f"machines on {ev.tier} at t={now}; a "
                                 f"pool keeps >= 1")
            for _ in range(-ev.delta):
                k = self._strike(pool, now)
                slot = pool.slots[k]
                slot.retired_at = max(self._slot_frees(pool, now)[k], now)
                slot.down = _INF
        self._log(("scale", now, ev.tier,
                               -1 if ev.ward is None else ev.ward,
                               ev.delta))
        self._after_fleet_event(ev.tier, ev.ward, now)

    def _after_fleet_event(self, tier: str, ward: Optional[int],
                           now: float,
                           fresh: Mapping[int, Sequence[int]] | None = None
                           ) -> None:
        """Capacity changed: replanning policies revisit every affected
        ward (all of them for the shared cloud — the matching-event-count
        batched replan); commit-and-hold policies just re-time. Crash
        kills pass `fresh` — those jobs lost their commitment and MUST be
        re-decided (through the normal decision path, as fresh arrivals)
        even by commit-and-hold policies. The replay runs first so the
        reserved views price the post-event fleet; started-occupancy busy
        views are replay-invariant, preserving the B=1 tabu parity."""
        if tier == CC:
            self._replay(now, edge_wards=())
        else:
            self._replay(now, edge_wards=[ward], cloud=False)
        fresh = dict(fresh or {})
        if self.policy.replans_on_fleet_events:
            affected = list(range(self.B)) \
                if tier == CC or self.policy.joint else [ward]
            self._decide(affected, now, fresh=fresh)
        elif fresh:
            self._decide(sorted(fresh), now, fresh=fresh)

    def _on_net(self, now: float, ev: NetworkEvent, on: bool) -> None:
        """A degraded-network window opens/closes: update the active
        factor set, log, and let replanning policies re-price movable
        jobs under the new uplink (commitments keep their arrivals —
        nothing already shipped is re-timed)."""
        factors = self._net.setdefault(ev.tier, [])
        if on:
            factors.append(ev.factor)
        else:
            factors.remove(ev.factor)
            if not factors:
                del self._net[ev.tier]
        self._log(("net", now, ev.tier, ev.factor, int(on)))
        if self.policy.replans_on_fleet_events:
            self._decide(range(self.B), now)

    # ---------------------------------------------------------------- run
    def run(self, sanitize: bool = False, trace: bool = False,
            profile: bool = False) -> MetroResult:
        """Drain the event heap. ``sanitize=True`` attaches the
        read-only `MetroSanitizer` (DESIGN.md §14): every replay,
        terminal event and hedge dispatch is validated against the
        engine invariants I1–I7 and a `SanitizerViolation` is raised on
        the first breach. ``trace=True`` attaches the flight recorder
        (`MetroTracer`, DESIGN.md §15): per-job spans and deadline-miss
        attribution land on ``MetroResult.trace``. ``profile=True`` arms
        the self-profiler: wall-clock phase timers (replay / policy /
        sanitizer / hedge hook / per-event-kind handlers) plus the
        compiled-shape cache delta land on ``MetroResult.profile``.
        All three observers are read-only — they never mutate state,
        push events or touch the event log, so armed runs hash
        bit-identically to bare ones."""
        if self._ran:
            raise ValueError("a MetroEngine instance runs once; build a "
                             "fresh one per policy")
        self._ran = True
        if sanitize:
            from repro.metro.sanitizer import MetroSanitizer
            self._san = MetroSanitizer(self)
        if trace:
            from repro.metro.tracing import MetroTracer
            self._tracer = MetroTracer(self)
        if profile:
            from repro.core.scheduler import compiled_shape_stats
            from repro.metro.tracing import EngineProfile
            self._prof = EngineProfile(
                shapes_before=compiled_shape_stats())
        prof = self._prof
        # bench-timing block: measures wall-clock THROUGHPUT of the run;
        # simulated time lives only in the event heap
        t0 = time.perf_counter()        # reprolint: disable=R002
        while self._heap:
            t, prio, _, payload = heapq.heappop(self._heap)
            if self._san is not None:
                self._san.on_event(t, payload)
            self._t_end = max(self._t_end, t)
            self._events += 1
            kind = payload[0]
            if prof is not None:
                _h0 = time.perf_counter()      # reprolint: disable=R002
            if kind == "complete":
                self._on_complete(t, *payload[1:])
            elif kind == "hcomplete":
                self._on_hcomplete(t, *payload[1:])
            elif kind == "arrive":
                self._on_arrive(t, *payload[1:])
            elif kind == "retry":
                self._on_retry(t, *payload[1:])
            elif kind == "fail":
                self._on_fail(t, payload[1])
            elif kind == "slow":
                self._on_slow(t, payload[1])
            elif kind == "slowend":
                self._on_slowend(t, *payload[1:])
            elif kind == "scale":
                self._on_scale(t, payload[1])
            elif kind == "net":
                self._on_net(t, *payload[1:])
            elif kind == "hedge":
                self._on_hedge(t, *payload[1:])
            else:
                self._on_recover(t, *payload[1:])
            if prof is not None:
                prof.add_handler(
                    kind,
                    time.perf_counter() - _h0)  # reprolint: disable=R002
        seconds = time.perf_counter() - t0   # reprolint: disable=R002

        if self._san is not None:
            self._san.at_exit(self._t_end)
        # close the in-progress metrics window so short runs report a
        # populated windowed snapshot (the §10 flush fix)
        self.metrics.flush()
        for b, flags in enumerate(self.finished):
            missing = [i for i, ok in enumerate(flags) if not ok]
            if missing:
                raise ValueError(f"ward {b}: jobs neither completed nor "
                                 f"shed: {missing[:5]} (event bug)")
        wards = []
        for b in range(self.B):
            # shed jobs have no commitment — the schedule holds only the
            # jobs that actually ran
            entries = [ScheduledJob(c.job, c.machine, c.arrival, c.start,
                                    c.end) for c in self.commits[b]
                       if c is not None]
            wards.append(Schedule(
                entries=entries,
                weighted_sum=sum(e.job.weight * e.response
                                 for e in entries),
                unweighted_sum=sum(e.response for e in entries),
                last_end=max((e.end for e in entries), default=0.0)))
        trace_obj = None
        if self._tracer is not None:
            trace_obj = self._tracer.finish()
        prof_out = None
        if prof is not None:
            from repro.core.scheduler import compiled_shape_stats
            prof.heap_pushes = self._seq
            prof_out = prof.summary(seconds, self._events,
                                    shapes_after=compiled_shape_stats())
        return MetroResult(policy=getattr(self.policy, "name", "?"),
                           wards=wards, metrics=self.metrics,
                           utilization=self._utilization(),
                           event_log=self.event_log, events=self._events,
                           seconds=seconds, trace=trace_obj,
                           profile=prof_out)

    def _utilization(self) -> Dict[str, float]:
        t_end = self._t_end
        busy = self.metrics.busy_time
        cloud_cap = self.cloud.capacity_integral(t_end)
        edge_cap = sum(p.capacity_integral(t_end) for p in self.edges)
        out = {}
        if cloud_cap > 0:
            out["cloud"] = busy.get(CC, 0.0) / cloud_cap
        if edge_cap > 0:
            out["edge"] = busy.get(ES, 0.0) / edge_cap
        if t_end > 0:
            # devices are private/unbounded: report mean concurrency
            out["device_concurrency"] = busy.get(ED, 0.0) / t_end
        return out


def simulate_metro(ward_traces: Sequence[Sequence[JobSpec]],
                   policy: Policy, *,
                   machines_per_tier: Mapping[str, int] | None = None,
                   failures: Sequence[FailureEvent] = (),
                   scale_events: Sequence[ScaleEvent] = (),
                   network_events: Sequence[NetworkEvent] = (),
                   slowdowns: Sequence[SlowdownEvent] = (),
                   hedge_factor: Optional[float] = None,
                   retry_backoff: float = 0.0,
                   max_attempts: Union[int, Mapping[str, int],
                                       None] = None,
                   metrics: MetroMetrics | None = None,
                   sanitize: bool = False,
                   trace: bool = False,
                   profile: bool = False) -> MetroResult:
    """Build-and-run convenience wrapper (one engine per policy run)."""
    return MetroEngine(ward_traces, policy,
                       machines_per_tier=machines_per_tier,
                       failures=failures, scale_events=scale_events,
                       network_events=network_events,
                       slowdowns=slowdowns, hedge_factor=hedge_factor,
                       retry_backoff=retry_backoff,
                       max_attempts=max_attempts,
                       metrics=metrics).run(sanitize=sanitize,
                                            trace=trace, profile=profile)
