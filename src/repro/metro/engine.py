"""Discrete-event metro traffic engine (DESIGN.md §10).

Event loop over job arrivals, completions, machine failures/recoveries
and elastic scale events for B hospital wards sharing one metropolitan
cloud pool (per-ward edge pools, private devices — the §9 fleet model,
now under streaming load instead of a finite scored-once job list).

Ground truth lives HERE, not in the policy: machines are explicit slots
with identity (so a failure can strike a specific machine and elastic
scale-down can retire one), and after every decision the engine replays
each pool's unstarted commitments through the same FIFO-by-arrival
dispatch `simulate` defines (C1–C5). Policies only pick tiers; the
replay prices their choices on the real fleet — a ward-local plan that
double-books the shared cloud gets delayed by the merged queue, exactly
as in `simulate_fleet`.

Commitment semantics follow `online_schedule` (DESIGN.md §7): a job
whose machine slot has begun (start <= now) is immutable (C2); every
other commitment may be re-tiered by the policy and is re-timed by the
replay. A *drain* failure (the default) never drops a running job — the
machine finishes it, then goes down for the repair duration, delaying
its queue successors. A *crash* failure (`kill_running=True`) kills the
struck machine's in-flight job: its commitment is invalidated, the
partial run's machine-seconds are recorded as wasted, and the job
returns to the pending set to be re-dispatched through the normal
decision path (retries count as fresh arrivals, so search policies may
fail it over to another tier). Policies may also return the SHED
sentinel for a movable job — the engine drops it with a ``shed`` event
and scores it as an explicit deadline miss (DESIGN.md §11). With B = 1
wards, no failures and the tabu policy, the engine's event sequence IS
`online_schedule(replan="tabu")` and the committed schedules match
bit-for-bit (tests/test_metro.py).

Degraded-network windows (`NetworkEvent`) multiply a shared tier's
transmission times while active: every decision made inside the window
prices the degraded uplink (the §7 shifted specs carry scaled
transmission for any tier the job would re-ship to), while data already
in flight toward a committed tier keeps its committed arrival.

Completion events are scheduled from commitment end times and validated
lazily on pop (a replan that re-times a commitment simply strands the
stale event), the standard DES invalidation scheme — so the event log is
a deterministic function of (traces, fleet events, policy) and of the
`scheduler.search` dispatch state: search-based policies inherit the
§3.3 compiled-shape cache, so a process that force-compiled a shape
before the run may legitimately commit a different (equally exact)
local optimum than a fresh process. Pin `jax_threshold` on the policy
for call-order-independent runs; the committed benchmarks run in a
fresh process with a fixed section order.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core import online
from repro.core.simulator import JobSpec, Schedule, ScheduledJob
from repro.core.tiers import CC, ED, ES
from repro.metro.metrics import MetroMetrics
from repro.metro.policies import SHED, Policy, ReplanRequest

_INF = float("inf")
# same-instant ordering: completions first (a machine freeing at t is
# visible to a replan at t), then fleet/network events, then arrivals
(_P_COMPLETE, _P_FAIL, _P_SCALE, _P_RECOVER, _P_NET,
 _P_ARRIVE) = 0, 1, 2, 3, 4, 5
# decisions a policy may return per movable job (validated centrally
# in _decide — not ad hoc per commit branch)
_DECISIONS = frozenset((CC, ES, ED, SHED))


@dataclass(frozen=True)
class FailureEvent:
    """A machine in `tier`'s pool (ward-local for edge, fleet-wide for
    cloud) breaks at `time` for `duration`.

    Drain mode (default): the earliest-free machine is struck, finishes
    any running job, then stays down until repaired — nothing is lost.

    Crash mode (``kill_running=True``): the BUSIEST (latest-free)
    machine is struck and dies immediately; its in-flight job is LOST —
    the partial run is wasted machine-seconds, the commitment is
    invalidated and the job re-dispatches through the normal decision
    path (DESIGN.md §11)."""
    time: float
    tier: str = CC
    ward: Optional[int] = None           # None = the shared cloud pool
    duration: float = 10.0
    kill_running: bool = False


@dataclass(frozen=True)
class NetworkEvent:
    """Degraded-network window: transmission times toward `tier` are
    multiplied by `factor` during [time, time + duration). Overlapping
    windows compound. Decisions made inside the window price the
    degraded uplink; data already shipped toward a committed tier keeps
    its committed arrival (the in-flight contract, DESIGN.md §11)."""
    time: float
    duration: float = 30.0
    tier: str = CC
    factor: float = 4.0


@dataclass(frozen=True)
class ScaleEvent:
    """Elastic capacity: delta > 0 adds machines to the pool at `time`;
    delta < 0 retires the earliest-free ones (each finishes its running
    job, then leaves the pool for good)."""
    time: float
    tier: str = CC
    ward: Optional[int] = None
    delta: int = 1


@dataclass
class _Commit:
    """One job's current commitment. Attribute names match
    `online._Commit` so `online._replan_spec` builds the replan view."""
    job: JobSpec
    machine: str
    arrival: float
    start: float
    end: float
    slot: int = -1
    planned_at: float = 0.0


class _Slot:
    """One machine with identity: when it joined the pool, until when it
    is down (inf = retired), and its recorded outage intervals (exact
    utilisation accounting)."""
    __slots__ = ("created", "down", "outages", "retired_at")

    def __init__(self, created: float = 0.0):
        self.created = created
        self.down = created          # not dispatchable before it exists
        self.outages: List[Tuple[float, float]] = []
        self.retired_at: Optional[float] = None


class _Pool:
    def __init__(self, tier: str, machines: int):
        if machines < 1:
            raise ValueError(f"{tier} pool needs >= 1 machine")
        self.tier = tier
        self.slots = [_Slot() for _ in range(machines)]
        # per-machine free times with every queued commitment dispatched —
        # the greedy policy's reserved view; refreshed by each replay
        self.reserved: List[float] = [0.0] * machines

    def capacity_integral(self, t_end: float) -> float:
        """Machine-seconds the pool could have run in [0, t_end]. Outage
        intervals may overlap (a crash can strike an already-down
        machine), so they are union-merged before subtracting."""
        total = 0.0
        for s in self.slots:
            hi = min(s.retired_at if s.retired_at is not None else t_end,
                     t_end)
            span = max(0.0, hi - s.created)
            clipped = sorted(
                (max(d0, s.created), min(d1, hi))
                for d0, d1 in s.outages if min(d1, hi) > max(d0, s.created))
            m0 = m1 = None
            for d0, d1 in clipped:
                if m1 is None or d0 > m1:
                    if m1 is not None:
                        span -= m1 - m0
                    m0, m1 = d0, d1
                elif d1 > m1:
                    m1 = d1
            if m1 is not None:
                span -= m1 - m0
            total += max(0.0, span)
        return total


@dataclass
class MetroResult:
    """One policy's run: verbatim committed schedules per ward, streaming
    metrics, exact per-tier utilisation, the deterministic event log, and
    the wall-clock throughput of the run."""
    policy: str
    wards: List[Schedule]
    metrics: MetroMetrics
    utilization: Dict[str, float]
    event_log: List[tuple]
    events: int
    seconds: float

    @property
    def events_per_s(self) -> float:
        return self.events / self.seconds if self.seconds > 0 else 0.0

    def summary(self) -> dict:
        out = self.metrics.summary(self.utilization)
        out.update(policy=self.policy, events=self.events,
                   seconds=self.seconds, events_per_s=self.events_per_s)
        return out


class MetroEngine:
    """See module docstring. One engine instance runs one policy over one
    set of ward traces; `run()` may be called once."""

    def __init__(self, ward_traces: Sequence[Sequence[JobSpec]],
                 policy: Policy, *,
                 machines_per_tier: Mapping[str, int] | None = None,
                 failures: Sequence[FailureEvent] = (),
                 scale_events: Sequence[ScaleEvent] = (),
                 network_events: Sequence[NetworkEvent] = (),
                 metrics: MetroMetrics | None = None):
        mpt = dict(machines_per_tier or {CC: 1, ES: 1})
        self.jobs: List[List[JobSpec]] = [list(t) for t in ward_traces]
        self.B = len(self.jobs)
        if self.B == 0:
            raise ValueError("metro engine needs at least one ward")
        self.policy = policy
        self.cloud = _Pool(CC, mpt.get(CC, 1))
        self.edges = [_Pool(ES, mpt.get(ES, 1)) for _ in range(self.B)]
        self.commits: List[List[Optional[_Commit]]] = [
            [None] * len(t) for t in self.jobs]
        self.finished: List[List[bool]] = [
            [False] * len(t) for t in self.jobs]
        self.pending: List[List[int]] = [[] for _ in range(self.B)]
        # per-job dispatch-loss count (crash kills); attempts = kills + 1
        self.kills: List[List[int]] = [[0] * len(t) for t in self.jobs]
        # active degraded-network factors per shared tier
        self._net: Dict[str, List[float]] = {}
        self.metrics = metrics or MetroMetrics()
        self.event_log: List[tuple] = []
        self._heap: List[tuple] = []
        self._seq = 0
        self._events = 0
        self._t_end = 0.0
        self._ran = False
        for b, trace in enumerate(self.jobs):
            for i, job in enumerate(trace):
                self._push(job.release, _P_ARRIVE, ("arrive", b, i))
        for ev in failures:
            self._pool(ev.tier, ev.ward)      # validate tier/ward early
            self._push(ev.time, _P_FAIL, ("fail", ev))
        for ev in scale_events:
            self._pool(ev.tier, ev.ward)
            self._push(ev.time, _P_SCALE, ("scale", ev))
        for ev in network_events:
            if ev.tier not in (CC, ES):
                raise ValueError(f"network events degrade a shared tier's "
                                 f"uplink, got {ev.tier!r}")
            if not (ev.factor > 0 and ev.duration > 0):
                raise ValueError(f"network event needs factor > 0 and "
                                 f"duration > 0, got {ev}")
            self._push(ev.time, _P_NET, ("net", ev, True))
            self._push(ev.time + ev.duration, _P_NET, ("net", ev, False))

    # ------------------------------------------------------------ plumbing
    def _push(self, t: float, prio: int, payload: tuple) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, prio, self._seq, payload))

    def _pool(self, tier: str, ward: Optional[int]) -> _Pool:
        if tier == CC:
            if ward is not None:
                raise ValueError("the cloud pool is shared: ward must be "
                                 "None for cloud fleet events")
            return self.cloud
        if tier == ES:
            if ward is None or not 0 <= ward < self.B:
                raise ValueError(f"edge fleet events need a ward in "
                                 f"[0, {self.B}), got {ward}")
            return self.edges[ward]
        raise ValueError(f"no machine pool on tier {tier!r}")

    def _pool_members(self, pool: _Pool) -> List[Tuple[int, int]]:
        if pool.tier == CC:
            wards: Sequence[int] = range(self.B)
        else:
            wards = [self.edges.index(pool)]
        return [(b, i) for b in wards
                for i, c in enumerate(self.commits[b])
                if c is not None and c.machine == pool.tier]

    def _slot_frees(self, pool: _Pool, now: float) -> List[float]:
        """Per-slot next-free times from STARTED commitments + outages —
        what a replan at `now` may not dispatch before."""
        free = [max(s.down, 0.0) for s in pool.slots]
        for b, i in self._pool_members(pool):
            c = self.commits[b][i]
            if c.start <= now and c.end > free[c.slot]:
                free[c.slot] = c.end
        return free

    def _busy_view(self, pool: _Pool, now: float) -> List[float]:
        """`busy_until` entries for the search policies: occupied-machine
        free times strictly beyond `now` (idle machines are implicit,
        matching `online._busy_vectors` / `machine_free_times`)."""
        return [f for f in self._slot_frees(pool, now) if f > now]

    # ------------------------------------------------------------- replay
    def _replay_pool(self, pool: _Pool, now: float) -> None:
        """Re-dispatch every unstarted commitment of one pool FIFO by
        (arrival, plan time, ward, index) over the slot free times —
        `simulate`'s C5 semantics with machine identity. Started jobs are
        untouched (C2); re-timed jobs get fresh completion events."""
        free = self._slot_frees(pool, now)
        queue = []
        for b, i in self._pool_members(pool):
            c = self.commits[b][i]
            if c.start > now:
                queue.append((max(now, c.arrival), c.planned_at, b, i))
        queue.sort()
        heap = list(zip(free, range(len(free))))
        heapq.heapify(heap)
        for arr, _, b, i in queue:
            c = self.commits[b][i]
            avail, k = heapq.heappop(heap)
            start = arr if arr > avail else avail
            end = start + c.job.proc[pool.tier]
            if end == _INF:                          # pragma: no cover
                raise ValueError(f"{pool.tier} pool has no dispatchable "
                                 f"machine for {c.job.name}")
            heapq.heappush(heap, (end, k))
            if (start, end, k) != (c.start, c.end, c.slot):
                c.start, c.end, c.slot = start, end, k
                self._push(end, _P_COMPLETE, ("complete", b, i, end))
        pool.reserved = sorted(f for f, _ in heap)

    def _replay(self, now: float, edge_wards: Sequence[int] | None = None,
                cloud: bool = True) -> None:
        """Replay the pools an event could have touched: the shared cloud
        (any decision can move jobs on/off it) plus the edge pools of the
        decided/affected wards — never the B-1 untouched edge pools."""
        if cloud:
            self._replay_pool(self.cloud, now)
        for b in (range(self.B) if edge_wards is None else edge_wards):
            self._replay_pool(self.edges[b], now)

    # ------------------------------------------------------------ replans
    def _net_factor(self, tier: str) -> float:
        f = 1.0
        for x in self._net.get(tier, ()):
            f *= x
        return f

    def _shift_spec(self, job: JobSpec, commit: Optional[_Commit],
                    now: float) -> JobSpec:
        """`online._replan_spec` view, with active degraded-network
        factors applied to any shared tier the job would RE-ship to.
        The committed tier's remaining transmission stays untouched:
        that data is already in flight under its committed arrival."""
        spec = online._replan_spec(job, commit, now)
        if not self._net:
            return spec
        keep = commit.machine if commit is not None \
            and commit.machine in (CC, ES) else None
        trans = dict(spec.trans)
        changed = False
        for t in (CC, ES):
            f = self._net_factor(t)
            if f != 1.0 and t != keep and trans.get(t, 0.0) > 0.0:
                trans[t] = trans[t] * f
                changed = True
        return replace(spec, trans=trans) if changed else spec

    def _decide(self, wards: Sequence[int], now: float,
                fresh: Mapping[int, Sequence[int]] = ()) -> None:
        fresh = dict(fresh or {})
        cloud_busy = self._busy_view(self.cloud, now)
        # every ward's unstarted cloud commitments, shifted to `now`:
        # ward b's replan sees the other wards' entries as frozen
        # background (queue-active, immovable — DESIGN.md §9)
        cloud_queue: List[Tuple[int, JobSpec]] = []
        for c in range(self.B):
            for j, cm in enumerate(self.commits[c]):
                if cm is not None and cm.machine == CC and cm.start > now:
                    cloud_queue.append(
                        (c, self._shift_spec(self.jobs[c][j], cm, now)))
        requests: List[ReplanRequest] = []
        for b in wards:
            movable = [i for i in self.pending[b]
                       if not self.finished[b][i]
                       and (self.commits[b][i] is None
                            or self.commits[b][i].start > now)]
            self.pending[b] = movable
            if not movable:
                continue
            shifted = [self._shift_spec(self.jobs[b][i],
                                        self.commits[b][i], now)
                       for i in movable]
            new = set(fresh.get(b, ()))
            requests.append(ReplanRequest(
                ward=b, movable=movable, shifted=shifted,
                current=[None if self.commits[b][i] is None
                         else self.commits[b][i].machine for i in movable],
                fresh=[p for p, i in enumerate(movable) if i in new],
                busy={CC: list(cloud_busy),
                      ES: self._busy_view(self.edges[b], now)},
                reserved={CC: list(self.cloud.reserved),
                          ES: list(self.edges[b].reserved)},
                machines_per_tier={CC: len(self.cloud.slots),
                                   ES: len(self.edges[b].slots)},
                background=[spec for c, spec in cloud_queue if c != b]))
        if requests:
            decisions = self.policy.decide(requests, now)
            if len(decisions) != len(requests):
                raise ValueError(f"policy returned {len(decisions)} plans "
                                 f"for {len(requests)} wards")
            for req, tiers in zip(requests, decisions):
                if len(tiers) != len(req.movable):
                    raise ValueError(
                        f"ward {req.ward}: {len(tiers)} tiers for "
                        f"{len(req.movable)} movable jobs")
                bad = sorted(set(t for t in tiers if t not in _DECISIONS))
                if bad:
                    raise ValueError(
                        f"ward {req.ward}: policy returned unknown "
                        f"decisions {bad}; expected a tier in "
                        f"{sorted(_DECISIONS - {SHED})} or {SHED!r}")
                for pos, i in enumerate(req.movable):
                    if tiers[pos] == SHED:
                        self._shed(req.ward, i, now)
                    else:
                        self._commit(req.ward, i, req.shifted[pos],
                                     tiers[pos], now)
        self._replay(now, edge_wards=[req.ward for req in requests])

    def _shed(self, b: int, i: int, now: float) -> None:
        """Drop a movable job on a SHED decision: finished-missed with an
        explicit `shed` event, never dispatched (DESIGN.md §11)."""
        job = self.jobs[b][i]
        self.finished[b][i] = True
        self.commits[b][i] = None
        self.metrics.record_shed(now, job.workload, job.weight)
        self.event_log.append(("shed", now, b, i, job.name))

    def _commit(self, b: int, i: int, shifted: JobSpec, tier: str,
                now: float) -> None:
        job = self.jobs[b][i]
        arrival = now + shifted.trans.get(tier, 0.0)
        if tier == ED:
            # private device: no queue, times final at commitment (C4)
            end = arrival + job.proc[ED]
            old = self.commits[b][i]
            if old is None or (old.machine, old.end) != (ED, end):
                self._push(end, _P_COMPLETE, ("complete", b, i, end))
            self.commits[b][i] = _Commit(job, ED, arrival, arrival, end,
                                         slot=-1, planned_at=now)
            return
        # shared tiers (decision already validated in _decide): the replay
        # assigns slot and times (start > now placeholder keeps it in the
        # unstarted set)
        self.commits[b][i] = _Commit(job, tier, arrival, _INF, _INF,
                                     slot=-1, planned_at=now)

    # ------------------------------------------------------------- events
    def _on_arrive(self, now: float, b: int, i: int) -> None:
        self.pending[b].append(i)
        self.event_log.append(("arrive", now, b, i, self.jobs[b][i].name))
        wards = range(self.B) if self.policy.joint else [b]
        self._decide(wards, now, fresh={b: [i]})

    def _on_complete(self, now: float, b: int, i: int, end: float) -> None:
        c = self.commits[b][i]
        if c is None or self.finished[b][i] or c.end != end or \
                c.start > now:
            return                                   # stale (re-timed) event
        self.finished[b][i] = True
        job = c.job
        response = end - job.release
        self.metrics.record(now, job.workload, response, job.deadline,
                            c.machine, end - c.start,
                            attempts=self.kills[b][i] + 1,
                            weight=job.weight)
        self.event_log.append(
            ("complete", now, b, i, c.machine, c.start, end, response,
             int(response > job.deadline), self.kills[b][i] + 1))

    def _strike(self, pool: _Pool, now: float,
                latest: bool = False) -> Optional[int]:
        """Non-retired machine a fleet event takes: the earliest-free one
        for drains/scale-downs, the LATEST-free (busiest) one for crash
        failures (`latest=True` — a crash that spared the idlest machine
        would rarely kill anything). None when the pool has none left."""
        cand = [(f, k) for k, (f, s) in enumerate(
            zip(self._slot_frees(pool, now), pool.slots))
            if s.retired_at is None]
        if not cand:
            return None
        return (max(cand) if latest else min(cand))[1]

    def _on_fail(self, now: float, ev: FailureEvent) -> None:
        pool = self._pool(ev.tier, ev.ward)
        k = self._strike(pool, now, latest=ev.kill_running)
        ward_key = -1 if ev.ward is None else ev.ward
        kill_flag = int(ev.kill_running)
        if k is None:                      # every machine already retired
            self.event_log.append(("fail", now, ev.tier, ward_key, -1,
                                   now, kill_flag))
            return
        slot = pool.slots[k]
        killed: List[Tuple[int, int]] = []
        if ev.kill_running:
            # crash: the machine dies NOW; its in-flight job is lost
            base = now
            killed = [(b, i) for b, i in self._pool_members(pool)
                      if not self.finished[b][i]
                      and self.commits[b][i].slot == k
                      and self.commits[b][i].start <= now
                      < self.commits[b][i].end]
        else:
            # drain: the machine finishes its running job first
            base = max(self._slot_frees(pool, now)[k], now)
        down_until = base + ev.duration
        slot.down = max(slot.down, down_until)
        slot.outages.append((base, down_until))
        self.event_log.append(("fail", now, ev.tier, ward_key, k,
                               down_until, kill_flag))
        fresh: Dict[int, List[int]] = {}
        for b, i in killed:
            c = self.commits[b][i]
            wasted = now - c.start
            self.kills[b][i] += 1
            self.metrics.record_kill(ev.tier, wasted)
            self.event_log.append(("kill", now, b, i, ev.tier, k, wasted,
                                   self.kills[b][i]))
            self.commits[b][i] = None
            if i not in self.pending[b]:
                self.pending[b].append(i)
            fresh.setdefault(b, []).append(i)
        self._push(down_until, _P_RECOVER, ("recover", ev.tier, ev.ward))
        self._after_fleet_event(ev.tier, ev.ward, now, fresh=fresh)

    def _on_recover(self, now: float, tier: str,
                    ward: Optional[int]) -> None:
        self.event_log.append(("recover", now, tier,
                               -1 if ward is None else ward))
        self._after_fleet_event(tier, ward, now)

    def _on_scale(self, now: float, ev: ScaleEvent) -> None:
        pool = self._pool(ev.tier, ev.ward)
        if ev.delta == 0:
            raise ValueError("scale event with delta 0")
        if ev.delta > 0:
            for _ in range(ev.delta):
                pool.slots.append(_Slot(created=now))
        else:
            active = sum(1 for s in pool.slots if s.retired_at is None)
            if active + ev.delta < 1:
                raise ValueError(f"scale-down to {active + ev.delta} "
                                 f"machines on {ev.tier} at t={now}; a "
                                 f"pool keeps >= 1")
            for _ in range(-ev.delta):
                k = self._strike(pool, now)
                slot = pool.slots[k]
                slot.retired_at = max(self._slot_frees(pool, now)[k], now)
                slot.down = _INF
        self.event_log.append(("scale", now, ev.tier,
                               -1 if ev.ward is None else ev.ward,
                               ev.delta))
        self._after_fleet_event(ev.tier, ev.ward, now)

    def _after_fleet_event(self, tier: str, ward: Optional[int],
                           now: float,
                           fresh: Mapping[int, Sequence[int]] | None = None
                           ) -> None:
        """Capacity changed: replanning policies revisit every affected
        ward (all of them for the shared cloud — the matching-event-count
        batched replan); commit-and-hold policies just re-time. Crash
        kills pass `fresh` — those jobs lost their commitment and MUST be
        re-decided (through the normal decision path, as fresh arrivals)
        even by commit-and-hold policies. The replay runs first so the
        reserved views price the post-event fleet; started-occupancy busy
        views are replay-invariant, preserving the B=1 tabu parity."""
        if tier == CC:
            self._replay(now, edge_wards=())
        else:
            self._replay(now, edge_wards=[ward], cloud=False)
        fresh = dict(fresh or {})
        if self.policy.replans_on_fleet_events:
            affected = list(range(self.B)) \
                if tier == CC or self.policy.joint else [ward]
            self._decide(affected, now, fresh=fresh)
        elif fresh:
            self._decide(sorted(fresh), now, fresh=fresh)

    def _on_net(self, now: float, ev: NetworkEvent, on: bool) -> None:
        """A degraded-network window opens/closes: update the active
        factor set, log, and let replanning policies re-price movable
        jobs under the new uplink (commitments keep their arrivals —
        nothing already shipped is re-timed)."""
        factors = self._net.setdefault(ev.tier, [])
        if on:
            factors.append(ev.factor)
        else:
            factors.remove(ev.factor)
            if not factors:
                del self._net[ev.tier]
        self.event_log.append(("net", now, ev.tier, ev.factor, int(on)))
        if self.policy.replans_on_fleet_events:
            self._decide(range(self.B), now)

    # ---------------------------------------------------------------- run
    def run(self) -> MetroResult:
        if self._ran:
            raise ValueError("a MetroEngine instance runs once; build a "
                             "fresh one per policy")
        self._ran = True
        t0 = time.perf_counter()
        while self._heap:
            t, prio, _, payload = heapq.heappop(self._heap)
            self._t_end = max(self._t_end, t)
            self._events += 1
            kind = payload[0]
            if kind == "complete":
                self._on_complete(t, *payload[1:])
            elif kind == "arrive":
                self._on_arrive(t, *payload[1:])
            elif kind == "fail":
                self._on_fail(t, payload[1])
            elif kind == "scale":
                self._on_scale(t, payload[1])
            elif kind == "net":
                self._on_net(t, *payload[1:])
            else:
                self._on_recover(t, *payload[1:])
        seconds = time.perf_counter() - t0

        for b, flags in enumerate(self.finished):
            missing = [i for i, ok in enumerate(flags) if not ok]
            if missing:
                raise ValueError(f"ward {b}: jobs neither completed nor "
                                 f"shed: {missing[:5]} (event bug)")
        wards = []
        for b in range(self.B):
            # shed jobs have no commitment — the schedule holds only the
            # jobs that actually ran
            entries = [ScheduledJob(c.job, c.machine, c.arrival, c.start,
                                    c.end) for c in self.commits[b]
                       if c is not None]
            wards.append(Schedule(
                entries=entries,
                weighted_sum=sum(e.job.weight * e.response
                                 for e in entries),
                unweighted_sum=sum(e.response for e in entries),
                last_end=max((e.end for e in entries), default=0.0)))
        return MetroResult(policy=getattr(self.policy, "name", "?"),
                           wards=wards, metrics=self.metrics,
                           utilization=self._utilization(),
                           event_log=self.event_log, events=self._events,
                           seconds=seconds)

    def _utilization(self) -> Dict[str, float]:
        t_end = self._t_end
        busy = self.metrics.busy_time
        cloud_cap = self.cloud.capacity_integral(t_end)
        edge_cap = sum(p.capacity_integral(t_end) for p in self.edges)
        out = {}
        if cloud_cap > 0:
            out["cloud"] = busy.get(CC, 0.0) / cloud_cap
        if edge_cap > 0:
            out["edge"] = busy.get(ES, 0.0) / edge_cap
        if t_end > 0:
            # devices are private/unbounded: report mean concurrency
            out["device_concurrency"] = busy.get(ED, 0.0) / t_end
        return out


def simulate_metro(ward_traces: Sequence[Sequence[JobSpec]],
                   policy: Policy, *,
                   machines_per_tier: Mapping[str, int] | None = None,
                   failures: Sequence[FailureEvent] = (),
                   scale_events: Sequence[ScaleEvent] = (),
                   network_events: Sequence[NetworkEvent] = (),
                   metrics: MetroMetrics | None = None) -> MetroResult:
    """Build-and-run convenience wrapper (one engine per policy run)."""
    return MetroEngine(ward_traces, policy,
                       machines_per_tier=machines_per_tier,
                       failures=failures, scale_events=scale_events,
                       network_events=network_events,
                       metrics=metrics).run()
