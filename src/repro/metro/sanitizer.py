"""Metro-engine sanitizer (DESIGN.md §14): runtime validation of the
invariants the DES promises but never asserts.

The engine's correctness story rests on properties that are argued in
docstrings and exercised indirectly by parity tests, yet nothing checks
them while a run is in flight. `MetroSanitizer` is a READ-ONLY observer
the engine consults when run with ``MetroEngine.run(sanitize=True)``:

  I1  C5 FIFO-by-arrival per pool — replaying a pool's unstarted
      commitments in (arrival, plan time, ward, index) order must yield
      non-decreasing start times, each at or after its (now-clamped)
      arrival.
  I2  No slot double-booking — per machine slot, the [start, end)
      service intervals of all attempts (primaries and hedge backups,
      finished history included) never overlap; no attempt starts
      before its slot existed, and no unstarted attempt is scheduled
      while its slot is down.
  I3  Started jobs immutable (C2) — once an attempt's start passes
      `now`, its (machine, slot, start) never changes again for that
      attempt while the job is live; only its END may stretch
      (fail-slow re-timing, §13). Attempts are keyed by the crash-kill
      count so a legitimate re-dispatch after a kill is a NEW attempt,
      not a mutation, and terminal jobs are exempt (a hedge win
      replaces the primary commitment with the winning backup so the
      final schedule reports the serving machine).
  I4  Event-time monotonicity — heap pops never go backwards in time,
      and every event-log record carries the pop instant.
  I5  At most one hedge per job, ever — even across crash promotions.
  I6  Every job completed-or-shed exactly once at exit (terminal
      events: complete / shed / giveup), independently recounted from
      the sanitizer's own terminal bookkeeping, not the engine's
      `finished` flags.
  I7  Capacity sanity — each pool's `capacity_integral` is bounded by
      its raw slot-seconds (outage/slowdown discounts only ever shave
      capacity), and the service the metrics charged per shared tier
      never exceeds the capacity that existed to deliver it.

Violations raise `SanitizerViolation` (a ValueError — survives
``python -O``, R001-clean) naming the invariant.

Cost model: I3–I5 are O(1) dict bookkeeping per observation; I1/I2
piggyback on `_replay_pool`, whose own sort already costs
O(E log E) in the pool's entries, so sanitizing adds a constant factor
— measured < 1.2x wall-clock on the chaos packs (DESIGN.md §14), well
inside the < 2x budget. The sanitizer never pushes events, never
mutates engine state and never touches the event log, so sanitized
runs produce bit-identical event-log CRCs to unsanitized ones.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.tiers import CC, ES

_INF = float("inf")
_EPS = 1e-9


class SanitizerViolation(ValueError):
    """An engine invariant (I1–I7, module docstring) was broken."""


class MetroSanitizer:
    """Read-only invariant checker attached by `MetroEngine.run` when
    ``sanitize=True``. One instance observes one run."""

    def __init__(self, engine):
        self.eng = engine
        self._last_t = -_INF
        # I3: (ward, index, is_hedge, attempt#) -> (machine, slot, start)
        self._started: Dict[Tuple[int, int, bool, int],
                            Tuple[str, int, float]] = {}
        # I6: (ward, index) -> terminal kind
        self._terminal: Dict[Tuple[int, int], str] = {}
        # I5: (ward, index) -> hedge dispatch count
        self._hedges: Dict[Tuple[int, int], int] = {}
        self.checks = 0          # observation count (tests/overhead)

    # ------------------------------------------------------------ helpers
    def _fail(self, inv: str, msg: str) -> None:
        raise SanitizerViolation(f"sanitizer[{inv}]: {msg}")

    def _pool_label(self, pool) -> str:
        if pool.tier == CC:
            return "cloud"
        try:
            return f"edge[{self.eng.edges.index(pool)}]"
        except ValueError:                       # pragma: no cover
            return pool.tier

    # ------------------------------------------------------------- events
    def on_event(self, t: float, payload: tuple) -> None:
        """I4: the event heap pops in non-decreasing time order."""
        self.checks += 1
        if t < self._last_t - _EPS:
            self._fail("I4-monotonic",
                       f"event {payload[0]!r} popped at t={t} after "
                       f"t={self._last_t}")
        self._last_t = max(self._last_t, t)

    def on_hedge(self, b: int, i: int) -> None:
        """I5: one hedge dispatch per job, ever."""
        self.checks += 1
        n = self._hedges.get((b, i), 0) + 1
        self._hedges[(b, i)] = n
        if n > 1:
            self._fail("I5-single-hedge",
                       f"job ({b}, {i}) hedged {n} times")

    def on_terminal(self, b: int, i: int, kind: str) -> None:
        """I6 bookkeeping: complete / shed / giveup, exactly once."""
        self.checks += 1
        prev = self._terminal.get((b, i))
        if prev is not None:
            self._fail("I6-terminal",
                       f"job ({b}, {i}) reached terminal {kind!r} after "
                       f"already terminating as {prev!r}")
        self._terminal[(b, i)] = kind

    # -------------------------------------------------------- pool checks
    def check_pool(self, pool, now: float) -> None:
        """I1 (FIFO), I2 (no double-booking), I3 (C2) for one pool —
        called by the engine at the end of every `_replay_pool`."""
        self.checks += 1
        eng = self.eng
        label = self._pool_label(pool)
        n_slots = len(pool.slots)
        per_slot: Dict[int, List[Tuple[float, float, Tuple]]] = {}
        queue: List[Tuple[Tuple, float, float]] = []
        for b, i, c, is_hedge in eng._pool_entries(pool):
            who = (b, i, "hedge" if is_hedge else "primary")
            if not c.start <= c.end:
                self._fail("I2-interval",
                           f"{label} {who}: start {c.start} > end "
                           f"{c.end}")
            if c.start == _INF:
                self._fail("I2-unplaced",
                           f"{label} {who}: commitment still has "
                           f"placeholder times after replay")
            if not 0 <= c.slot < n_slots:
                self._fail("I2-slot",
                           f"{label} {who}: slot {c.slot} outside "
                           f"[0, {n_slots})")
            slot = pool.slots[c.slot]
            if c.start < slot.created - _EPS:
                self._fail("I2-created",
                           f"{label} {who}: starts at {c.start} before "
                           f"slot {c.slot} existed ({slot.created})")
            per_slot.setdefault(c.slot, []).append((c.start, c.end, who))
            if c.start > now:
                # unstarted: replay may not dispatch into a down window
                if c.start < slot.down - _EPS:
                    self._fail("I2-down",
                               f"{label} {who}: start {c.start} inside "
                               f"slot {c.slot} down-until {slot.down}")
                queue.append(((max(now, c.arrival), c.planned_at, b, i,
                               is_hedge), c.start, c.arrival))
            elif not eng.finished[b][i]:
                # I3: snapshot/verify (machine, slot, start) per attempt.
                # Terminal jobs are exempt: a hedge WIN replaces the
                # primary commitment with the winning backup so the
                # final schedule reports the machine that actually
                # served the job (§13) — reporting, not occupancy.
                key = (b, i, is_hedge, eng.kills[b][i])
                val = (c.machine, c.slot, c.start)
                seen = self._started.get(key)
                if seen is None:
                    self._started[key] = val
                elif seen != val:
                    self._fail("I3-immutable",
                               f"{label} {who}: started attempt mutated "
                               f"from {seen} to {val} (C2)")
        # I2: per-slot intervals must not overlap
        for k, spans in per_slot.items():
            spans.sort()
            for (s0, e0, w0), (s1, e1, w1) in zip(spans, spans[1:]):
                if s1 < e0 - _EPS:
                    self._fail("I2-overlap",
                               f"{label} slot {k}: {w0} [{s0}, {e0}) "
                               f"overlaps {w1} [{s1}, {e1}) "
                               f"(double-booking)")
        # I1: FIFO-by-arrival — dispatch order must yield monotone starts
        queue.sort()
        prev_start, prev_key = -_INF, None
        for key, start, arrival in queue:
            if start < max(now, arrival) - _EPS:
                self._fail("I1-fifo",
                           f"{label} job {key[2:4]}: start {start} "
                           f"before its replay arrival "
                           f"{max(now, arrival)}")
            if start < prev_start - _EPS:
                self._fail("I1-fifo",
                           f"{label}: FIFO inversion — job {key[2:4]} "
                           f"(arrival {key[0]}) starts at {start}, "
                           f"before job {prev_key[2:4]} (earlier "
                           f"arrival {prev_key[0]}) at {prev_start}")
            prev_start, prev_key = start, key
        # the reserved view the replay just refreshed stays sorted
        if list(pool.reserved) != sorted(pool.reserved) or \
                len(pool.reserved) != n_slots:
            self._fail("I1-reserved",
                       f"{label}: reserved view inconsistent "
                       f"({len(pool.reserved)} entries for {n_slots} "
                       f"slots)")

    # --------------------------------------------------------------- exit
    def at_exit(self, t_end: float) -> None:
        """I6 (every job terminal exactly once) and I7 (capacity
        bounds), checked once after the heap drains."""
        eng = self.eng
        for b, trace in enumerate(eng.jobs):
            for i in range(len(trace)):
                if (b, i) not in self._terminal:
                    self._fail("I6-terminal",
                               f"job ({b}, {i}) never completed, shed "
                               f"or gave up")
        pools = [eng.cloud] + list(eng.edges)
        for pool in pools:
            cap = pool.capacity_integral(t_end)
            raw = sum(
                max(0.0, min(s.retired_at if s.retired_at is not None
                             else t_end, t_end) - s.created)
                for s in pool.slots)
            if not -_EPS <= cap <= raw + _EPS:
                self._fail("I7-capacity",
                           f"{self._pool_label(pool)}: capacity_integral "
                           f"{cap} outside [0, slot-seconds {raw}]")
        busy = eng.metrics.busy_time
        for tier, label, cap in (
                (CC, "cloud", eng.cloud.capacity_integral(t_end)),
                (ES, "edge", sum(p.capacity_integral(t_end)
                                 for p in eng.edges))):
            used = busy.get(tier, 0.0)
            if used > cap + _EPS * max(1.0, cap):
                self._fail("I7-capacity",
                           f"{label}: {used} machine-seconds of service "
                           f"charged against {cap} available")
