"""Streaming SLA metrics for metro traffic runs (DESIGN.md §10).

Everything here is O(1) memory in the number of completions: response
times land in fixed log-spaced histograms (quantiles are read back by
bucket interpolation, so a p99 is accurate to one bucket width — ~5%
relative with the default 256 buckets over [0.01, 1e5]), per-class
deadline misses / sheds / crash retries are counters, and "recent"
statistics come from a ring of per-window histograms that folds closed
windows into the totals. A SHED job counts as an explicit deadline
miss (it never ran) and a crash kill accumulates the wasted
machine-seconds of the lost partial run (DESIGN.md §11).
Long runs therefore hold `bins + windows * bins` integers regardless of
how many episodes stream through.

All state is plain ints/floats updated in event order, so two runs of
the same seeded engine produce bit-identical summaries (the metro
determinism invariant, tests/test_metro.py).
"""
from __future__ import annotations

import math
from collections import deque
from typing import Deque, Dict, List

_UNCLASSED = "unclassified"


class StreamingQuantiles:
    """Fixed log-bucket histogram with quantile read-back.

    add() is O(1); quantile(q) interpolates inside the bucket holding the
    q-th observation. Values below `lo` land in bucket 0, values above
    `hi` in the overflow bucket (whose upper edge is the running max, so
    a pathological tail still reports a finite p99). The clamping is NOT
    silent: `underflow`/`overflow` count every observation outside
    [lo, hi], so a fail-slow-stretched tail that escapes the range is
    visible in summary() rather than faking an in-range quantile."""

    def __init__(self, lo: float = 1e-2, hi: float = 1e5, bins: int = 256):
        if not (lo > 0 and hi > lo and bins > 1):
            raise ValueError(f"bad histogram shape lo={lo} hi={hi} "
                             f"bins={bins}")
        self.lo, self.hi, self.bins = lo, hi, bins
        self._scale = bins / math.log(hi / lo)
        self.counts = [0] * (bins + 1)          # +1: overflow bucket
        self.n = 0
        self.max = 0.0
        self.sum = 0.0
        self.underflow = 0             # observations strictly below lo
        self.overflow = 0              # observations strictly above hi

    def _bucket(self, x: float) -> int:
        if x <= self.lo:
            return 0
        if x >= self.hi:
            return self.bins
        return min(self.bins - 1,
                   int(math.log(x / self.lo) * self._scale))

    def _edges(self, b: int) -> tuple:
        if b >= self.bins:
            return self.hi, max(self.max, self.hi)
        return (self.lo * math.exp(b / self._scale),
                self.lo * math.exp((b + 1) / self._scale))

    def add(self, x: float) -> None:
        self.counts[self._bucket(x)] += 1
        self.n += 1
        self.sum += x
        if x > self.max:
            self.max = x
        if x < self.lo:
            self.underflow += 1
        elif x > self.hi:
            self.overflow += 1

    def merge(self, other: "StreamingQuantiles") -> None:
        if (other.lo, other.hi, other.bins) != (self.lo, self.hi, self.bins):
            raise ValueError("histogram shapes differ")
        self.counts = [a + b for a, b in zip(self.counts, other.counts)]
        self.n += other.n
        self.sum += other.sum
        self.max = max(self.max, other.max)
        self.underflow += other.underflow
        self.overflow += other.overflow

    def quantile(self, q: float) -> float:
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.n == 0:
            return 0.0
        rank = q * (self.n - 1)
        seen = 0
        for b, c in enumerate(self.counts):
            if c and seen + c > rank:
                left, right = self._edges(b)
                frac = (rank - seen + 0.5) / c
                return left + (right - left) * frac
            seen += c
        return self.max                                  # pragma: no cover

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else 0.0


class _Window:
    """One closed (or the open) time window's counters."""

    def __init__(self, start: float, hist_shape):
        self.start = start
        self.hist = StreamingQuantiles(*hist_shape)
        self.completions = 0
        self.misses = 0
        self.sheds = 0


class MetroMetrics:
    """Windowed streaming metrics sink the metro engine feeds.

    record() takes one completion; busy time per tier accumulates for the
    utilisation report (the engine supplies the capacity integrals, since
    only it knows the failure/scale timeline). `window` is the roll width
    in trace time units; `keep_windows` bounds the recent-statistics ring.
    """

    def __init__(self, window: float = 60.0, keep_windows: int = 8,
                 hist_lo: float = 1e-2, hist_hi: float = 1e5,
                 hist_bins: int = 256):
        if window <= 0:
            raise ValueError(f"window must be > 0, got {window}")
        self._shape = (hist_lo, hist_hi, hist_bins)
        self.window = window
        self.total = StreamingQuantiles(*self._shape)
        self.completions = 0
        self.misses = 0
        self.shed = 0                  # jobs dropped by SHED decisions
        self.retries = 0               # crash kills (lost in-flight jobs)
        self.wasted_seconds = 0.0      # machine-seconds lost to kills
        self.max_attempts = 1          # worst dispatch count of any job
        self.retry_exhausted = 0       # sheds from the max_attempts cap
        self.retries_by_tier: Dict[str, int] = {}
        self.wasted_by_tier: Dict[str, float] = {}
        self.hedges = 0                # backup attempts dispatched
        self.hedge_wins = 0            # completions where the backup won
        self.hedge_waste = 0.0         # machine-seconds of cancelled work
        self.hedge_waste_by_tier: Dict[str, float] = {}
        self.hedge_by_tier: Dict[str, int] = {}   # backup target tiers
        # per-class response histograms for the p99/p99.9 tail report
        self.class_hist: Dict[str, StreamingQuantiles] = {}
        self.weighted_finished = 0.0   # sum of weight over completed + shed
        self.weighted_missed = 0.0     # ... over missed + shed
        # class -> [completed, missed, shed]
        self.by_class: Dict[str, List[int]] = {}
        self.class_weight: Dict[str, float] = {}     # class -> job weight
        self.busy_time: Dict[str, float] = {}        # tier -> sum of proc
        self.recent: Deque[_Window] = deque(maxlen=max(1, keep_windows))
        self._open: _Window | None = None
        self.last_time = 0.0

    # ------------------------------------------------------------- feeding
    def _roll(self, now: float) -> None:
        start = math.floor(now / self.window) * self.window
        if self._open is None:
            self._open = _Window(start, self._shape)
        elif start > self._open.start:
            self.recent.append(self._open)
            self._open = _Window(start, self._shape)

    def record(self, now: float, wclass: str, response: float,
               deadline: float, tier: str, proc: float, *,
               attempts: int = 1, weight: float = 1.0,
               hedged: bool = False, hedge_win: bool = False) -> None:
        """One job completion at sim time `now`. `attempts` counts
        dispatches (1 = never crash-killed); `weight` feeds the
        weighted miss-rate alongside the per-class counters. `hedged`
        marks a job that ever dispatched a backup attempt; `hedge_win`
        marks the backup finishing first."""
        self._roll(now)
        missed = response > deadline
        self.total.add(response)
        self.completions += 1
        if hedge_win:
            self.hedge_wins += 1
        self.busy_time[tier] = self.busy_time.get(tier, 0.0) + proc
        if attempts > self.max_attempts:
            self.max_attempts = attempts
        self.weighted_finished += weight
        cls = wclass or _UNCLASSED
        self.class_weight[cls] = max(self.class_weight.get(cls, weight),
                                     weight)
        hist = self.class_hist.get(cls)
        if hist is None:
            hist = self.class_hist[cls] = StreamingQuantiles(*self._shape)
        hist.add(response)
        row = self.by_class.setdefault(cls, [0, 0, 0])
        row[0] += 1
        if missed:
            row[1] += 1
            self.misses += 1
            self.weighted_missed += weight
        w = self._open
        w.hist.add(response)
        w.completions += 1
        w.misses += int(missed)
        if now > self.last_time:
            self.last_time = now

    def record_shed(self, now: float, wclass: str, weight: float = 1.0,
                    exhausted: bool = False) -> None:
        """One job dropped — by a SHED decision, or (`exhausted=True`)
        because its crash-retry budget ran out (the max_attempts cap):
        an explicit deadline miss (no response sample)."""
        self._roll(now)
        self.shed += 1
        if exhausted:
            self.retry_exhausted += 1
        self.weighted_finished += weight
        self.weighted_missed += weight
        cls = wclass or _UNCLASSED
        self.class_weight[cls] = max(self.class_weight.get(cls, weight),
                                     weight)
        row = self.by_class.setdefault(cls, [0, 0, 0])
        row[2] += 1
        self._open.misses += 1
        self._open.sheds += 1
        if now > self.last_time:
            self.last_time = now

    def record_kill(self, tier: str, wasted: float) -> None:
        """A crash failure killed an in-flight job: `wasted` machine-
        seconds of partial work on `tier` are lost and the job retries."""
        self.retries += 1
        self.wasted_seconds += wasted
        self.retries_by_tier[tier] = self.retries_by_tier.get(tier, 0) + 1
        self.wasted_by_tier[tier] = \
            self.wasted_by_tier.get(tier, 0.0) + wasted

    def record_hedge(self, tier: str) -> None:
        """A backup attempt was dispatched onto `tier`."""
        self.hedges += 1
        self.hedge_by_tier[tier] = self.hedge_by_tier.get(tier, 0) + 1

    def record_hedge_cancel(self, tier: str, wasted: float) -> None:
        """The losing attempt of a hedge race was cancelled on `tier`
        after consuming `wasted` machine-seconds (0 if never started)."""
        self.hedge_waste += wasted
        self.hedge_waste_by_tier[tier] = \
            self.hedge_waste_by_tier.get(tier, 0.0) + wasted

    def flush(self) -> None:
        """Close the in-progress window into the ring. The engine calls
        this once at exit: without it a run shorter than one roll width
        never lands a window in `recent`, so the windowed snapshot of a
        short run reads all-zeros even though jobs finished. Idempotent
        (the window moves, nothing is double-counted), and a later
        record() simply opens a fresh window."""
        if self._open is not None:
            self.recent.append(self._open)
            self._open = None

    # ------------------------------------------------------------ reading
    @property
    def finished(self) -> int:
        """Jobs accounted for: completed + explicitly shed."""
        return self.completions + self.shed

    @property
    def miss_rate(self) -> float:
        """Deadline misses over all finished jobs; a shed job IS a miss."""
        return (self.misses + self.shed) / self.finished \
            if self.finished else 0.0

    @property
    def shed_rate(self) -> float:
        return self.shed / self.finished if self.finished else 0.0

    @property
    def hedge_rate(self) -> float:
        """Backup attempts dispatched per finished job."""
        return self.hedges / self.finished if self.finished else 0.0

    @property
    def weighted_miss_rate(self) -> float:
        return self.weighted_missed / self.weighted_finished \
            if self.weighted_finished else 0.0

    @property
    def critical_miss_rate(self) -> float:
        """Miss rate over the HEAVIEST weight class(es) only — the
        life-critical SLA the shedding policy protects by sacrificing
        lighter classes (DESIGN.md §11)."""
        if not self.by_class:
            return 0.0
        w_max = max(self.class_weight.values())
        done = miss = 0
        for c, (d, m, s) in self.by_class.items():
            if self.class_weight[c] >= w_max:
                done += d + s
                miss += m + s
        return miss / done if done else 0.0

    def miss_rate_by_class(self) -> Dict[str, float]:
        return {c: ((m + s) / (d + s) if d + s else 0.0)
                for c, (d, m, s) in sorted(self.by_class.items())}

    def recent_quantile(self, q: float) -> float:
        """Quantile over the last `keep_windows` closed windows plus the
        open one — the live-dashboard view of the tail."""
        merged = StreamingQuantiles(*self._shape)
        for w in self.recent:
            merged.merge(w.hist)
        if self._open is not None:
            merged.merge(self._open.hist)
        return merged.quantile(q)

    def _recent_counts(self) -> tuple:
        """(finished, misses, windows) over the ring + open window; a
        shed job finished (and missed) in its window, like miss_rate."""
        windows = list(self.recent)
        if self._open is not None:
            windows.append(self._open)
        done = sum(w.completions + w.sheds for w in windows)
        miss = sum(w.misses for w in windows)
        return done, miss, len(windows)

    def summary(self, utilization: Dict[str, float] | None = None) -> dict:
        """Flat report dict (serve's policy table / the metro benchmark)."""
        r_done, r_miss, r_windows = self._recent_counts()
        return {
            "recent_windows": r_windows,
            "recent_finished": r_done,
            "recent_misses": r_miss,
            "recent_miss_rate": r_miss / r_done if r_done else 0.0,
            "recent_p99": self.recent_quantile(0.99),
            "completions": self.completions,
            "shed": self.shed,
            "shed_rate": self.shed_rate,
            "retries": self.retries,
            "retry_exhausted": self.retry_exhausted,
            "retries_by_tier": dict(sorted(self.retries_by_tier.items())),
            "wasted_machine_seconds": self.wasted_seconds,
            "wasted_by_tier": dict(sorted(self.wasted_by_tier.items())),
            "max_attempts": self.max_attempts,
            "hedges": self.hedges,
            "hedge_rate": self.hedge_rate,
            "hedge_wins": self.hedge_wins,
            "hedge_waste": self.hedge_waste,
            "hedge_by_tier": dict(sorted(self.hedge_by_tier.items())),
            "hedge_waste_by_tier":
                dict(sorted(self.hedge_waste_by_tier.items())),
            "p50": self.total.quantile(0.50),
            "p95": self.total.quantile(0.95),
            "p99": self.total.quantile(0.99),
            "p999": self.total.quantile(0.999),
            "p99_by_class": {c: h.quantile(0.99)
                             for c, h in sorted(self.class_hist.items())},
            "p999_by_class": {c: h.quantile(0.999)
                              for c, h in sorted(self.class_hist.items())},
            "tail_underflow": self.total.underflow,
            "tail_overflow": self.total.overflow,
            "mean_response": self.total.mean,
            "max_response": self.total.max,
            "miss_rate": self.miss_rate,
            "weighted_miss_rate": self.weighted_miss_rate,
            "critical_miss_rate": self.critical_miss_rate,
            "miss_by_class": self.miss_rate_by_class(),
            "busy_time": dict(sorted(self.busy_time.items())),
            "utilization": dict(sorted((utilization or {}).items())),
        }
