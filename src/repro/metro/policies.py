"""Pluggable replanning policies for the metro engine (DESIGN.md §10).

The engine owns ground truth (fleet occupancy, FIFO dispatch, commit
times); a policy only answers "which tier should each movable job run
on?" at each decision event, through one `decide` call over the wards
the event touched. The engine hands every ward's subproblem in the same
shifted-spec form `online_schedule` replans (release moved to `now`,
remaining transmission on the committed tier), so search-based policies
optimise exactly the committed problem (DESIGN.md §7).

A decision is a tier name (cloud/edge/device) or the `SHED` sentinel:
a shed job is dropped — the engine marks it finished-missed with a
``shed`` event instead of ever running it (DESIGN.md §11). Shedding is
the admission-control escape valve for saturation: a job that cannot
meet any deadline anyway is cheaper missed *explicitly* than queued in
front of jobs that still can.

Four built-ins:

  * `GreedyPolicy` — commit-on-arrival with the paper's greedy rule
    against the RESERVED fleet view (queued commitments hold their
    machines); never revisits a decision.
  * `TabuPolicy` — `online_schedule(replan="tabu")`-style committed
    replanning of the affected ward. When one event touches several
    wards at once (a shared-cloud failure/recovery/scale event reaches
    every ward at the same event count), all their replans go through a
    single `scheduler.search_batched` call, so the sweep vectorises on
    accelerator backends instead of looping ward by ward.
  * `FleetPolicy` — the contention-aware fixed point: every decision
    event replans ALL wards jointly via `scheduler.search_fleet`, so
    no two wards ever double-book the shared metropolitan cloud.
  * `SheddingPolicy` — a wrapper that delegates tier choice to any
    inner policy, then sheds lowest-weight-class movable jobs whose
    reserved backlog exceeds a deadline-derived horizon.
  * `HedgingPolicy` — a wrapper that delegates `decide` to any inner
    policy and additionally answers the engine's hedge watchdog
    (`hedge()` hook): when an in-flight job of the HEAVIEST weight
    class has overrun its expected runtime (fail-slow machine) or its
    committed end misses the deadline, pick the backup tier whose
    reserved queue finishes the job earliest — if that beats the
    committed projection by a margin (DESIGN.md §13).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence

from repro.core import scheduler
from repro.core.simulator import JobSpec, Reservation
from repro.core.tiers import CC, ED, ES

# sentinel decision: drop the job instead of placing it on a tier (the
# engine validates decisions against tiers + SHED in one place)
SHED = "shed"
_INF = float("inf")


@dataclass
class ReplanRequest:
    """One ward's movable subproblem at a decision event."""
    ward: int
    movable: List[int]                  # ward-local job indices
    shifted: List[JobSpec]              # online-style replan specs
    current: List[Optional[str]]        # committed tier per movable job
    fresh: List[int]                    # positions in `movable` new this event
    busy: Dict[str, List[float]]        # started-occupancy per shared tier
    reserved: Dict[str, List[float]]    # per-machine frees incl. queued jobs
    machines_per_tier: Dict[str, int]
    # OTHER wards' unstarted cloud commitments (shifted), queue-active
    # but immovable for this ward
    background: List[JobSpec] = field(default_factory=list)


@dataclass
class HedgeRequest:
    """One in-flight job's hedge question at a watchdog event: the job
    as a fresh replan spec (release = now, full re-ship transmission,
    degraded-network factors priced in), where it currently runs and
    when the engine projects it to finish, plus the same fleet views a
    ReplanRequest carries."""
    ward: int
    job: JobSpec                        # fresh shifted spec (release=now)
    tier: str                           # committed (running) tier
    projected_end: float                # committed end under fail-slow
    busy: Dict[str, List[float]]
    reserved: Dict[str, List[float]]
    machines_per_tier: Dict[str, int]


class Policy(Protocol):
    """What the engine needs from a policy. `joint` policies replan every
    ward at every decision event; `replans_on_fleet_events` ones get a
    decide() call on failure/recovery/scale events (otherwise the engine
    just re-times the committed tiers around the changed capacity)."""
    name: str
    joint: bool
    replans_on_fleet_events: bool

    def decide(self, requests: Sequence[ReplanRequest], now: float
               ) -> List[List[str]]:
        """One decision list per request, aligned with its `movable`:
        each entry a tier name or `SHED` (drop the job, scored as an
        explicit deadline miss)."""
        ...                                               # pragma: no cover


@dataclass
class GreedyPolicy:
    """Paper greedy, one arrival at a time: the new job takes the machine
    minimising its completion given every reservation so far; existing
    commitments keep their tier (the engine re-times them around
    failures). The myopic baseline every replanner must beat."""
    name: str = "greedy"
    joint: bool = False
    replans_on_fleet_events: bool = False

    def decide(self, requests, now):
        out = []
        for req in requests:
            resv = {t: list(req.reserved.get(t, ())) for t in (CC, ES)}
            tiers = list(req.current)
            for pos in req.fresh:
                job = req.shifted[pos]
                tier = scheduler.greedy_schedule(
                    [job], machines_per_tier=req.machines_per_tier,
                    busy_until=resv)[0]
                tiers[pos] = tier
                if tier != ED:
                    vec = resv[tier]
                    k = min(range(len(vec)), key=vec.__getitem__)
                    arr = job.release + job.trans.get(tier, 0.0)
                    vec[k] = max(arr, vec[k]) + job.proc[tier]
            if any(t is None for t in tiers):
                raise ValueError("greedy saw a non-fresh uncommitted job")
            out.append(tiers)
        return out


@dataclass
class TabuPolicy:
    """Committed tabu replanning (`online_schedule(replan="tabu")`): every
    decision event re-searches the affected ward's movable jobs against
    the started-occupancy fleet state. Multi-ward events batch through
    `scheduler.search_batched` — the "replans batched across wards at
    matching event counts" path that closes the event-sequential ROADMAP
    item."""
    max_count: int = 5
    jax_threshold: Optional[int] = None
    min_batch: Optional[int] = None
    name: str = "tabu"
    joint: bool = False
    replans_on_fleet_events: bool = True

    @staticmethod
    def _reservations(req: ReplanRequest):
        """-> ({tier: [Reservation]} | None, initial | None) with the
        other wards' unstarted cloud commitments as interval reservations
        (DESIGN.md §12 — `online_schedule_fleet`'s view: ward-local
        decisions, fleet-true queueing, no frozen phantom rows for the
        kernel to carry)."""
        bg = list(req.background or ())
        if not bg:
            return None, None
        resv = {CC: [Reservation(arrival=s.release + s.trans.get(CC, 0.0),
                                 proc=s.proc[CC], release=s.release,
                                 weight=s.weight) for s in bg]}
        return resv, [t if t is not None else ED for t in req.current]

    def decide(self, requests, now):
        if len(requests) == 1:
            req = requests[0]
            resv, initial = self._reservations(req)
            plan = scheduler.search(
                list(req.shifted), initial=initial, reserved=resv,
                max_count=self.max_count,
                jax_threshold=self.jax_threshold,
                machines_per_tier=req.machines_per_tier,
                busy_until=req.busy)
            return [plan.assignment()]
        pairs = [self._reservations(req) for req in requests]
        if any(init is not None for _, init in pairs):
            # the batched backend wants initials for all wards or none
            inits = [init if init is not None
                     else [t if t is not None else ED for t in req.current]
                     for (_, init), req in zip(pairs, requests)]
        else:
            inits = None
        plans = scheduler.search_batched(
            [list(req.shifted) for req in requests],
            max_count=self.max_count,
            machines_per_tier=[req.machines_per_tier for req in requests],
            busy_until=[req.busy for req in requests],
            initial=inits,
            reserved=[resv for resv, _ in pairs]
            if any(resv is not None for resv, _ in pairs) else None,
            min_batch=self.min_batch, jax_threshold=self.jax_threshold)
        return [plan.assignment() for plan in plans]


@dataclass
class FleetPolicy:
    """Joint fixed-point replanning: all wards' movable jobs re-searched
    together by `scheduler.search_fleet`, so the shared cloud's merged
    FIFO queue is priced into every decision (DESIGN.md §9). Budgets are
    deliberately small — each event only needs local repair on top of
    the previous fixed point."""
    max_count: int = 3
    max_sweeps: int = 2
    sweep_max_count: int = 2
    jax_threshold: Optional[int] = None
    name: str = "fleet"
    joint: bool = True
    replans_on_fleet_events: bool = True

    def decide(self, requests, now):
        shared = requests[0].busy.get(CC, [])
        plan = scheduler.search_fleet(
            [req.shifted for req in requests],
            machines_per_tier=[req.machines_per_tier for req in requests],
            max_count=self.max_count, max_sweeps=self.max_sweeps,
            sweep_max_count=self.sweep_max_count,
            jax_threshold=self.jax_threshold,
            busy_until={CC: list(shared)} if shared else None,
            ward_busy_until=[{ES: req.busy.get(ES, [])}
                             for req in requests])
        return [list(a) for a in plan.assignments]


@dataclass
class SheddingPolicy:
    """Saturation-aware load shedding on top of any inner policy
    (DESIGN.md §11): tier choice is delegated to `inner`, then a
    movable job of the ward's LOWEST weight class is shed when the
    reserved backlog of the shared tier it was placed on exceeds a
    deadline-derived horizon — the earliest machine there frees more
    than ``shed_factor * deadline`` away, so queueing the job burns
    saturated capacity better spent on tighter-deadline classes.
    Only jobs strictly BELOW the heaviest weight seen so far are ever
    shed (never a life-critical class, never device placements): under
    mass-casualty saturation the policy chooses WHICH deadline to miss
    instead of letting overflowing queues miss the life-critical ones."""
    inner: Optional[Policy] = None              # default: GreedyPolicy
    shed_factor: float = 0.3
    name: str = "shed"

    def __post_init__(self):
        if self.inner is None:
            self.inner = GreedyPolicy()
        self._max_weight = float("-inf")

    @property
    def joint(self) -> bool:
        return self.inner.joint

    @property
    def replans_on_fleet_events(self) -> bool:
        return self.inner.replans_on_fleet_events

    def decide(self, requests, now):
        decisions = self.inner.decide(requests, now)
        for req in requests:
            for job in req.shifted:
                if job.weight > self._max_weight:
                    self._max_weight = job.weight
        for req, tiers in zip(requests, decisions):
            for pos, job in enumerate(req.shifted):
                tier = tiers[pos]
                if tier not in (CC, ES) or \
                        job.weight >= self._max_weight or \
                        not math.isfinite(job.deadline):
                    continue
                vec = req.reserved.get(tier)
                if not vec:
                    continue
                # how far away the earliest free machine of the placed
                # tier is with every queued commitment dispatched
                backlog = min(vec) - now
                if backlog > self.shed_factor * job.deadline:
                    tiers[pos] = SHED
        return decisions


@dataclass
class HedgingPolicy:
    """Deadline-aware hedging on top of any inner policy (DESIGN.md
    §13): `decide` is delegated untouched; the `hedge()` hook answers
    the engine's watchdog for in-flight stragglers. Mirroring
    `SheddingPolicy`'s class discipline in reverse, only jobs of the
    HEAVIEST weight class seen so far are ever hedged — backup attempts
    burn real machine-seconds, so the duplicate-execution budget is
    spent exclusively on the life-critical SLA. The backup tier is the
    one whose reserved view (every queued commitment dispatched)
    finishes the job earliest, and the hedge is declined unless that
    estimate beats the committed projection by `min_gain` time units —
    a backup that would lose the race is pure waste."""
    inner: Optional[Policy] = None              # default: GreedyPolicy
    min_gain: float = 2.0
    name: str = "hedge"

    def __post_init__(self):
        if self.inner is None:
            self.inner = GreedyPolicy()
        self._max_weight = float("-inf")

    @property
    def joint(self) -> bool:
        return self.inner.joint

    @property
    def replans_on_fleet_events(self) -> bool:
        return self.inner.replans_on_fleet_events

    def _see(self, jobs) -> None:
        for job in jobs:
            if job.weight > self._max_weight:
                self._max_weight = job.weight

    def decide(self, requests, now):
        for req in requests:
            self._see(req.shifted)
        return self.inner.decide(requests, now)

    def hedge(self, req: HedgeRequest, now: float) -> Optional[str]:
        self._see((req.job,))
        job = req.job
        if job.weight < self._max_weight:
            return None                 # hedge only the heaviest class
        best, best_end = None, req.projected_end - self.min_gain
        for tier in (ED, ES, CC):
            if tier == req.tier or job.proc.get(tier, _INF) == _INF:
                continue
            arr = now + job.trans.get(tier, 0.0)
            if tier == ED:
                end = arr + job.proc[ED]
            else:
                vec = req.reserved.get(tier) or []
                free = min(vec) if vec else now
                end = max(arr, free, now) + job.proc[tier]
            if end < best_end:
                best, best_end = tier, end
        return best


def make_policy(name: str, **kw) -> Policy:
    """Factory keyed by the names serve/benchmarks print."""
    try:
        cls = {"greedy": GreedyPolicy, "tabu": TabuPolicy,
               "fleet": FleetPolicy, "shed": SheddingPolicy,
               "hedge": HedgingPolicy}[name]
    except KeyError:
        raise ValueError(f"unknown metro policy {name!r}") from None
    return cls(**kw)
