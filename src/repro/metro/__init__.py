"""Metro traffic engine (DESIGN.md §10): streaming patient-episode
simulation for metro-scale emergency load.

Three layers over the core scheduling machinery:

  * `traces`   — patient-episode generators (correlated bursts of the
    paper's three ICU apps) with diurnal/surge-modulated Poisson
    intensity per ward, per-workload-class SLA deadlines, machine
    failure / elastic-capacity / degraded-network event streams, and
    the seeded chaos scenario-pack registry (`make_scenario`);
  * `engine`   — a discrete-event loop over arrivals, completions,
    drain/crash failures, fail-slow slowdowns, recoveries, scale and
    network events, maintaining the true fleet occupancy (shared
    metropolitan cloud pool, per-ward edge pools, private devices) and
    driving a pluggable `Policy`; crash kills retry through the normal
    decision path with exponential backoff and a bounded attempt cap,
    SHED decisions drop jobs as explicit misses (DESIGN.md §11), and a
    hedge watchdog races backup attempts against stragglers with
    first-completion-wins cancellation (DESIGN.md §13);
  * `policies` — greedy commit-on-arrival, tabu committed replanning
    (`online_schedule`-style, batched across wards at matching event
    counts via `scheduler.search_batched`), the contention-aware
    fleet fixed point (`scheduler.search_fleet`), the saturation-aware
    shedding wrapper, and the deadline-aware hedging wrapper;
  * `metrics`  — streaming, windowed SLA metrics: p50/p95/p99/p99.9
    response (overall and per class), deadline miss-rate per workload
    class (shed jobs are explicit misses), crash-retry/wasted-work and
    hedge counters broken out per tier, per-tier utilisation, all O(1)
    memory over unbounded runs;
  * `tracing`  — the flight recorder (DESIGN.md §15): per-job span
    trees (decision/backoff/wait/transmit/service with fail-slow
    segment splits, hedge races, terminal outcomes) derived from the
    event stream with bit-identical CRCs, an exact additive
    deadline-miss attribution (blame table per class x tier), engine
    self-profiling, and JSONL / Chrome-trace (Perfetto) exporters.
"""
from repro.metro.engine import (FailureEvent, MetroEngine, MetroResult,
                                NetworkEvent, ScaleEvent, SlowdownEvent,
                                simulate_metro)
from repro.metro.metrics import MetroMetrics
from repro.metro.policies import (SHED, FleetPolicy, GreedyPolicy,
                                  HedgeRequest, HedgingPolicy, Policy,
                                  SheddingPolicy, TabuPolicy, make_policy)
from repro.metro.sanitizer import MetroSanitizer, SanitizerViolation
from repro.metro.traces import SCENARIO_PACKS, Scenario, make_scenario
from repro.metro.tracing import (TERMS, EngineProfile, MetroTrace,
                                 MetroTracer, Span)

__all__ = ["FailureEvent", "MetroEngine", "MetroResult", "NetworkEvent",
           "ScaleEvent", "SlowdownEvent", "simulate_metro", "MetroMetrics",
           "SHED", "FleetPolicy", "GreedyPolicy", "HedgeRequest",
           "HedgingPolicy", "Policy", "SheddingPolicy", "TabuPolicy",
           "make_policy", "MetroSanitizer", "SanitizerViolation",
           "SCENARIO_PACKS", "Scenario", "make_scenario",
           "TERMS", "EngineProfile", "MetroTrace", "MetroTracer", "Span"]
