"""Metro traffic engine (DESIGN.md §10): streaming patient-episode
simulation for metro-scale emergency load.

Three layers over the core scheduling machinery:

  * `traces`   — patient-episode generators (correlated bursts of the
    paper's three ICU apps) with diurnal/surge-modulated Poisson
    intensity per ward, per-workload-class SLA deadlines, and machine
    failure / elastic-capacity event streams;
  * `engine`   — a discrete-event loop over arrivals, completions,
    failures/recoveries and scale events, maintaining the true fleet
    occupancy (shared metropolitan cloud pool, per-ward edge pools,
    private devices) and driving a pluggable `Policy`;
  * `policies` — greedy commit-on-arrival, tabu committed replanning
    (`online_schedule`-style, batched across wards at matching event
    counts via `scheduler.search_batched`), and the contention-aware
    fleet fixed point (`scheduler.search_fleet`);
  * `metrics`  — streaming, windowed SLA metrics: p50/p95/p99 response,
    deadline miss-rate per workload class, per-tier utilisation, all
    O(1) memory over unbounded runs.
"""
from repro.metro.engine import (FailureEvent, MetroEngine, MetroResult,
                                ScaleEvent, simulate_metro)
from repro.metro.metrics import MetroMetrics
from repro.metro.policies import (FleetPolicy, GreedyPolicy, Policy,
                                  TabuPolicy, make_policy)

__all__ = ["FailureEvent", "MetroEngine", "MetroResult", "ScaleEvent",
           "simulate_metro", "MetroMetrics", "FleetPolicy", "GreedyPolicy",
           "Policy", "TabuPolicy", "make_policy"]
