"""Metro traffic engine (DESIGN.md §10): streaming patient-episode
simulation for metro-scale emergency load.

Three layers over the core scheduling machinery:

  * `traces`   — patient-episode generators (correlated bursts of the
    paper's three ICU apps) with diurnal/surge-modulated Poisson
    intensity per ward, per-workload-class SLA deadlines, machine
    failure / elastic-capacity / degraded-network event streams, and
    the seeded chaos scenario-pack registry (`make_scenario`);
  * `engine`   — a discrete-event loop over arrivals, completions,
    drain/crash failures, recoveries, scale and network events,
    maintaining the true fleet occupancy (shared metropolitan cloud
    pool, per-ward edge pools, private devices) and driving a pluggable
    `Policy`; crash kills retry through the normal decision path and
    SHED decisions drop jobs as explicit misses (DESIGN.md §11);
  * `policies` — greedy commit-on-arrival, tabu committed replanning
    (`online_schedule`-style, batched across wards at matching event
    counts via `scheduler.search_batched`), the contention-aware
    fleet fixed point (`scheduler.search_fleet`), and the
    saturation-aware shedding wrapper;
  * `metrics`  — streaming, windowed SLA metrics: p50/p95/p99 response,
    deadline miss-rate per workload class (shed jobs are explicit
    misses), crash-retry/wasted-work counters, per-tier utilisation,
    all O(1) memory over unbounded runs.
"""
from repro.metro.engine import (FailureEvent, MetroEngine, MetroResult,
                                NetworkEvent, ScaleEvent, simulate_metro)
from repro.metro.metrics import MetroMetrics
from repro.metro.policies import (SHED, FleetPolicy, GreedyPolicy, Policy,
                                  SheddingPolicy, TabuPolicy, make_policy)
from repro.metro.traces import SCENARIO_PACKS, Scenario, make_scenario

__all__ = ["FailureEvent", "MetroEngine", "MetroResult", "NetworkEvent",
           "ScaleEvent", "simulate_metro", "MetroMetrics", "SHED",
           "FleetPolicy", "GreedyPolicy", "Policy", "SheddingPolicy",
           "TabuPolicy", "make_policy", "SCENARIO_PACKS", "Scenario",
           "make_scenario"]
