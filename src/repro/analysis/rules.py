"""Reprolint rules R001–R006 (DESIGN.md §14).

Each rule codifies a bug class this repo has already fixed by hand —
the catalogue, rationale and suppression policy live in DESIGN.md §14.
Rules are static and conservative by design: they flag syntactic
patterns without data-flow analysis, so a hazard smuggled through an
alias (``t = time.time; t()``) escapes them. That trade keeps the pass
dependency-free and fast enough to run on every push.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.linter import (FileContext, Finding, Rule,
                                   dotted_name, import_aliases, resolve)

# ------------------------------------------------------------------ R001


class BareAssertRule(Rule):
    """``assert`` in runtime code vanishes under ``python -O`` — every
    guard that protects an invariant must raise ValueError/TypeError
    instead (DESIGN.md §7; converted piecemeal in PRs 3/4/6)."""
    id = "R001"
    title = "bare assert in runtime path (stripped by python -O)"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assert):
                yield ctx.finding(
                    self.id, node,
                    "bare assert is stripped by `python -O`; raise "
                    "ValueError/TypeError so the guard survives")


# ------------------------------------------------------------------ R002

_WALL_CLOCK = {
    "time.time", "time.perf_counter", "time.monotonic",
    "time.process_time", "time.thread_time", "time.clock",
    "time.time_ns", "time.perf_counter_ns", "time.monotonic_ns",
}
# any `<x>.now()` / `<x>.utcnow()` / `<x>.today()` where the chain ends
# in a datetime-ish name
_DATETIME_HEADS = {"datetime", "date"}
_DATETIME_CALLS = {"now", "utcnow", "today"}


class WallClockRule(Rule):
    """Wall-clock reads inside simulation logic (``metro/``, ``core/``)
    make event timing a function of the host instead of the seed and
    break the ``--check-determinism`` CRC contract. Simulation time is
    an explicit variable (`now`, event times); bench-timing blocks that
    only measure wall-clock throughput carry a per-line suppression."""
    id = "R002"
    title = "wall-clock read inside simulation logic"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if not ctx.in_dir("metro", "core"):
            return
        aliases = import_aliases(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = resolve(node.func, aliases)
            if name is None:
                continue
            if name in _WALL_CLOCK:
                yield ctx.finding(
                    self.id, node,
                    f"`{name}` inside simulation logic: event timing "
                    f"must be a function of the seed, not the host "
                    f"clock (suppress only for bench-timing blocks)")
                continue
            parts = name.split(".")
            if parts[-1] in _DATETIME_CALLS and \
                    any(p in _DATETIME_HEADS for p in parts[:-1]):
                yield ctx.finding(
                    self.id, node,
                    f"`{name}` reads the wall clock inside simulation "
                    f"logic; thread simulated time instead")


# ------------------------------------------------------------------ R003

# numpy.random module-level constructors that ARE the seeded path
_NP_SEEDED = {"default_rng", "Generator", "SeedSequence", "PCG64",
              "PCG64DXSM", "Philox", "SFC64", "MT19937", "BitGenerator",
              "RandomState"}
# stdlib random functions that sample/mutate the hidden global state
_PY_RANDOM_FNS = {
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "lognormvariate",
    "expovariate", "betavariate", "gammavariate", "paretovariate",
    "weibullvariate", "vonmisesvariate", "triangular", "seed",
    "getrandbits", "randbytes", "binomialvariate",
}


class UnseededRNGRule(Rule):
    """Module-level RNG calls (``np.random.*`` legacy functions,
    stdlib ``random.*``) draw from hidden global state that any import
    or earlier call can perturb — results stop being a function of the
    passed seed. Thread a `np.random.default_rng(seed)` Generator or a
    `jax.random.PRNGKey` instead (DESIGN.md §6)."""
    id = "R003"
    title = "unseeded / global-state RNG call"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        aliases = import_aliases(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = resolve(node.func, aliases)
            if name is None:
                continue
            parts = name.split(".")
            # numpy.random.<fn>(...): legacy global-state samplers
            if len(parts) >= 3 and parts[0] == "numpy" \
                    and parts[1] == "random" \
                    and parts[2] not in _NP_SEEDED:
                yield ctx.finding(
                    self.id, node,
                    f"`{name}` samples numpy's hidden global RNG; "
                    f"thread a seeded `np.random.default_rng` "
                    f"Generator instead")
                continue
            # numpy.random.default_rng() / RandomState() with no seed
            if len(parts) == 3 and parts[0] == "numpy" \
                    and parts[1] == "random" \
                    and parts[2] in ("default_rng", "RandomState") \
                    and not node.args and not node.keywords:
                yield ctx.finding(
                    self.id, node,
                    f"`{name}()` without a seed draws OS entropy — "
                    f"results are not reproducible; pass a seed")
                continue
            # stdlib random.<fn>(...) incl. `from random import choice`
            if parts[0] == "random" and len(parts) == 2 \
                    and parts[1] in _PY_RANDOM_FNS:
                yield ctx.finding(
                    self.id, node,
                    f"`{name}` uses the stdlib global RNG; thread a "
                    f"seeded `random.Random(seed)` (or better, a numpy "
                    f"Generator) instead")
                continue
            if parts[0] == "random" and len(parts) == 2 \
                    and parts[1] == "Random" \
                    and not node.args and not node.keywords:
                yield ctx.finding(
                    self.id, node,
                    "`random.Random()` without a seed is "
                    "OS-entropy-seeded; pass a seed")


# ------------------------------------------------------------------ R004

# consumers whose result does NOT depend on iteration order
_ORDER_FREE = {"sorted", "min", "max", "sum", "len", "any", "all",
               "set", "frozenset"}
# consumers that reveal iteration order
_ORDER_SENSITIVE = {"list", "tuple", "enumerate", "iter"}


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        fn = dotted_name(node.func)
        if fn in ("set", "frozenset"):
            return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


class SetIterationRule(Rule):
    """Iterating a `set` reveals hash order, which for str keys varies
    with PYTHONHASHSEED across processes — if the order feeds event
    sequencing (heap pushes, appends, tie-prone sorts) the run is no
    longer a function of the seed. Wrap the set in `sorted(...)` or
    keep an insertion-ordered dict/list. Order-insensitive reductions
    (`min`/`max`/`sum`/`len`/`any`/`all`/membership) are exempt."""
    id = "R004"
    title = "order-revealing iteration over a set"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        msg = ("iteration order of a set is hash order "
               "(PYTHONHASHSEED-dependent for str); wrap in "
               "`sorted(...)` before it feeds event ordering")
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.For, ast.AsyncFor)) \
                    and _is_set_expr(node.iter):
                yield ctx.finding(self.id, node.iter, msg)
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                                   ast.DictComp)):
                for gen in node.generators:
                    if _is_set_expr(gen.iter):
                        yield ctx.finding(self.id, gen.iter, msg)
            elif isinstance(node, ast.Call):
                fn = dotted_name(node.func)
                if fn in _ORDER_SENSITIVE and node.args \
                        and _is_set_expr(node.args[0]):
                    yield ctx.finding(
                        self.id, node.args[0],
                        f"`{fn}(<set>)` materialises hash order; " + msg)


# ------------------------------------------------------------------ R005

_SAFE_ATTRS = {"shape", "dtype", "ndim", "size", "sharding", "aval"}
_HOST_CALLBACKS = ("pure_callback", "io_callback", "host_callback",
                   "call_tf")


def _jit_static_names(dec: ast.AST,
                      aliases: Dict[str, str]) -> Optional[Set[str]]:
    """If `dec` is a jax.jit decorator (bare or functools.partial),
    return its static_argnames as a set; else None."""
    if resolve(dec, aliases) == "jax.jit":
        return set()
    if isinstance(dec, ast.Call):
        fn = resolve(dec.func, aliases)
        if fn == "jax.jit":
            return _static_from_call(dec)
        if fn == "functools.partial" and dec.args \
                and resolve(dec.args[0], aliases) == "jax.jit":
            return _static_from_call(dec)
    return None


def _static_from_call(call: ast.Call) -> Set[str]:
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value,
                                                              str):
                    names.add(n.value)
    return names


def _param_names(fn: ast.FunctionDef) -> List[str]:
    a = fn.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg.arg)
    if a.kwarg:
        params.append(a.kwarg.arg)
    return params


def _traced_refs(expr: ast.AST, traced: Set[str]) -> List[ast.Name]:
    """Name nodes in `expr` referring to traced params, EXCLUDING
    references that only touch static metadata (`x.shape`, `x.dtype`,
    `len(x)`, `isinstance(x, ...)`) — those are concrete Python values
    even on tracers."""
    out: List[ast.Name] = []

    def visit(node: ast.AST) -> None:
        if isinstance(node, ast.Attribute) and \
                node.attr in _SAFE_ATTRS and \
                isinstance(node.value, ast.Name):
            return                       # x.shape et al: static metadata
        if isinstance(node, ast.Call):
            fn = dotted_name(node.func)
            if fn in ("len", "isinstance", "type"):
                return
        if isinstance(node, ast.Name) and node.id in traced:
            out.append(node)
            return
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return out


class TracedPythonLeakRule(Rule):
    """Inside a `@jax.jit` function or a Pallas kernel body, Python
    control flow on a traced argument, `.item()`/`float()`/`int()`
    coercion of a traced value, or a host callback either fails at
    trace time or silently bakes one traced value into the compiled
    graph. Branch on static args (static_argnames) or use `lax.cond`/
    `jnp.where`; read metadata via `.shape`/`.dtype` (always safe)."""
    id = "R005"
    title = "Python leaking into traced jit/pallas code"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        aliases = import_aliases(ctx.tree)
        defs: Dict[str, ast.FunctionDef] = {
            n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, ast.FunctionDef)}
        seen: Set[str] = set()
        # (a) decorated `@jax.jit` / `@functools.partial(jax.jit, ...)`
        for fn in defs.values():
            for dec in fn.decorator_list:
                statics = _jit_static_names(dec, aliases)
                if statics is not None:
                    seen.add(fn.name)
                    yield from self._check_fn(ctx, fn, statics)
                    break
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = resolve(node.func, aliases)
            # (b) `jax.jit(f, ...)` applied to a local def
            if name == "jax.jit" and node.args and \
                    isinstance(node.args[0], ast.Name):
                target = defs.get(node.args[0].id)
                if target is not None and target.name not in seen:
                    seen.add(target.name)
                    yield from self._check_fn(
                        ctx, target, _static_from_call(node))
            # (c) kernel body handed to pl.pallas_call — every param is
            # a traced Ref except those bound via functools.partial
            if name is not None and name.endswith("pallas_call") \
                    and node.args:
                kernel = node.args[0]
                bound: Set[str] = set()
                if isinstance(kernel, ast.Call) and \
                        resolve(kernel.func, aliases) == \
                        "functools.partial" and kernel.args:
                    bound = {kw.arg for kw in kernel.keywords if kw.arg}
                    kernel = kernel.args[0]
                if isinstance(kernel, ast.Name):
                    target = defs.get(kernel.id)
                    if target is not None and target.name not in seen:
                        seen.add(target.name)
                        yield from self._check_fn(ctx, target, bound)

    def _check_fn(self, ctx: FileContext, fn: ast.FunctionDef,
                  statics: Set[str]) -> Iterable[Finding]:
        traced = {p for p in _param_names(fn) if p not in statics}
        aliases = import_aliases(ctx.tree)
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                refs = _traced_refs(node.test, traced)
                if refs:
                    yield ctx.finding(
                        self.id, node,
                        f"Python `{type(node).__name__.lower()}` on "
                        f"traced value `{refs[0].id}` inside "
                        f"`{fn.name}`: branches must be static or go "
                        f"through lax.cond/jnp.where")
            elif isinstance(node, ast.Call):
                name = resolve(node.func, aliases)
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item":
                    yield ctx.finding(
                        self.id, node,
                        f"`.item()` inside traced `{fn.name}` forces a "
                        f"host sync / fails under jit")
                elif name in ("float", "int", "bool") and node.args \
                        and _traced_refs(node.args[0], traced):
                    yield ctx.finding(
                        self.id, node,
                        f"`{name}()` coerces traced value inside "
                        f"`{fn.name}`; keep it as an array or make the "
                        f"arg static")
                elif name is not None and \
                        name.split(".")[-1] in _HOST_CALLBACKS:
                    yield ctx.finding(
                        self.id, node,
                        f"host callback `{name}` inside traced "
                        f"`{fn.name}` breaks pure compiled dispatch")


# ------------------------------------------------------------------ R006

_CACHED_DISPATCH = ("tabu_search_jax", "tabu_search_batched")
_DISPATCH_HOME = ("core/scheduler.py", "core/scheduler_jax.py")
_AOT_ATTRS = {"lower", "trace", "eval_shape"}


class JitDispatchBypassRule(Rule):
    """`jax.jit(f)(x)` builds a FRESH jit wrapper per call — every
    invocation retraces and recompiles. Hoist the jitted callable to a
    module/instance attribute. Likewise, calling the raw jitted
    scheduler kernels (`tabu_search_jax`/`tabu_search_batched`)
    anywhere but `scheduler.search`'s dispatcher bypasses the
    `_COMPILED_SHAPES` bucketed compile cache (DESIGN.md §3.3/§12) —
    shapes stop being bucketed and the retrace cost comes back.
    AOT use (`jax.jit(f).lower(...)`) is exempt: lowering is an
    explicit one-shot compile."""
    id = "R006"
    title = "jit dispatch bypassing the bucketed compile cache"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        aliases = import_aliases(ctx.tree)
        aot: Set[ast.Call] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute) and \
                    node.attr in _AOT_ATTRS and \
                    isinstance(node.value, ast.Call) and \
                    resolve(node.value.func, aliases) == "jax.jit":
                aot.add(node.value)
        in_home = any(ctx.path.endswith(h) for h in _DISPATCH_HOME)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            # (a) immediately-invoked jax.jit(f)(...)
            if isinstance(node.func, ast.Call) and \
                    resolve(node.func.func, aliases) == "jax.jit" and \
                    node.func not in aot:
                yield ctx.finding(
                    self.id, node,
                    "`jax.jit(f)(...)` builds a fresh wrapper per call "
                    "and retraces every time; hoist the jitted "
                    "callable")
            # (b) raw scheduler-kernel calls outside the dispatcher
            name = resolve(node.func, aliases)
            if name is not None and not in_home and \
                    name.split(".")[-1] in _CACHED_DISPATCH:
                yield ctx.finding(
                    self.id, node,
                    f"direct `{name.split('.')[-1]}` call bypasses "
                    f"scheduler.search's _COMPILED_SHAPES bucketed "
                    f"dispatch (retrace hazard); route through "
                    f"scheduler.search/search_batched")


ALL_RULES: Tuple[Rule, ...] = (
    BareAssertRule(), WallClockRule(), UnseededRNGRule(),
    SetIterationRule(), TracedPythonLeakRule(), JitDispatchBypassRule())

RULES_BY_ID: Dict[str, Rule] = {r.id: r for r in ALL_RULES}
