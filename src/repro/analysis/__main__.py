"""``python -m repro.analysis [paths ...]`` — run reprolint.

Exit status: 0 when clean, 1 when any finding survives suppression,
2 on usage errors. ``--format json`` emits a machine-readable report
(CI uploads it as an artifact); ``--output`` writes the report to a
file while the human summary still goes to stdout.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from pathlib import Path

from repro.analysis.linter import iter_python_files, lint_paths
from repro.analysis.rules import ALL_RULES, RULES_BY_ID


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: determinism/invariant static analysis "
                    "(rules R001-R006, DESIGN.md §14)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run "
                         "(default: all)")
    ap.add_argument("--format", choices=("human", "json"),
                    default="human")
    ap.add_argument("--output", default=None,
                    help="write the report to this file as well")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.id}  {r.title}")
        return 0

    rules = list(ALL_RULES)
    if args.rules:
        ids = [s.strip() for s in args.rules.split(",") if s.strip()]
        unknown = [i for i in ids if i not in RULES_BY_ID]
        if unknown:
            print(f"unknown rule ids: {unknown} "
                  f"(known: {sorted(RULES_BY_ID)})", file=sys.stderr)
            return 2
        rules = [RULES_BY_ID[i] for i in ids]

    paths = [Path(p) for p in (args.paths or ["src"])]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"no such path: {missing}", file=sys.stderr)
        return 2
    findings = lint_paths(paths, rules)
    n_files = sum(1 for _ in iter_python_files(paths))

    counts = Counter(f.rule for f in findings)
    report = {"files": n_files,
              "rules": [r.id for r in rules],
              "counts": dict(sorted(counts.items())),
              "findings": [f.as_dict() for f in findings]}
    rendered_json = json.dumps(report, indent=2)

    if args.format == "json":
        print(rendered_json)
    else:
        for f in findings:
            print(f.human())
        tally = ", ".join(f"{k}: {v}" for k, v in sorted(counts.items()))
        print(f"reprolint: {len(findings)} finding(s) in {n_files} "
              f"file(s)" + (f" [{tally}]" if tally else ""))
    if args.output:
        Path(args.output).write_text(rendered_json + "\n",
                                     encoding="utf-8")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
