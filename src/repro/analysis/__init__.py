"""Reprolint — determinism/invariant static analysis (DESIGN.md §14).

Run it as ``python -m repro.analysis src``. The companion RUNTIME
checker — the metro-engine sanitizer — lives in `repro.metro.sanitizer`
and is enabled per run via ``MetroEngine.run(sanitize=True)``.
"""
from repro.analysis.linter import (FileContext, Finding, Rule, lint_file,
                                   lint_paths)
from repro.analysis.rules import ALL_RULES, RULES_BY_ID

__all__ = ["ALL_RULES", "RULES_BY_ID", "FileContext", "Finding", "Rule",
           "lint_file", "lint_paths"]
