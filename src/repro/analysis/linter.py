"""Reprolint core — a tiny AST lint framework for determinism and
invariant hazards (DESIGN.md §14).

The repo's verification story (chaos-pack CRC determinism, ranking
invariants, bit-identical parity oracles) rests on properties nothing
used to check mechanically: guards that survive ``python -O``, no
wall-clock reads inside simulation logic, seeded RNG everywhere, no
hash-order iteration feeding event ordering, no Python leaking into
traced JAX code, and no jit dispatch that bypasses the bucketed
compile cache. Each of those is a bug class this repo has fixed by
hand at least once (PRs 3/4/6); reprolint codifies them as rules
R001–R006 (see `repro.analysis.rules`) so CI catches the next
regression at lint time.

Framework contract:

* A rule is a `Rule` subclass with a unique ``id`` ("R001"), a
  one-line ``title``, and a ``check(ctx)`` generator yielding
  `Finding`s. `FileContext` hands it the parsed AST, the source lines
  and the repo-relative posix path (rules scope themselves by path —
  e.g. R002 only fires under ``metro/`` and ``core/``).
* Suppression is per-line and per-rule: ``# reprolint: disable=R002``
  on the finding's line (or the line directly above, for lines with no
  room) suppresses that rule there; ``# reprolint: disable`` with no
  ids suppresses every rule on that line. There is no file-level or
  block-level suppression — a hazard is either fixed, or visibly
  waived exactly where it lives.
* `lint_paths` walks ``*.py`` files, runs every rule, filters
  suppressed findings and returns the survivors sorted by location.
  Files that fail to parse yield an ``E000`` finding (a syntax error
  is never silently skipped).

The module is stdlib-only (ast + pathlib) so the CI lint step needs no
jax/numpy install.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*disable(?:=(?P<ids>[A-Z0-9, ]+))?")


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""
    rule: str
    path: str            # repo-relative posix path
    line: int            # 1-indexed
    col: int             # 0-indexed (ast convention)
    message: str

    def human(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"

    def as_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}


@dataclass
class FileContext:
    """Everything a rule may look at for one file."""
    path: str                    # repo-relative posix path
    source: str
    tree: ast.AST
    lines: List[str] = field(default_factory=list)

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule=rule, path=self.path,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0),
                       message=message)

    def in_dir(self, *parts: str) -> bool:
        """True when the file lives under any of the given package
        directories (matched as path segments, e.g. "metro")."""
        segs = self.path.split("/")
        return any(p in segs for p in parts)


class Rule:
    """Base rule. Subclasses set `id`/`title` and implement check()."""
    id = "R000"
    title = ""

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        raise NotImplementedError


def _suppressions(lines: Sequence[str]) -> Dict[int, Optional[Set[str]]]:
    """Map 1-indexed line number -> suppressed rule ids (None = all).
    A directive covers its own line and the line directly below it
    (for findings whose statement had no room for a trailing comment)."""
    out: Dict[int, Optional[Set[str]]] = {}

    def add(n: int, ids: Optional[Set[str]]) -> None:
        if ids is None or out.get(n, set()) is None:
            out[n] = None
        else:
            out.setdefault(n, set()).update(ids)

    for n, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        raw = m.group("ids")
        ids = None if raw is None else {
            s.strip() for s in raw.split(",") if s.strip()}
        covers = (n, n + 1) if text.lstrip().startswith("#") else (n,)
        for c in covers:
            add(c, ids)
    return out


def _suppressed(f: Finding,
                supp: Dict[int, Optional[Set[str]]]) -> bool:
    ids = supp.get(f.line, set())
    return ids is None or f.rule in ids


def lint_file(path: Path, rules: Sequence[Rule],
              rel: str) -> List[Finding]:
    source = path.read_text(encoding="utf-8")
    lines = source.splitlines()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [Finding(rule="E000", path=rel, line=e.lineno or 1,
                        col=e.offset or 0,
                        message=f"syntax error: {e.msg}")]
    ctx = FileContext(path=rel, source=source, tree=tree, lines=lines)
    supp = _suppressions(lines)
    found: List[Finding] = []
    for rule in rules:
        for f in rule.check(ctx):
            if not _suppressed(f, supp):
                found.append(f)
    return found


def iter_python_files(paths: Sequence[Path]) -> Iterator[Path]:
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            yield from sorted(p.rglob("*.py"))


def lint_paths(paths: Sequence[Path], rules: Sequence[Rule],
               root: Optional[Path] = None) -> List[Finding]:
    """Lint every ``*.py`` under `paths`; paths in findings are
    relative to `root` (default: the current working directory when
    possible, else absolute)."""
    root = root or Path.cwd()
    findings: List[Finding] = []
    for f in iter_python_files([Path(p) for p in paths]):
        try:
            rel = f.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        findings.extend(lint_file(f, rules, rel))
    findings.sort(key=lambda x: (x.path, x.line, x.col, x.rule))
    return findings


# ---------------------------------------------------------- AST helpers

def dotted_name(node: ast.AST) -> Optional[str]:
    """"a.b.c" for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.AST) -> Dict[str, str]:
    """Map local alias -> imported dotted module/name.

    ``import numpy as np``          -> {"np": "numpy"}
    ``import numpy.random as npr``  -> {"npr": "numpy.random"}
    ``from numpy import random``    -> {"random": "numpy.random"}
    ``from random import choice``   -> {"choice": "random.choice"}
    """
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def resolve(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Dotted name with the leading alias expanded through the file's
    imports: with ``import numpy as np``, `np.random.rand` resolves to
    "numpy.random.rand"."""
    name = dotted_name(node)
    if name is None:
        return None
    head, _, tail = name.partition(".")
    base = aliases.get(head)
    if base is None:
        return name
    return f"{base}.{tail}" if tail else base
