"""The paper's ICU LSTM workloads (Edge AIBench, Table IV).

LSTM classifier over clinical time series: (B, T, features) -> class logits.
The per-step cell is the Pallas fused kernel (kernels.ops.lstm_step) scanned
over time — the exact compute the paper's allocator places on a tier.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.icu_lstm import ICULSTMConfig
from repro.kernels import ops
from repro.models import common


class ICULSTM:
    def __init__(self, cfg: ICULSTMConfig):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        layers = []
        k_head = key
        in_dim = cfg.input_dim
        for i in range(cfg.depth):
            k_head, kx, kh = jax.random.split(k_head, 3)
            layers.append({
                "wx": common.dense_init(kx, in_dim, 4, cfg.hidden),
                "wh": common.dense_init(kh, cfg.hidden, 4, cfg.hidden),
                "b": jnp.zeros((4, cfg.hidden)),
            })
            in_dim = cfg.hidden
        k_head, kw = jax.random.split(k_head)
        return {"layers": layers,
                "head": common.dense_init(kw, cfg.hidden, cfg.num_classes),
                "head_b": jnp.zeros((cfg.num_classes,))}

    def param_specs(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def forward(self, p, x):
        """x: (B, T, input_dim) -> logits (B, num_classes)."""
        cfg = self.cfg
        bsz = x.shape[0]
        h_seq = x
        for layer in p["layers"]:
            h0 = jnp.zeros((bsz, cfg.hidden), x.dtype)
            c0 = jnp.zeros((bsz, cfg.hidden), x.dtype)

            def step(carry, xt, layer=layer):
                h, c = carry
                h, c = ops.lstm_step(xt, h, c, layer["wx"], layer["wh"],
                                     layer["b"])
                return (h, c), h

            (h, _), hs = jax.lax.scan(step, (h0, c0),
                                      jnp.moveaxis(h_seq, 1, 0))
            h_seq = jnp.moveaxis(hs, 0, 1)
        return h @ p["head"] + p["head_b"]

    def loss(self, p, batch):
        logits = self.forward(p, batch["features"])
        labels = batch["labels"]
        if self.cfg.num_classes == 2 and labels.ndim == 1:
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, labels[:, None],
                                                 axis=-1))
        # multi-label (phenotype): sigmoid BCE over num_classes
        z = logits.astype(jnp.float32)
        y = labels.astype(jnp.float32)
        return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
