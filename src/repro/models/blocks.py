"""Block library: every block kind in configs.base.BLOCK_KINDS.

Uniform interface:
    init_block(kind, key, cfg)                      -> params pytree
    apply_block(kind, p, x, ctx, cache, mode)       -> (x', cache', aux)

mode in {"train", "prefill", "decode"}. ctx carries positions / decode pos /
cross states / shared weights. aux is a dict of scalars (MoE load-balance).
Caches are pytrees of arrays; `empty_block_cache` builds decode caches.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import attention, common
from repro.sharding import policy

LORA_RANK = 64  # zamba2 per-block adapters on the shared attention weights


# =============================================================== dense / attn
def _init_attn_mlp(key, cfg: ModelConfig, cross=False):
    k1, k2 = jax.random.split(key)
    return {"attn": attention.attn_init(k1, cfg, cross=cross),
            "mlp": common.mlp_init(k2, cfg)}


def _attn_window(kind: str, cfg: ModelConfig) -> Optional[int]:
    if kind == base.ATTN_LOCAL:
        return cfg.attn_window
    if kind == base.ATTN_GLOBAL:
        return None
    # plain ATTN / MOE: cfg.attn_window if the arch is natively SWA (mixtral),
    # else the explicit long-context variant window, else full.
    return cfg.attn_window or cfg.long_context_window


def _apply_attn_block(kind, p, x, ctx, cache, mode):
    cfg = ctx["cfg"]
    window = _attn_window(kind, cfg)
    if mode == "decode":
        x, cache_a = attention.attn_decode(p["attn"], x, cache["attn"],
                                           ctx["pos"], cfg, window=window)
        x = common.mlp_apply(p["mlp"], x, cfg)
        return x, {"attn": cache_a}, {}
    x, cache_a = attention.attn_full(
        p["attn"], x, cfg, window=window, positions=ctx.get("positions"),
        causal=ctx.get("causal", True), make_cache=(mode == "prefill"),
        cache_len=ctx.get("cache_len", 0))
    x = common.mlp_apply(p["mlp"], x, cfg)
    cache = {"attn": cache_a} if mode == "prefill" else None
    return x, cache, {}


# ======================================================================== moe
def _init_moe(key, cfg: ModelConfig):
    ka, kr, ke = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    keys = jax.random.split(ke, 3)
    w_gate = common.dense_init(keys[0], d, e, f, dtype=dtype).transpose(1, 0, 2)
    w_up = common.dense_init(keys[1], d, e, f, dtype=dtype).transpose(1, 0, 2)
    w_down = common.dense_init(keys[2], f, e, d, dtype=dtype).transpose(1, 0, 2)
    if cfg.moe_ep_shards:
        # EP-major storage: (E*r, d, f/r) / (E*r, f/r, d), leading dim on
        # "model" (sharding/ep_moe.py) — zero weight movement at use
        r = cfg.moe_ep_shards
        fr = f // r
        split_f = lambda w: (w.reshape(e, d, r, fr).transpose(0, 2, 1, 3)
                             .reshape(e * r, d, fr))
        split_f0 = lambda w: (w.reshape(e, r, fr, d).reshape(e * r, fr, d))
        experts = {"ep_gate": split_f(w_gate), "ep_up": split_f(w_up),
                   "ep_down": split_f0(w_down)}
    else:
        experts = {"w_gate": w_gate, "w_up": w_up, "w_down": w_down}
    return {"attn": attention.attn_init(ka, cfg),
            "moe_norm": common.norm_init(d, dtype),
            "router": common.dense_init(kr, d, e, dtype=jnp.float32),
            "experts": experts}


def _moe_ffn(p, x, cfg: ModelConfig):
    """Dropless-ish top-k MoE with per-row capacity via sort-based dispatch.

    x: (B, S, d). Sort/gather dispatch (no one-hot einsums) keeps HLO FLOPs
    ~= active-expert FLOPs x capacity_factor, so the roofline "useful ratio"
    stays honest. All index ops are per-row => no cross-shard comms when the
    batch is data-sharded; expert weights are TP-sharded on "model" by
    default (EP all-to-all variant lives in sharding/ep_moe.py).
    """
    bsz, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    # dispatch in groups of <= 2048 tokens (GShard-style) so the (E*cap, d)
    # expert buffer and the (E, cap, d_ff) activations stay bounded at long
    # sequence lengths (32k-prefill TP-MoE temp: 45.7 -> ~25 GB on 8x22b)
    g = s
    while g > 2048:
        if s % (g // 2):
            break
        g //= 2
    # capacity >= k so single-token decode never drops an expert
    cap = max(k, int(math.ceil(k * g / e * cfg.moe_capacity_factor)))

    h = common.rms_norm(x, p["moe_norm"], cfg.norm_eps)
    we = p["experts"]
    if "ep_gate" in we:
        mesh = policy.current_mesh()
        if mesh is not None and mesh.shape.get("model", 1) == \
                e * cfg.moe_ep_shards:
            from repro.sharding.ep_moe import ep_moe_ffn
            y, aux = ep_moe_ffn(we, p["router"], h, cfg, mesh)
            return x + y.astype(x.dtype), aux
        # no mesh (CPU tests): reconstruct the logical (E, d, f) weights
        r = cfg.moe_ep_shards
        fr = cfg.d_ff // r
        we = {
            "w_gate": we["ep_gate"].reshape(e, r, d, fr)
            .transpose(0, 2, 1, 3).reshape(e, d, cfg.d_ff),
            "w_up": we["ep_up"].reshape(e, r, d, fr)
            .transpose(0, 2, 1, 3).reshape(e, d, cfg.d_ff),
            "w_down": we["ep_down"].reshape(e, cfg.d_ff, d),
        }
    logits = h.astype(jnp.float32) @ p["router"]              # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                    # (B, S, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    def dispatch_group(h_row, ids_row, w_row):
        # h_row: (g, d); ids_row/w_row: (g, k)
        flat_e = ids_row.reshape(-1)                          # (g*k,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        # rank within expert among sorted copies
        counts = jnp.bincount(sorted_e, length=e)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(g * k) - starts[sorted_e]
        keep = rank < cap
        slot = jnp.where(keep, sorted_e * cap + rank, e * cap)  # drop bucket
        tok = order // k
        buf = jnp.zeros((e * cap + 1, d), h_row.dtype)
        buf = buf.at[slot].add(h_row[tok] * keep[:, None].astype(h_row.dtype))
        buf = buf[:-1].reshape(e, cap, d)
        act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we["w_gate"]))
        out = act * jnp.einsum("ecd,edf->ecf", buf, we["w_up"])
        out = jnp.einsum("ecf,efd->ecd", out, we["w_down"])
        out_flat = out.reshape(e * cap, d)
        w_sorted = w_row.reshape(-1)[order]
        contrib = (out_flat[jnp.where(keep, slot, 0)]
                   * (w_sorted * keep).astype(out_flat.dtype)[:, None])
        y = jnp.zeros((g, d), out_flat.dtype).at[tok].add(contrib)
        return y

    rows = bsz * s // g
    hr = h.reshape(rows, g, d)
    er = top_e.reshape(rows, g, k)
    wr = top_w.reshape(rows, g, k)
    chunk = 8
    if rows > chunk and rows % chunk == 0:
        # sequential map over row-chunks: a flat vmap materialises EVERY
        # row's (E*cap, d)/(E, cap, d_ff) buffers at once (38-46 GB/chip
        # at 32k prefill); lax.map bounds the live set to one chunk, and
        # remat keeps the bwd from saving per-chunk intermediates
        body = jax.checkpoint(
            lambda args: jax.vmap(dispatch_group)(*args))
        y = jax.lax.map(body, (hr.reshape(rows // chunk, chunk, g, d),
                               er.reshape(rows // chunk, chunk, g, k),
                               wr.reshape(rows // chunk, chunk, g, k)))
        y = y.reshape(bsz, s, d)
    else:
        y = jax.vmap(dispatch_group)(hr, er, wr).reshape(bsz, s, d)
    # load-balance aux (Switch-style): E * sum_e f_e * P_e
    frac = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * mean_p)
    return x + y.astype(x.dtype), aux


def _apply_moe_block(p, x, ctx, cache, mode):
    cfg = ctx["cfg"]
    window = _attn_window(base.MOE, cfg)
    if mode == "decode":
        x, cache_a = attention.attn_decode(p["attn"], x, cache["attn"],
                                           ctx["pos"], cfg, window=window)
    else:
        x, cache_a = attention.attn_full(
            p["attn"], x, cfg, window=window, positions=ctx.get("positions"),
            make_cache=(mode == "prefill"), cache_len=ctx.get("cache_len", 0))
    x, aux = _moe_ffn(p, x, cfg)
    cache = {"attn": cache_a} if mode in ("prefill", "decode") else None
    return x, cache, {"moe_aux": aux}


# ===================================================================== mamba2
def _init_mamba(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    h, n = cfg.ssm_num_heads, cfg.ssm_state_dim
    conv_dim = d_inner + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "norm": common.norm_init(d, dtype),
        "w_in": common.dense_init(ks[0], d, 2 * d_inner + 2 * n + h,
                                  dtype=dtype),
        "conv": common.causal_conv_init(ks[1], conv_dim, cfg.ssm_conv_width,
                                        dtype=dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),     # A = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_gate": common.norm_init(d_inner, dtype),
        "w_out": common.dense_init(ks[3], d_inner, d, dtype=dtype),
    }


def _mamba_split(p, cfg, zxbcdt):
    d_inner = cfg.ssm_expand * cfg.d_model
    n, h = cfg.ssm_state_dim, cfg.ssm_num_heads
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * n]
    dt_raw = zxbcdt[..., -h:]
    return z, xbc, dt_raw


def _apply_mamba(p, x, ctx, cache, mode):
    cfg = ctx["cfg"]
    d_inner = cfg.ssm_expand * cfg.d_model
    n, h = cfg.ssm_state_dim, cfg.ssm_num_heads
    ph = cfg.ssm_head_dim
    bsz, l, _ = x.shape

    hid = common.rms_norm(x, p["norm"], cfg.norm_eps)
    z, xbc, dt_raw = _mamba_split(p, cfg, hid @ p["w_in"])
    conv_state = cache["conv"] if mode == "decode" else None
    xbc, conv_state = common.causal_conv_apply(p["conv"], xbc, conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_inner].reshape(bsz, l, h, ph)
    b_mat = xbc[..., d_inner:d_inner + n]
    c_mat = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    if mode == "decode":
        # single-step recurrence
        state = cache["ssm"]                                   # (B, H, P, N)
        dt1 = dt[:, 0]                                         # (B, H)
        decay = jnp.exp(dt1 * a[None])                         # (B, H)
        upd = jnp.einsum("bhp,bn->bhpn",
                         xs[:, 0].astype(jnp.float32) * dt1[..., None],
                         b_mat[:, 0].astype(jnp.float32))
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state,
                       c_mat[:, 0].astype(jnp.float32))
        y = y + xs[:, 0].astype(jnp.float32) * p["d_skip"][None, :, None]
        y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
        new_cache = {"conv": conv_state, "ssm": state}
    else:
        y, final_state = ops.ssm(xs, dt, a, b_mat, c_mat, p["d_skip"],
                                 chunk=cfg.ssm_chunk)
        y = y.reshape(bsz, l, d_inner)
        new_cache = ({"conv": conv_state, "ssm": final_state}
                     if mode == "prefill" else None)

    y = y * jax.nn.silu(z)
    y = common.rms_norm(y, p["norm_gate"], cfg.norm_eps)
    return x + y @ p["w_out"], new_cache, {}


# ============================================================== shared attn
def _init_shared_lora(key, cfg: ModelConfig):
    """Per-group LoRA adapters over the shared attention block (zamba2)."""
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {
        "lora_a": common.dense_init(k1, d, LORA_RANK, dtype=dtype),
        "lora_b": jnp.zeros((LORA_RANK, d), dtype),
    }


def _apply_shared_attn(lora_p, x, ctx, cache, mode):
    """Shared full-attention block (one weight set reused across groups),
    specialised per group by a LoRA residual on the block input."""
    cfg = ctx["cfg"]
    shared = ctx["shared_attn"]
    x = x + (x @ lora_p["lora_a"]) @ lora_p["lora_b"]
    window = cfg.long_context_window  # zamba2 shared attn is full by default
    if mode == "decode":
        x, cache_a = attention.attn_decode(shared["attn"], x, cache["attn"],
                                           ctx["pos"], cfg, window=window)
        x = common.mlp_apply(shared["mlp"], x, cfg)
        return x, {"attn": cache_a}, {}
    x, cache_a = attention.attn_full(
        shared["attn"], x, cfg, window=window,
        positions=ctx.get("positions"), make_cache=(mode == "prefill"),
        cache_len=ctx.get("cache_len", 0))
    x = common.mlp_apply(shared["mlp"], x, cfg)
    return x, ({"attn": cache_a} if mode == "prefill" else None), {}


# ================================================================ cross attn
def _apply_cross(p, x, ctx, cache, mode):
    cfg = ctx["cfg"]
    if mode == "decode":
        x, _ = attention.attn_decode(p["attn"], x, cache["attn"], ctx["pos"],
                                     cfg, cross=True)
        x = common.mlp_apply(p["mlp"], x, cfg)
        return x, cache, {}
    x, cache_a = attention.attn_full(
        p["attn"], x, cfg, cross_states=ctx["cross_states"],
        make_cache=False)
    if mode == "prefill":
        # cross KV depends only on the (static) cross states: build once
        states = ctx["cross_states"]
        k = jnp.einsum("bld,dhe->bhle", states, p["attn"]["wk"])
        v = jnp.einsum("bld,dhe->bhle", states, p["attn"]["wv"])
        cache_a = {"k": k, "v": v}
    x = common.mlp_apply(p["mlp"], x, cfg)
    return x, ({"attn": cache_a} if mode == "prefill" else None), {}


# ====================================================================== xLSTM
def _init_mlstm(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    h = cfg.ssm_num_heads
    ks = jax.random.split(key, 7)
    return {
        "norm": common.norm_init(d, dtype),
        "w_up": common.dense_init(ks[0], d, 2 * d_inner, dtype=dtype),
        "conv": common.causal_conv_init(ks[1], d_inner, cfg.ssm_conv_width,
                                        dtype=dtype),
        "wq": common.dense_init(ks[2], d_inner, d_inner, dtype=dtype),
        "wk": common.dense_init(ks[3], d_inner, d_inner, dtype=dtype),
        "wv": common.dense_init(ks[4], d_inner, d_inner, dtype=dtype),
        "w_gates": common.dense_init(ks[5], d_inner, 2 * h, dtype=jnp.float32),
        "gate_bias": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]),
        "norm_out": common.norm_init(d_inner, dtype),
        "w_down": common.dense_init(ks[6], d_inner, d, dtype=dtype),
    }


def _apply_mlstm(p, x, ctx, cache, mode):
    cfg = ctx["cfg"]
    d_inner = cfg.ssm_expand * cfg.d_model
    h = cfg.ssm_num_heads
    ph = d_inner // h
    bsz, l, _ = x.shape

    hid = common.rms_norm(x, p["norm"], cfg.norm_eps)
    up = hid @ p["w_up"]
    xin, z = up[..., :d_inner], up[..., d_inner:]
    conv_state = cache["conv"] if mode == "decode" else None
    cx, conv_state = common.causal_conv_apply(p["conv"], xin, conv_state)
    cx = jax.nn.silu(cx)
    # cell inputs are dp-sharded on batch, replicated elsewhere (the mLSTM
    # matrix memory is computed locally per batch shard — §Perf iter 2.3)
    bld = (policy.DP, None, None)
    q = policy.constrain((cx @ p["wq"]), bld).reshape(bsz, l, h, ph)
    k = policy.constrain((cx @ p["wk"]), bld).reshape(bsz, l, h, ph)
    v = policy.constrain((xin @ p["wv"]), bld).reshape(bsz, l, h, ph)
    gates = policy.constrain(
        cx.astype(jnp.float32) @ p["w_gates"], bld) + p["gate_bias"]
    ig, fg = gates[..., :h], gates[..., h:]

    if mode == "decode":
        c0, n0, m0 = cache["c"], cache["n"], cache["m"]
        scale = 1.0 / math.sqrt(ph)
        qt = q[:, 0].astype(jnp.float32)
        kt = k[:, 0].astype(jnp.float32) * scale
        vt = v[:, 0].astype(jnp.float32)
        it, ft = ig[:, 0], fg[:, 0]
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m0, it)
        fdec = jnp.exp(log_f + m0 - m_new)
        iamp = jnp.exp(it - m_new)
        c = c0 * fdec[..., None, None] + iamp[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", kt, vt)
        nvec = n0 * fdec[..., None] + iamp[..., None] * kt
        num = jnp.einsum("bhde,bhd->bhe", c, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", nvec, qt)),
                          jnp.exp(-m_new))
        y = (num / den[..., None]).reshape(bsz, 1, d_inner).astype(x.dtype)
        new_cache = {"conv": conv_state, "c": c, "n": nvec, "m": m_new}
    else:
        y, (c, nvec, m) = ops.mlstm(q, k, v, ig, fg, chunk=cfg.ssm_chunk
                                    if cfg.ssm_chunk <= 64 else 64)
        y = y.reshape(bsz, l, d_inner)
        new_cache = ({"conv": conv_state, "c": c, "n": nvec, "m": m}
                     if mode == "prefill" else None)

    y = y * jax.nn.silu(z)
    y = common.rms_norm(y, p["norm_out"], cfg.norm_eps)
    return x + y @ p["w_down"], new_cache, {}


def _init_slstm(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    h = cfg.num_heads
    ph = d // h
    ks = jax.random.split(key, 4)
    return {
        "norm": common.norm_init(d, dtype),
        "conv": common.causal_conv_init(ks[0], d, cfg.ssm_conv_width,
                                        dtype=dtype),
        "w_gates": common.dense_init(ks[1], d, 4 * d, dtype=dtype),
        "r_gates": (jax.random.normal(ks[2], (4, h, ph, ph), jnp.float32)
                    / math.sqrt(ph)).astype(dtype),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)), jnp.zeros((d,))]),
        "norm_out": common.norm_init(d, dtype),
        "w_up": common.dense_init(ks[3], d, 2 * cfg.d_model, dtype=dtype),
        "w_down": common.dense_init(jax.random.fold_in(ks[3], 1),
                                    cfg.d_model, d, dtype=dtype),
    }


def _slstm_step(p, cfg, xg_t, state):
    """xg_t: (B, 4d) input gate preactivations; state: (h, c, n, m)."""
    h_prev, c_prev, n_prev, m_prev = state
    d = cfg.d_model
    nh = cfg.num_heads
    ph = d // nh
    bsz = xg_t.shape[0]
    hp = h_prev.reshape(bsz, nh, ph)
    rec = jnp.einsum("bhp,ghpq->bghq", hp,
                     p["r_gates"].astype(jnp.float32)).reshape(bsz, 4 * d)
    g = xg_t + rec + p["gate_bias"]
    zt = jnp.tanh(g[..., 0:d])
    it = g[..., d:2 * d]
    ft = g[..., 2 * d:3 * d]
    ot = jax.nn.sigmoid(g[..., 3 * d:])
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m_prev, it)
    i_act = jnp.exp(it - m_new)
    f_act = jnp.exp(log_f + m_prev - m_new)
    c_new = f_act * c_prev + i_act * zt
    n_new = f_act * n_prev + i_act
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return h_new, c_new, n_new, m_new


def _apply_slstm(p, x, ctx, cache, mode):
    cfg = ctx["cfg"]
    d = cfg.d_model
    bsz, l, _ = x.shape
    hid = common.rms_norm(x, p["norm"], cfg.norm_eps)
    conv_state = cache["conv"] if mode == "decode" else None
    cx, conv_state = common.causal_conv_apply(p["conv"], hid, conv_state)
    cx = jax.nn.silu(cx)
    xg = (cx @ p["w_gates"]).astype(jnp.float32)               # (B, L, 4d)

    if mode == "decode":
        state = (cache["h"], cache["c"], cache["n"], cache["m"])
        state = _slstm_step(p, cfg, xg[:, 0], state)
        y = state[0][:, None, :]
        new_cache = {"conv": conv_state, "h": state[0], "c": state[1],
                     "n": state[2], "m": state[3]}
    else:
        init = tuple(jnp.zeros((bsz, d), jnp.float32) for _ in range(3)) + (
            jnp.full((bsz, d), -1e30, jnp.float32),)

        def step(s, xt):
            s = _slstm_step(p, cfg, xt, s)
            return s, s[0]

        state, ys = jax.lax.scan(step, init, jnp.moveaxis(xg, 1, 0))
        y = jnp.moveaxis(ys, 0, 1)
        new_cache = ({"conv": conv_state, "h": state[0], "c": state[1],
                      "n": state[2], "m": state[3]}
                     if mode == "prefill" else None)

    y = common.rms_norm(y.astype(x.dtype), p["norm_out"], cfg.norm_eps)
    up = y @ p["w_up"]
    half = cfg.d_model
    y = jax.nn.gelu(up[..., :half]) * up[..., half:]
    return x + y @ p["w_down"], new_cache, {}


# ================================================================= dispatch
_INIT = {
    base.ATTN: _init_attn_mlp,
    base.ATTN_LOCAL: _init_attn_mlp,
    base.ATTN_GLOBAL: _init_attn_mlp,
    base.MOE: _init_moe,
    base.MAMBA: _init_mamba,
    base.SHARED_ATTN: _init_shared_lora,
    base.CROSS: lambda k, c: _init_attn_mlp(k, c, cross=True),
    base.SLSTM: _init_slstm,
    base.MLSTM: _init_mlstm,
}


def init_block(kind: str, key, cfg: ModelConfig):
    return _INIT[kind](key, cfg)


def apply_block(kind: str, p, x, ctx, cache, mode: str):
    if kind in (base.ATTN, base.ATTN_LOCAL, base.ATTN_GLOBAL):
        return _apply_attn_block(kind, p, x, ctx, cache, mode)
    if kind == base.MOE:
        return _apply_moe_block(p, x, ctx, cache, mode)
    if kind == base.MAMBA:
        return _apply_mamba(p, x, ctx, cache, mode)
    if kind == base.SHARED_ATTN:
        return _apply_shared_attn(p, x, ctx, cache, mode)
    if kind == base.CROSS:
        return _apply_cross(p, x, ctx, cache, mode)
    if kind == base.SLSTM:
        return _apply_slstm(p, x, ctx, cache, mode)
    if kind == base.MLSTM:
        return _apply_mlstm(p, x, ctx, cache, mode)
    raise ValueError(kind)


def empty_block_cache(kind: str, cfg: ModelConfig, batch: int, cache_len: int,
                      dtype) -> dict:
    """Zero decode cache for one block."""
    d_inner = cfg.ssm_expand * cfg.d_model
    if kind in (base.ATTN, base.ATTN_LOCAL, base.ATTN_GLOBAL, base.MOE,
                base.SHARED_ATTN):
        window = _attn_window(kind, cfg)
        if kind == base.SHARED_ATTN:
            window = cfg.long_context_window
        return {"attn": attention.empty_cache(batch, cfg, cache_len, window,
                                              dtype)}
    if kind == base.CROSS:
        shape = (batch, cfg.num_kv_heads, cfg.cross_attn_states, cfg.head_dim)
        return {"attn": {"k": jnp.zeros(shape, dtype),
                         "v": jnp.zeros(shape, dtype)}}
    if kind == base.MAMBA:
        n, h, ph = cfg.ssm_state_dim, cfg.ssm_num_heads, cfg.ssm_head_dim
        conv_dim = d_inner + 2 * n
        return {"conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim),
                                  dtype),
                "ssm": jnp.zeros((batch, h, ph, n), jnp.float32)}
    if kind == base.MLSTM:
        h = cfg.ssm_num_heads
        ph = d_inner // h
        return {"conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, d_inner),
                                  dtype),
                "c": jnp.zeros((batch, h, ph, ph), jnp.float32),
                "n": jnp.zeros((batch, h, ph), jnp.float32),
                "m": jnp.full((batch, h), -1e30, jnp.float32)}
    if kind == base.SLSTM:
        d = cfg.d_model
        return {"conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, d), dtype),
                "h": jnp.zeros((batch, d), jnp.float32),
                "c": jnp.zeros((batch, d), jnp.float32),
                "n": jnp.zeros((batch, d), jnp.float32),
                "m": jnp.full((batch, d), -1e30, jnp.float32)}
    raise ValueError(kind)
