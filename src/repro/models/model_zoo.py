"""build_model(cfg): uniform entry point for every assigned architecture."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.decoder import DecoderModel
from repro.models.encdec import EncDecModel


def build_model(cfg: ModelConfig, remat: bool = False):
    if cfg.is_encdec:
        return EncDecModel(cfg, remat=remat)
    return DecoderModel(cfg, remat=remat)
