"""Shared building blocks: norms, RoPE, MLPs, causal conv, init helpers.

All models are plain pytrees of jnp arrays + pure apply functions (no flax).
Param leaf names are load-bearing: sharding/sharding.py assigns
PartitionSpecs by matching (path, shape) rules.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------- init utils
def dense_init(key, in_dim: int, *out_dims: int, dtype=jnp.float32):
    shape = (in_dim, *out_dims)
    std = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim), jnp.float32)
            * (1.0 / math.sqrt(dim))).astype(dtype)


# ---------------------------------------------------------------------- norm
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def norm_init(dim: int, dtype=jnp.float32):
    # stored as (gamma - 1): zeros init, gemma convention (1 + g)
    return jnp.zeros((dim,), dtype)


# ---------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., L, D) with D even; positions: broadcastable to (..., L)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # (..., L, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- mlp
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm": norm_init(cfg.d_model, dtype),
         "w_up": dense_init(k2, cfg.d_model, d_ff, dtype=dtype),
         "w_down": dense_init(k3, d_ff, cfg.d_model, dtype=dtype)}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(k1, cfg.d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    up = h @ p["w_up"]
    if cfg.mlp_type == "swiglu":
        up = jax.nn.silu(h @ p["w_gate"]) * up
    elif cfg.mlp_type == "geglu":
        up = jax.nn.gelu(h @ p["w_gate"]) * up
    else:
        up = jax.nn.gelu(up)
    return x + up @ p["w_down"]


# --------------------------------------------------------------- causal conv
def causal_conv_init(key, channels: int, width: int, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (width, channels), jnp.float32)
                  / math.sqrt(width)).astype(dtype),
            "b": jnp.zeros((channels,), dtype)}


def causal_conv_apply(p, x: jax.Array, state: Optional[jax.Array] = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B, L, C); state: (B, width-1, C) history.

    Returns (y, new_state). With state=None a zero history is used.
    """
    w, b = p["w"], p["b"]
    width = w.shape[0]
    bsz, l, c = x.shape
    if state is None:
        state = jnp.zeros((bsz, width - 1, c), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, L+width-1, C)
    y = jnp.zeros((bsz, l, c), jnp.float32)
    for i in range(width):                            # width is tiny (4)
        y = y + xp[:, i:i + l].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    new_state = xp[:, l:]                             # last width-1 inputs
    return y.astype(x.dtype), new_state


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
