"""Self/cross attention with GQA, RoPE, sliding-window, softcap, KV caches.

Two paths:
  * full-sequence (train / prefill): repro.kernels.ops.attention (Pallas
    flash kernel on TPU, oracle on CPU);
  * cached decode (1 query token): a masked GEMV in plain jnp — no kernel
    needed, it is HBM-bandwidth-bound on the KV cache read.

KV caches are either linear (length = context) or ring buffers
(length = sliding window) — ring buffers make long_500k decode O(window)
memory for SWA layers. Keys are stored post-RoPE so decode never re-rotates.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import common
from repro.sharding.policy import DP, TP, constrain

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, dtype=None, cross: bool = False):
    dtype = dtype or jnp.dtype(cfg.dtype)
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    kv_in = d  # cross-attn keys/values also read d_model-wide states
    p = {
        "norm": common.norm_init(d, dtype),
        "wq": common.dense_init(ks[0], d, hq, hd, dtype=dtype),
        "wk": common.dense_init(ks[1], kv_in, hkv, hd, dtype=dtype),
        "wv": common.dense_init(ks[2], kv_in, hkv, hd, dtype=dtype),
        "wo": (common.dense_init(ks[3], hq * hd, d, dtype=dtype)
               .reshape(hq, hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    if cross:
        p["gate_attn"] = jnp.zeros((), dtype)  # tanh-gated residual (llama-vision)
    return p


def _qkv(p, x, states, cfg: ModelConfig):
    """x: (B, L, d) queries source; states: kv source (defaults to x)."""
    kv_src = x if states is None else states
    q = jnp.einsum("bld,dhe->bhle", x, p["wq"])
    k = jnp.einsum("bld,dhe->bhle", kv_src, p["wk"])
    v = jnp.einsum("bld,dhe->bhle", kv_src, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, :, None, :]
        k = k + p["bk"][None, :, None, :]
        v = v + p["bv"][None, :, None, :]
    qkv_spec = (DP, TP, None, None)     # batch on data, heads on model
    return (constrain(q, qkv_spec), constrain(k, qkv_spec),
            constrain(v, qkv_spec))


def attn_full(p, x: jax.Array, cfg: ModelConfig, *,
              window: Optional[int] = None,
              positions: Optional[jax.Array] = None,
              causal: bool = True,
              cross_states: Optional[jax.Array] = None,
              make_cache: bool = False,
              cache_len: int = 0):
    """Full-sequence attention. Returns (y, cache | None).

    positions: (L,) absolute positions for RoPE (self-attn only).
    """
    h = common.rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cross_states, cfg)
    if cross_states is None:
        l = x.shape[1]
        if positions is None:
            positions = jnp.arange(l)
        q = common.rope(q, positions[None, None, :], cfg.rope_theta)
        k = common.rope(k, positions[None, None, :], cfg.rope_theta)
    y = ops.attention(q, k, v, causal=causal and cross_states is None,
                      window=window, softcap=cfg.attn_logit_softcap)
    y = jnp.einsum("bhle,hed->bld", y, p["wo"])
    if "gate_attn" in p:
        y = jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(y.dtype) * y
    out = x + y

    cache = None
    if make_cache:
        cache = _cache_from_prefill(k, v, window, cache_len,
                                    cfg.kv_cache_dtype)
    return out, cache


def _quantize(x, axis=-1):
    """Symmetric int8 quantisation with a per-(b, h, slot) f32 scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32)
                           / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _cache_from_prefill(k, v, window, cache_len, kv_dtype="native"):
    """Build a decode cache from prefill K/V: (B, Hkv, L, hd) -> cache slots."""
    b, hkv, l, hd = k.shape
    slots = min(window, cache_len) if window else cache_len
    kc = jnp.zeros((b, hkv, slots, hd), k.dtype)
    vc = jnp.zeros((b, hkv, slots, hd), v.dtype)
    if window and slots <= l:
        # ring buffer: last `slots` tokens, placed at their pos % slots
        tail_k, tail_v = k[:, :, l - slots:], v[:, :, l - slots:]
        idx = (jnp.arange(l - slots, l)) % slots
        kc = kc.at[:, :, idx].set(tail_k)
        vc = vc.at[:, :, idx].set(tail_v)
    else:
        n = min(l, slots)
        kc = jax.lax.dynamic_update_slice(kc, k[:, :, :n], (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v[:, :, :n], (0, 0, 0, 0))
    if kv_dtype == "int8":
        kq, ks = _quantize(kc)
        vq, vs = _quantize(vc)
        return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    return {"k": kc, "v": vc}


def empty_cache(batch: int, cfg: ModelConfig, cache_len: int,
                window: Optional[int], dtype) -> dict:
    slots = min(window, cache_len) if window else cache_len
    shape = (batch, cfg.num_kv_heads, slots, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(p, x: jax.Array, cache: dict, pos: jax.Array,
                cfg: ModelConfig, *, window: Optional[int] = None,
                cross: bool = False):
    """One decode step. x: (B, 1, d); pos: scalar int32 (tokens already in
    context). Returns (y, new_cache)."""
    h = common.rms_norm(x, p["norm"], cfg.norm_eps)
    if cross:
        # cross-attn cache is static (built at prefill): attend, don't insert
        q = jnp.einsum("bld,dhe->bhle", h, p["wq"])
        y = _cached_attention(q, cache["k"], cache["v"], None, None, cfg,
                              full=True)
    else:
        q, k, v = _qkv(p, h, None, cfg)
        q = common.rope(q, pos[None, None, None], cfg.rope_theta)
        k = common.rope(k, pos[None, None, None], cfg.rope_theta)
        slots = cache["k"].shape[2]
        slot = jax.lax.rem(pos, slots) if window else pos
        if "k_scale" in cache:
            kq, ks = _quantize(k)
            vq, vs = _quantize(v)
            cache = {
                "k": jax.lax.dynamic_update_slice(cache["k"], kq,
                                                  (0, 0, slot, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], vq,
                                                  (0, 0, slot, 0)),
                "k_scale": jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, (0, 0, slot)),
                "v_scale": jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, (0, 0, slot)),
            }
        else:
            cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0)),
            }
        y = _cached_attention(q, cache["k"], cache["v"], pos, window, cfg,
                              full=False,
                              k_scale=cache.get("k_scale"),
                              v_scale=cache.get("v_scale"))
    y = jnp.einsum("bhle,hed->bld", y, p["wo"])
    if "gate_attn" in p:
        y = jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(y.dtype) * y
    return x + y, cache


def _cached_attention(q, kc, vc, pos, window, cfg: ModelConfig, *, full,
                      k_scale=None, v_scale=None):
    """q: (B, Hq, 1, hd); kc/vc: (B, Hkv, S, hd). Masked GEMV decode
    attention. GQA is expressed as grouped einsums (never jnp.repeat over
    the kv-head axis: repeating a sharded dim forces GSPMD to all-gather
    the whole KV cache — measured 8x1.07 GB/step on llama-vision decode,
    EXPERIMENTS.md §Perf iteration 1.1). int8 caches carry per-(b, h, slot)
    scales folded in AFTER the integer-weight contractions."""
    b, hq, _, hd = q.shape
    hkv, slots = kc.shape[1], kc.shape[2]
    group = hq // hkv
    compute_dtype = jnp.bfloat16 if kc.dtype == jnp.int8 else kc.dtype
    # narrow cache reads, f32 accumulation: halves (bf16) or quarters
    # (int8) decode HBM traffic vs an upcast cache (§Perf 1.2 / 1.4)
    qf = q.astype(compute_dtype).reshape(b, hkv, group, hd)
    logits = jnp.einsum("bkge,bkse->bkgs", qf, kc.astype(compute_dtype),
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    if k_scale is not None:
        logits = logits * k_scale[:, :, None, :]
    if cfg.attn_logit_softcap is not None:
        logits = common.softcap(logits, cfg.attn_logit_softcap)
    if not full:
        slot_idx = jnp.arange(slots)
        if window:
            # ring buffer: valid slots are the last min(pos+1, slots) writes
            n_valid = jnp.minimum(pos + 1, slots)
            age = jax.lax.rem(jax.lax.rem(pos, slots) - slot_idx + slots,
                              slots)  # 0 = newest
            mask = age < n_valid
        else:
            mask = slot_idx <= pos
        logits = jnp.where(mask[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if v_scale is not None:
        probs = probs * v_scale[:, :, None, :]
    out = jnp.einsum("bkgs,bkse->bkge", probs.astype(compute_dtype),
                     vc.astype(compute_dtype),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, 1, hd).astype(q.dtype)
