"""Encoder-decoder model (seamless-m4t family).

Per the assignment carve-out the speech frontend is a stub — the encoder
consumes precomputed frame embeddings (B, frames, d_model). The decoder is
a standard causal stack where every layer is (self-attn, cross-attn, MLP);
we express that as a TransformerStack with pattern (ATTN, CROSS) scanned
num_layers times, cross-attending to the encoder output.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.decoder import TransformerStack, padded_vocab


class EncDecModel:
    """batch keys: "tokens" (B, L) int32 targets, "frames" (B, F, d_model)
    stub-encoder frame embeddings."""

    def __init__(self, cfg: ModelConfig, remat: bool = False):
        if not cfg.is_encdec:
            raise ValueError(f"{cfg.name}: EncDecModel needs "
                             f"encoder_layers > 0")
        self.cfg = cfg
        self.encoder = TransformerStack(cfg, pattern=(base.ATTN,),
                                        num_groups=cfg.encoder_layers,
                                        remat=remat)
        self.decoder = TransformerStack(cfg, pattern=(base.ATTN, base.CROSS),
                                        num_groups=cfg.num_layers,
                                        remat=remat)

    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 5)
        vpad = padded_vocab(cfg.vocab_size)
        p = {"embed": common.embed_init(ks[0], vpad, cfg.d_model, dtype),
             "enc_norm": common.norm_init(cfg.d_model, dtype),
             "final_norm": common.norm_init(cfg.d_model, dtype),
             "encoder": self.encoder.init(ks[1]),
             "decoder": self.decoder.init(ks[2])}
        if not cfg.tie_embeddings:
            p["unembed"] = common.dense_init(ks[3], cfg.d_model, vpad,
                                             dtype=dtype)
        return p

    def param_specs(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def encode(self, p, frames):
        ctx = {"cfg": self.cfg, "causal": False, "cross_states": None}
        x, _, _ = self.encoder.apply(p["encoder"], frames, ctx, mode="train")
        return common.rms_norm(x, p["enc_norm"], self.cfg.norm_eps)

    def _embed(self, p, tokens):
        x = jnp.take(p["embed"], tokens, axis=0)
        return x * jnp.asarray(math.sqrt(self.cfg.d_model), x.dtype)

    def _head(self, p, x):
        cfg = self.cfg
        x = common.rms_norm(x, p["final_norm"], cfg.norm_eps)
        w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
        from repro.models.decoder import _mask_vocab_pad
        return _mask_vocab_pad((x @ w).astype(jnp.float32), cfg.vocab_size)

    def forward(self, p, batch):
        enc = self.encode(p, batch["frames"])
        x = self._embed(p, batch["tokens"])
        ctx = {"cfg": self.cfg, "causal": True, "cross_states": enc}
        x, _, aux = self.decoder.apply(p["decoder"], x, ctx, mode="train")
        return self._head(p, x), aux

    def loss(self, p, batch, *, loss_chunk: int = 512):
        from repro.models.decoder import chunked_nll
        enc = self.encode(p, batch["frames"])
        tokens = batch["tokens"]
        x = self._embed(p, tokens)
        ctx = {"cfg": self.cfg, "causal": True, "cross_states": enc}
        x, _, _ = self.decoder.apply(p["decoder"], x, ctx, mode="train")
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        weights = jnp.concatenate(
            [jnp.ones_like(tokens[:, 1:], jnp.float32),
             jnp.zeros((tokens.shape[0], 1), jnp.float32)], axis=1)
        return chunked_nll(lambda h: self._head(p, h), x, labels, weights,
                           loss_chunk)

    def prefill(self, p, batch, max_len=None):
        enc = self.encode(p, batch["frames"])
        tokens = batch["tokens"]
        cache_len = max_len or tokens.shape[1]
        x = self._embed(p, tokens)
        ctx = {"cfg": self.cfg, "causal": True, "cross_states": enc,
               "cache_len": cache_len}
        x, caches, _ = self.decoder.apply(p["decoder"], x, ctx,
                                          mode="prefill")
        logits = self._head(p, x[:, -1:])[:, 0]
        return logits, {"pos": jnp.asarray(tokens.shape[1], jnp.int32),
                        "groups": caches}

    def decode_step(self, p, token, cache):
        x = self._embed(p, token[:, None])
        ctx = {"cfg": self.cfg, "causal": True, "pos": cache["pos"],
               "cross_states": None}
        x, caches, _ = self.decoder.apply(p["decoder"], x, ctx,
                                          caches=cache["groups"],
                                          mode="decode")
        logits = self._head(p, x)[:, 0]
        return logits, {"pos": cache["pos"] + 1, "groups": caches}

    def init_cache(self, batch: int, cache_len: int):
        dtype = jnp.dtype(self.cfg.dtype)
        return {"pos": jnp.asarray(0, jnp.int32),
                "groups": self.decoder.empty_caches(batch, cache_len, dtype)}
