"""Decoder-only model: embeddings + scanned block-group stack + LM head.

Layer stacking uses jax.lax.scan over *groups* (one group = one copy of
cfg.group_pattern, params stacked on a leading num_groups axis). This keeps
the HLO O(len(group_pattern)) instead of O(num_layers) — an 88-layer
mistral-large compiles as one scanned body.

Supports every decoder-ish family in the pool: dense (llama/qwen/gemma/
gemma2/mistral), MoE (mixtral), SSM (xLSTM), hybrid (zamba2, with shared
attention weights passed around the scan as a closure), VLM (llama-vision,
cross-attending to stub patch embeddings through a learned projector).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.configs.base import ModelConfig
from repro.models import blocks, common
from repro.sharding.policy import DP, TP, constrain, constrain_residual

AUX_KEYS = ("moe_aux",)
VOCAB_PAD_MULTIPLE = 256   # Megatron-style: pad embeddings so the vocab dim
                           # shards evenly on the "model" axis


def padded_vocab(vocab_size: int) -> int:
    m = VOCAB_PAD_MULTIPLE
    return ((vocab_size + m - 1) // m) * m


def _mask_vocab_pad(logits, vocab_size: int):
    """-inf the padding logits (additive, keeps the sharded padded shape)."""
    vpad = logits.shape[-1]
    if vpad == vocab_size:
        return logits
    pad_mask = jnp.arange(vpad) >= vocab_size
    return logits + jnp.where(pad_mask, -1e30, 0.0).astype(logits.dtype)


def chunked_nll(head_fn, x, labels, weights, chunk: int):
    """Mean next-token NLL without materialising full-vocab logits.

    head_fn: (B, T, d) -> (B, T, V) f32 logits. x: (B, S, d);
    labels, weights: (B, S). Scans the head over S in `chunk`-token slices
    (S stays un-sliced so production seq lengths divide evenly; positions
    with weight 0 are ignored).
    """
    bsz, s, _ = x.shape
    denom = jnp.maximum(jnp.sum(weights), 1.0)
    if s % chunk or s <= chunk:
        return -_nll_sum(head_fn(x), labels, weights) / denom
    n = s // chunk
    xc = x.reshape(bsz, n, chunk, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(bsz, n, chunk).transpose(1, 0, 2)
    wc = weights.reshape(bsz, n, chunk).transpose(1, 0, 2)

    def body(acc, args):
        xs, ls, ws = args
        return acc + _nll_sum(head_fn(xs), ls, ws), None

    # remat: recompute each chunk's logits in bwd instead of saving them
    body = jax.checkpoint(body)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc, wc))
    return -total / denom


def _nll_sum(logits, labels, weights):
    """weighted sum of log p(labels), vocab-sharding-friendly (no gather
    over the sharded vocab dim: one-hot contraction + explicit logsumexp)."""
    logits = constrain(logits, (DP, None, TP))
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    tgt = jnp.sum(logits * onehot, axis=-1)
    return jnp.sum((tgt - lse) * weights)


def _pad_aux(aux: dict) -> dict:
    return {k: aux.get(k, jnp.zeros((), jnp.float32)) for k in AUX_KEYS}


class TransformerStack:
    """Scanned stack of block groups. Shared-weight blocks (zamba2) are
    initialised once and routed through ctx rather than the scan xs."""

    def __init__(self, cfg: ModelConfig,
                 pattern: Optional[tuple] = None,
                 num_groups: Optional[int] = None,
                 remat: bool = False):
        self.cfg = cfg
        self.pattern = pattern or cfg.group_pattern
        self.num_groups = num_groups or cfg.num_groups
        self.has_shared = base.SHARED_ATTN in self.pattern
        self.remat = remat

    def init(self, key):
        cfg = self.cfg
        k_groups, k_shared = jax.random.split(key)

        def init_group(k):
            ks = jax.random.split(k, len(self.pattern))
            return {f"b{i}_{kind}": blocks.init_block(kind, ks[i], cfg)
                    for i, kind in enumerate(self.pattern)}

        p = {"groups": jax.vmap(init_group)(
            jax.random.split(k_groups, self.num_groups))}
        if self.has_shared:
            p["shared"] = blocks._init_attn_mlp(k_shared, cfg)
        return p

    def apply(self, p, x, ctx, caches=None, mode="train"):
        """caches: stacked per-group cache pytree (decode) or None.

        Returns (x, caches_out | None, aux dict)."""
        ctx = dict(ctx)
        if self.has_shared:
            ctx["shared_attn"] = p["shared"]
        collect_cache = mode in ("prefill", "decode")

        def body(carry, xs):
            x = carry
            gp, gcache = xs if collect_cache else (xs, None)
            caches_out, aux_sum = {}, {k: jnp.zeros((), jnp.float32)
                                       for k in AUX_KEYS}
            for i, kind in enumerate(self.pattern):
                c_in = gcache[f"b{i}_{kind}"] if gcache is not None else None
                x, c_out, aux = blocks.apply_block(kind, gp[f"b{i}_{kind}"],
                                                   x, ctx,
                                                   c_in, mode)
                aux = _pad_aux(aux)
                aux_sum = {k: aux_sum[k] + aux[k] for k in AUX_KEYS}
                if collect_cache:
                    caches_out[f"b{i}_{kind}"] = c_out
            x = constrain_residual(x)
            ys = (caches_out, aux_sum) if collect_cache else aux_sum
            return x, ys

        if self.remat and mode == "train":
            body = jax.checkpoint(body)

        if collect_cache:
            if mode == "prefill":
                # caches are produced by the blocks; feed groups only
                def body_prefill(carry, gp):
                    return body(carry, (gp, None))
                x, (caches_out, auxs) = jax.lax.scan(body_prefill, x,
                                                     p["groups"])
            else:
                x, (caches_out, auxs) = jax.lax.scan(body, x,
                                                     (p["groups"], caches))
        else:
            x, auxs = jax.lax.scan(body, x, p["groups"])
            caches_out = None
        aux = {k: jnp.sum(auxs[k]) for k in AUX_KEYS}
        return x, caches_out, aux

    def empty_caches(self, batch: int, cache_len: int, dtype):
        one = {f"b{i}_{kind}": blocks.empty_block_cache(kind, self.cfg,
                                                        batch,
                                                 cache_len, dtype)
               for i, kind in enumerate(self.pattern)}
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.num_groups,) + a.shape), one)

    def prefill_cache_len(self):
        raise NotImplementedError


class DecoderModel:
    """tokens (+ optional vision embeddings) -> logits, with KV/state caches.

    batch dict keys: "tokens" (B, L) int32; vlm additionally
    "vision_embeds" (B, S_v, vision_dim).
    """

    def __init__(self, cfg: ModelConfig, remat: bool = False):
        self.cfg = cfg
        self.stack = TransformerStack(cfg, remat=remat)

    # ------------------------------------------------------------- params
    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 4)
        vpad = padded_vocab(cfg.vocab_size)
        p = {"embed": common.embed_init(ks[0], vpad, cfg.d_model, dtype),
             "final_norm": common.norm_init(cfg.d_model, dtype),
             "stack": self.stack.init(ks[1])}
        if not cfg.tie_embeddings:
            p["unembed"] = common.dense_init(ks[2], cfg.d_model, vpad,
                                             dtype=dtype)
        if cfg.family == "vlm":
            p["vision_proj"] = common.dense_init(ks[3], cfg.vision_dim,
                                                 cfg.d_model, dtype=dtype)
        return p

    def param_specs(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # -------------------------------------------------------------- pieces
    def _embed(self, p, tokens):
        x = jnp.take(p["embed"], tokens, axis=0)
        x = constrain_residual(x)
        return x * jnp.asarray(math.sqrt(self.cfg.d_model), x.dtype)

    def _head(self, p, x):
        cfg = self.cfg
        x = common.rms_norm(x, p["final_norm"], cfg.norm_eps)
        w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
        logits = (x @ w).astype(jnp.float32)
        if cfg.final_logit_softcap is not None:
            logits = common.softcap(logits, cfg.final_logit_softcap)
        return _mask_vocab_pad(logits, cfg.vocab_size)

    def _cross_states(self, p, batch):
        if self.cfg.family != "vlm":
            return None
        ve = batch["vision_embeds"]
        return ve @ p["vision_proj"]

    def _ctx(self, p, batch, cache_len=0):
        return {"cfg": self.cfg, "causal": True,
                "cross_states": self._cross_states(p, batch),
                "cache_len": cache_len}

    # ---------------------------------------------------------------- api
    def forward(self, p, batch):
        """Full-sequence forward (training). Returns (logits, aux)."""
        x = self._embed(p, batch["tokens"])
        x, _, aux = self.stack.apply(p["stack"], x, self._ctx(p, batch),
                                     mode="train")
        return self._head(p, x), aux

    def loss(self, p, batch, *, loss_chunk: int = 512):
        """Next-token cross-entropy (+ MoE load-balance aux).

        The LM-head matmul + log_softmax are evaluated in sequence chunks
        so the (B, S, V) f32 logits tensor is never materialised — at
        production shapes (S=4k, V=256k) that tensor would dominate HBM.
        """
        tokens = batch["tokens"]
        x = self._embed(p, tokens)
        x, _, aux = self.stack.apply(p["stack"], x, self._ctx(p, batch),
                                     mode="train")
        # predict token t+1 at position t; the last position is masked so
        # the sequence dim stays power-of-two for the chunked head scan
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        weights = jnp.concatenate(
            [jnp.ones_like(tokens[:, 1:], jnp.float32),
             jnp.zeros((tokens.shape[0], 1), jnp.float32)], axis=1)
        loss = chunked_nll(lambda h: self._head(p, h), x, labels, weights,
                           loss_chunk)
        if self.cfg.num_experts:
            loss = loss + 0.01 * aux["moe_aux"] / max(1, self.cfg.num_layers)
        return loss

    def prefill(self, p, batch, max_len: Optional[int] = None):
        """Returns (last-token logits (B, V), cache).

        max_len: total context budget (prompt + decode steps); defaults to
        the prompt length (no decode growth room)."""
        tokens = batch["tokens"]
        cache_len = max_len or tokens.shape[1]
        x = self._embed(p, tokens)
        ctx = self._ctx(p, batch, cache_len=cache_len)
        x, caches, _ = self.stack.apply(p["stack"], x, ctx, mode="prefill")
        logits = self._head(p, x[:, -1:])[:, 0]
        cache = {"pos": jnp.asarray(tokens.shape[1], jnp.int32),
                 "groups": caches}
        return logits, cache

    def decode_step(self, p, token, cache):
        """token: (B,) int32; returns (logits (B, V), cache)."""
        x = self._embed(p, token[:, None])
        ctx = {"cfg": self.cfg, "causal": True, "pos": cache["pos"],
               "cross_states": None}
        x, caches, _ = self.stack.apply(p["stack"], x, ctx,
                                        caches=cache["groups"], mode="decode")
        logits = self._head(p, x)[:, 0]
        return logits, {"pos": cache["pos"] + 1, "groups": caches}

    def init_cache(self, batch: int, cache_len: int):
        """Zero decode cache (for dry-runs and fresh decode sessions)."""
        dtype = jnp.dtype(self.cfg.dtype)
        return {"pos": jnp.asarray(0, jnp.int32),
                "groups": self.stack.empty_caches(batch, cache_len, dtype)}
