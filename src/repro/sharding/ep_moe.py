"""Expert-parallel MoE via shard_map + all-to-all (beyond-paper, §Perf 3.x).

The baseline MoE is tensor-parallel: every chip computes every expert with
d_ff split over "model", paying two (tokens x d_model) all-reduces per
layer. Expert parallelism instead PLACES each expert on a model-axis shard
group and moves the (much smaller) routed token copies with all_to_all —
the paper's workload-allocation insight applied inside the chip fleet:
compute goes where the weights live; only the job payload travels.

Layout on the "model" axis (size M) with E experts, r = M/E:
  * weights are STORED EP-major (configs.base.moe_ep_shards): shard s owns
    expert s//r's (d, f/r) slice — zero weight movement at use;
  * activations arrive sequence-sharded on "model" (the residual stream
    already is, DESIGN.md §5): each shard routes its own s_loc tokens;
  * all_to_all ships routed copies to owner shards; the expert FFN output
    is partial over f/r, completed by a psum over the r-shard expert
    group; a second all_to_all ships results back; the router-weighted
    combine is local.

Per-layer comms: 2 x all_to_all(~ s_loc*k*cf*d) + r-group psum, vs
2 x all_reduce(s_chip*d) for TP-MoE.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import policy

# jax.shard_map moved out of the top-level namespace and back again across
# releases, and its replication-check kwarg was renamed check_rep ->
# check_vma independently of that move — so pick the kwarg by the resolved
# function's actual signature, not by where it lives.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map
import inspect

_SHARD_MAP_KW = {
    "check_vma" if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep": False}


def ep_group_pairs(e: int, r: int):
    return [[i * r + j for j in range(r)] for i in range(e)]


def ep_moe_ffn(experts, router, h, cfg, mesh):
    """h: (B, S, d) normed MoE input (batch on dp, seq on model).
    experts: {"ep_gate","ep_up"} (E*r, d, f/r), {"ep_down"} (E*r, f/r, d).
    Returns the expert-FFN output with h's sharding + the load-balance aux.
    """
    e = cfg.num_experts
    k = cfg.num_experts_per_tok
    m = mesh.shape["model"]
    r = cfg.moe_ep_shards
    if m != e * r:
        raise ValueError(f"EP MoE needs model axis == experts x shards, "
                         f"got model={m}, experts={e}, shards={r}")
    d = cfg.d_model
    dp_axes = policy.fsdp_axes(mesh.axis_names)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    bsz, s, _ = h.shape
    dp_total = 1
    for ax in dp_axes:
        dp_total *= mesh.shape[ax]
    # decode (seq=1) can't shard the seq dim; batch=1 can't shard dp —
    # degrade those spec entries to replicated
    seq_spec = "model" if s % m == 0 and s >= m else None
    b_spec = dp if bsz % dp_total == 0 and bsz >= dp_total else None
    s_loc = s // m if seq_spec else s
    b_loc = bsz // dp_total if b_spec else bsz
    t_loc = b_loc * s_loc                       # tokens per shard
    # capacity per EXPERT GROUP: every copy is sent to all r replicas of
    # its expert (each holds an f/r slice; the group psum completes the
    # matmul, so replicas must see identical token sets)
    send_cap = max(1, int(math.ceil(k * t_loc / e
                                    * cfg.moe_capacity_factor)))

    in_specs = (P(b_spec, seq_spec, None),     # h
                P("model", None, None),        # ep_gate
                P("model", None, None),        # ep_up
                P("model", None, None),        # ep_down
                P(None, None))                 # router
    out_specs = (P(b_spec, seq_spec, None), P())

    @partial(_shard_map, mesh=mesh, in_specs=in_specs,
             out_specs=out_specs, **_SHARD_MAP_KW)
    def run(h_loc, wg, wu, wd, rt):
        hf = h_loc.reshape(-1, d)                           # (T, d)
        t = hf.shape[0]
        logits = hf.astype(jnp.float32) @ rt                # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

        # destination EXPERT GROUP; the send block is replicated to all r
        # replica shards of the group (each computes its f/r slice)
        dest = top_e.reshape(-1)                            # (T*k,) in [0,e)
        order = jnp.argsort(dest, stable=True)
        sorted_dest = dest[order]
        counts = jnp.bincount(sorted_dest, length=e)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(t * k) - starts[sorted_dest]
        keep = rank < send_cap
        slot = jnp.where(keep, sorted_dest * send_cap + rank, e * send_cap)
        tok = order // k
        send = jnp.zeros((e * send_cap + 1, d), h_loc.dtype)
        send = send.at[slot].add(hf[tok] * keep[:, None].astype(hf.dtype))
        send = jnp.repeat(send[:-1].reshape(e, send_cap, d), r, axis=0)

        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        work = recv.reshape(m * send_cap, d)                # my expert's jobs

        act = jax.nn.silu(work @ wg[0]) * (work @ wu[0])
        out = act @ wd[0]                                   # partial (f/r)
        if r > 1:
            out = jax.lax.psum(out, "model",
                               axis_index_groups=ep_group_pairs(e, r))

        back = jax.lax.all_to_all(out.reshape(m, send_cap, d).astype(
            h_loc.dtype), "model", split_axis=0, concat_axis=0, tiled=True)
        # replicas return identical psum-complete results; keep replica 0
        back = back.reshape(e, r, send_cap, d)[:, 0].reshape(
            e * send_cap, d)

        w_sorted = top_w.reshape(-1)[order]
        contrib = back[jnp.where(keep, slot, 0)] \
            * (w_sorted * keep).astype(back.dtype)[:, None]
        y = jnp.zeros((t, d), back.dtype).at[tok].add(contrib)

        frac = jnp.mean(jax.nn.one_hot(top_e[..., 0], e,
                                       dtype=jnp.float32), axis=0)
        mean_p = jnp.mean(probs, axis=0)
        aux = e * jnp.sum(frac * mean_p)
        aux = jax.lax.pmean(aux, "model")
        for ax in (dp_axes if isinstance(dp, tuple) else (dp,)):
            aux = jax.lax.pmean(aux, ax)
        return y.reshape(h_loc.shape), aux

    h = policy.constrain(h, (policy.DP, policy.TP, None))
    return run(h, experts["ep_gate"], experts["ep_up"], experts["ep_down"],
               router)
