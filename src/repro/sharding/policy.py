"""FSDP x TP sharding policy over the ("pod",) "data", "model" mesh.

Two mechanisms, both production-standard:

1. **Name-aware parameter rules** (Megatron-style): every param leaf name in
   the model zoo has an explicit PartitionSpec — qkv column-parallel on
   heads, output projections row-parallel, d_ff column/row pairs, vocab-
   parallel embeddings, expert-stacked MoE weights TP on d_ff, FSDP
   (("pod","data") or ("data",)) on the matching input dim. Divisibility is
   checked per dim (qwen2's 12 heads fall back to head_dim; seamless'
   256206 vocab falls back to replicated-vocab), so nothing relies on
   GSPMD padding. Optimizer state mirrors the param tree and inherits the
   same specs by leaf name.

2. **Activation constraints**: models call ``constrain(x, (DP, None, TP))``
   at block boundaries / qkv / logits. Under an active
   ``activation_policy`` (set by launch code) this lowers to
   ``with_sharding_constraint``; with no policy active it is an identity —
   CPU unit tests never see a mesh. Without these constraints GSPMD is
   free to propagate weight shardings into activations (e.g. head_dim on
   the data axis), which replicated the batch in early dry-runs — see
   EXPERIMENTS.md §Perf for the before/after.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP = "dp"   # logical data-parallel axes (("pod","data") or ("data",))
TP = "tp"   # logical tensor-parallel axis ("model")

_policy = threading.local()


def fsdp_axes(mesh_axis_names) -> tuple:
    return (("pod", "data") if "pod" in mesh_axis_names else ("data",))


class activation_policy:
    """Context manager enabling activation sharding constraints.

    residual: "seq" shards the block-boundary residual stream on the
    sequence dim over the model axis (Megatron sequence parallelism — the
    remat-saved per-layer residuals shrink by |model|, which is what lets
    the 88-layer mistral-large fit HBM); "replicated" keeps it model-
    replicated (§Perf compares the two)."""

    def __init__(self, mesh: Mesh, residual: str = "seq"):
        self.dp = fsdp_axes(mesh.axis_names)
        self.tp = ("model",) if "model" in mesh.axis_names else ()
        self.dp_size = int(np.prod([mesh.shape[a] for a in self.dp]))
        self.tp_size = int(np.prod([mesh.shape[a] for a in self.tp])) \
            if self.tp else 1
        if residual not in ("seq", "replicated"):
            raise ValueError(f"residual must be 'seq' or 'replicated', "
                             f"got {residual!r}")
        self.residual = residual
        self.mesh = mesh

    def __enter__(self):
        _policy.current = self
        return self

    def __exit__(self, *exc):
        _policy.current = None


def constrain(x, spec: Sequence):
    """spec entries: None | DP | TP. Dims that don't divide are dropped."""
    pol = getattr(_policy, "current", None)
    if pol is None:
        return x
    parts = []
    for dim, s in zip(x.shape, spec):
        if s == DP and dim % pol.dp_size == 0 and dim >= pol.dp_size:
            parts.append(pol.dp if len(pol.dp) > 1 else pol.dp[0])
        elif s == TP and pol.tp and dim % pol.tp_size == 0 \
                and dim >= pol.tp_size:
            parts.append(pol.tp[0])
        else:
            parts.append(None)
    parts += [None] * (len(x.shape) - len(parts))
    return jax.lax.with_sharding_constraint(x, P(*parts))


def current_mesh():
    pol = getattr(_policy, "current", None)
    return None if pol is None else pol.mesh


def constrain_residual(x):
    """Block-boundary residual stream (B, S, d)."""
    pol = getattr(_policy, "current", None)
    if pol is None:
        return x
    spec = (DP, TP, None) if pol.residual == "seq" else (DP, None, None)
    return constrain(x, spec)


# ------------------------------------------------------------- param rules
def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0 and n >= k


def _param_rule(name: str, shape, model: int, fsdp: int, dp_axes):
    """PartitionSpec for one (unstacked) param leaf by name."""
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    nd = len(shape)

    def d(i):  # dp if divisible
        return dp if _div(shape[i], fsdp) else None

    def m(i):  # model if divisible
        return "model" if _div(shape[i], model) else None

    if nd <= 1:
        return P()
    if name in ("wq", "wk", "wv"):
        if nd == 2:                           # xLSTM: (d_inner, d_inner)
            return P(d(0), m(1))
        if m(1):                              # (d, H, hd) column-parallel
            return P(d(0), "model", None)
        return P(d(0), None, m(2))
    if name == "wo":                          # (H, hd, d) row-parallel
        if m(0):
            return P("model", None, d(2))
        return P(None, m(1), d(2))
    if name in ("bq", "bk", "bv"):            # (H, hd) follow qkv
        return P("model", None) if m(0) else P(None, m(1))
    if name in ("w_up", "w_gate", "w_in", "w_gates"):   # (d, out) column
        return P(d(0), m(1))
    if name == "w_down" or name == "w_out":   # (in, d) row-parallel
        return P(m(0), d(1))
    if name == "embed":                       # (V, d) vocab-parallel
        return P(m(0), d(1))
    if name == "unembed":                     # (d, V)
        return P(d(0), m(1))
    if name == "router":
        return P()
    if name == "lora_a":
        return P(d(0), None)
    if name == "lora_b":
        return P(None, d(1))
    if name == "vision_proj":                 # (vision_dim, d)
        return P(d(0), m(1))
    if name in ("wx", "wh"):                  # ICU LSTM (I, 4, H): tiny
        return P()
    if name.startswith("ep_"):                # EP-major experts (E*r, d, f/r)
        # leading dim on "model" (one expert slice per shard);
        # dp-replicated by design — inference layout (sharding/ep_moe.py)
        return P("model" if _div(shape[0], model) else None, None, None)
    # fallback: model on last divisible dim, fsdp on first
    spec = [None] * nd
    for i in range(nd - 1, 0, -1):
        if _div(shape[i], model):
            spec[i] = "model"
            break
    if spec[0] is None and _div(shape[0], fsdp):
        spec[0] = dp
    return P(*spec)


def _expert_rule(name: str, shape, model: int, fsdp: int, dp_axes):
    """Stacked MoE expert weights (E, d, f) / (E, f, d): TP on d_ff."""
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    if name.startswith("ep_"):   # EP-major (E*r, d, f/r): expert on model
        return P("model" if _div(shape[0], model) else None, None, None)
    if name in ("w_up", "w_gate"):
        return P(None, dp if _div(shape[1], fsdp) else None,
                 "model" if _div(shape[2], model) else None)
    if name == "w_down":
        return P(None, "model" if _div(shape[1], model) else None,
                 dp if _div(shape[2], fsdp) else None)
    return P()


def _mesh_sizes(mesh: Mesh):
    dp_axes = fsdp_axes(mesh.axis_names)
    fsdp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    model = mesh.shape.get("model", 1)
    return model, fsdp, dp_axes


def param_specs(tree, mesh: Mesh):
    model, fsdp, dp_axes = _mesh_sizes(mesh)

    def one(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        name = keys[-1] if keys else ""
        stacked = "groups" in keys
        in_experts = "experts" in keys
        # xLSTM cell blocks: dp-only (no TP) — the matrix-memory cell needs
        # d_inner replicated; column-parallel w_up forced 18.8 GB/step of
        # per-chunk regathers on a 350M model (§Perf iteration 2.1). The
        # model axis still serves the (dominant) vocab-parallel embedding.
        dp_only = any(k.endswith(("_mlstm", "_slstm")) for k in keys)
        eff_model = 1 << 62 if dp_only else model
        shape = leaf.shape[1:] if stacked else leaf.shape
        if len(shape) <= 1:
            return P()
        if in_experts:
            spec = _expert_rule(name, shape, model, fsdp, dp_axes)
        else:
            spec = _param_rule(name, shape, eff_model, fsdp, dp_axes)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(one, tree)


def cache_specs(tree, mesh: Mesh):
    """Decode caches: KV (G, B, Hkv, S, hd) — batch on dp when divisible,
    else sequence/slots on dp (context parallel for batch-1 long decode);
    kv-heads on model when divisible, else head_dim, else slots."""
    model, fsdp, dp_axes = _mesh_sizes(mesh)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def one(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        name = keys[-1] if keys else ""
        stacked = "groups" in keys
        shape = leaf.shape[1:] if stacked else leaf.shape
        nd = len(shape)
        spec = [None] * nd
        if nd >= 2:
            used_dp = False
            if _div(shape[0], fsdp):            # batch
                spec[0] = dp
                used_dp = True
            if name in ("k_scale", "v_scale") and nd == 3:  # (B, Hkv, S)
                if _div(shape[1], model):
                    spec[1] = "model"
                elif _div(shape[2], model):
                    spec[2] = "model"
            elif name in ("k", "v") and nd == 4:  # (B, Hkv, S, hd)
                # kv-heads on model when divisible; else SLOTS on model
                # (flash-decode style: attention contractions stay local,
                # softmax reduces are tiny) — never head_dim, which is the
                # qk contraction dim and forces full-cache gathers
                # (EXPERIMENTS.md §Perf iteration 1.2)
                if _div(shape[1], model):
                    spec[1] = "model"
                elif _div(shape[2], model):
                    spec[2] = "model"
                if not used_dp and _div(shape[2], fsdp) and spec[2] is None:
                    spec[2] = dp                # context-parallel slots
            else:
                # recurrent states: model on the largest remaining dim
                order = sorted(range(1, nd), key=lambda i: -shape[i])
                for i in order:
                    if _div(shape[i], model):
                        spec[i] = "model"
                        break
                if not used_dp:
                    for i in order:
                        if spec[i] is None and _div(shape[i], fsdp):
                            spec[i] = dp
                            break
        if stacked:
            spec = [None] + spec
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, tree)


def batch_specs(tree, mesh: Mesh):
    """Model inputs: batch on dp when divisible, rest replicated."""
    _, fsdp, dp_axes = _mesh_sizes(mesh)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def one(leaf):
        if not leaf.shape:
            return P()
        first = dp if _div(leaf.shape[0], fsdp) else None
        return P(first, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(one, tree)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
