"""Batched serving engine: prefill once, decode autoregressively.

The engine is tier-agnostic compute; tier *placement* of requests is the
paper's contribution and lives in core/ (launch/serve.py glues them: the
scheduler decides which tier's engine a request batch runs on).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array            # (B, prompt + steps)
    prefill_seconds: float
    decode_seconds: float

    @property
    def total_seconds(self):
        return self.prefill_seconds + self.decode_seconds


class ServingEngine:
    def __init__(self, model, params):
        self.model = model
        self.params = params
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("max_len",))
        self._decode = jax.jit(model.decode_step)

    def generate(self, batch: dict, steps: int, *,
                 greedy: bool = True, rng: Optional[jax.Array] = None,
                 max_len: Optional[int] = None) -> GenerationResult:
        prompt = batch["tokens"]
        bsz, plen = prompt.shape
        max_len = max_len or plen + steps

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, max_len=max_len)
        logits.block_until_ready()
        t1 = time.perf_counter()

        out = [prompt]
        tok = self._sample(logits, greedy, rng, 0)
        for i in range(steps):
            out.append(tok[:, None])
            if i == steps - 1:
                break
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._sample(logits, greedy, rng, i + 1)
        jax.block_until_ready(out[-1])
        t2 = time.perf_counter()
        return GenerationResult(tokens=jnp.concatenate(out, axis=1),
                                prefill_seconds=t1 - t0,
                                decode_seconds=t2 - t1)

    @staticmethod
    def _sample(logits, greedy, rng, i):
        if greedy or rng is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(jax.random.fold_in(rng, i),
                                      logits).astype(jnp.int32)


class ClassifierEngine:
    """Single-shot inference engine for the paper's ICU LSTM classifiers."""

    def __init__(self, model, params):
        self.model = model
        self.params = params
        self._forward = jax.jit(model.forward)

    def infer(self, features: jax.Array):
        t0 = time.perf_counter()
        logits = self._forward(self.params, features)
        logits.block_until_ready()
        return logits, time.perf_counter() - t0
