"""Public jit'd entry points for the Pallas kernels.

Models call these, never pallas_call directly. Each op dispatches to the
Pallas kernel when shapes are block-compatible (and runs it in interpret
mode off-TPU), falling back to the pure-jnp oracle for tiny/ragged shapes —
so the same model code runs in CPU smoke tests and TPU production.

``use_pallas`` can be forced via the REPRO_FORCE_PALLAS / REPRO_NO_PALLAS
env vars (tests use these to pin the path under test).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lstm_cell import lstm_cell
from repro.kernels.mlstm_chunk import mlstm_chunk
from repro.kernels.ssm_scan import ssm_scan

__all__ = ["attention", "lstm_step", "ssm", "mlstm", "flash_attention",
           "lstm_cell", "ssm_scan", "mlstm_chunk"]


def _pallas_enabled() -> bool:
    if os.environ.get("REPRO_NO_PALLAS"):
        return False
    if os.environ.get("REPRO_FORCE_PALLAS"):
        return True
    # Pallas interpret mode on CPU is correct but slow; default to the oracle
    # off-TPU unless forced. On TPU the kernels are the default.
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              scale=None, block_q=128, block_k=128):
    lq, lk, d = q.shape[-2], k.shape[-2], q.shape[-1]
    blockable = (lq % min(block_q, lq) == 0 and lk % min(block_k, lk) == 0)
    if _pallas_enabled() and blockable:
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               block_q=block_q, block_k=block_k)
    if lq >= 1024:  # production shapes: block-wise, memory-bounded path
        return ref.attention_blockwise(q, k, v, causal=causal, window=window,
                                       softcap=softcap, scale=scale)
    return ref.attention_reference(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale)


def lstm_step(x, h, c, wx, wh, b):
    """wx: (I, 4, H); wh: (H, 4, H); b: (4, H)."""
    if _pallas_enabled():
        return lstm_cell(x, h, c, wx, wh, b)
    i_dim, _, h_dim = wx.shape
    return ref.lstm_cell_reference(x, h, c, wx.reshape(i_dim, 4 * h_dim),
                                   wh.reshape(h_dim, 4 * h_dim),
                                   b.reshape(4 * h_dim))


def ssm(x, dt, a, b, c, d, *, chunk=256, block_h=8):
    l, h = x.shape[1], x.shape[2]
    t = min(chunk, l)
    blockable = l % t == 0 and h % min(block_h, h) == 0
    if _pallas_enabled() and blockable:
        return ssm_scan(x, dt, a, b, c, d, chunk=chunk, block_h=block_h)
    return ref.ssm_scan_reference(x, dt, a, b, c, d)


def mlstm(q, k, v, i_gate, f_gate, *, chunk=64, block_h=4):
    """Returns (y, (C, n, m) final state)."""
    l, h = q.shape[1], q.shape[2]
    t = min(chunk, l)
    blockable = l % t == 0 and h % min(block_h, h) == 0
    if _pallas_enabled() and blockable:
        return mlstm_chunk(q, k, v, i_gate, f_gate, chunk=chunk,
                           block_h=block_h)
    if l >= 256:   # chunkwise jnp path: O(L/chunk) saved state, trainable
        return ref.mlstm_chunk_jnp(q, k, v, i_gate, f_gate, chunk=256)
    return ref.mlstm_chunk_reference(q, k, v, i_gate, f_gate)
