"""Pallas TPU chunkwise-parallel mLSTM (xLSTM matrix memory, arXiv:2405.04517).

The mLSTM recurrence with exponential input gates needs running max
stabilisation; the chunkwise-parallel form used here telescopes the
per-step stabiliser into

    b_t  = cumsum(logsigmoid(f))          per-chunk forget log-decay
    g_u  = i_u - b_u
    cm_t = max(m_in, cummax_{u<=t} g_u)   running stabiliser
    m_t  = b_t + cm_t
    w_tu = exp(g_u - cm_t) [u<=t]         intra-chunk weights
    h_t  = (S_tu v_u + exp(m_in - cm_t) q_t C_in) / max(|q_t n_t|, exp(-m_t))

which is exactly the sequential recurrence (kernels.ref.mlstm_chunk_reference)
re-associated — verified exact to fp32 tolerance in tests.

TPU mapping: grid (batch, head-blocks, chunks), chunk axis sequential; the
(bh, D, D) matrix memory, (bh, D) normaliser and (bh,) stabiliser are VMEM
scratch carried across chunks. All O(T^2)/O(T D^2) contractions are
dot_general on the MXU. Chunk default 64 keeps the (bh, D, D) state plus
(T, T, bh) weights under ~2 MiB for D=256 heads (xlstm-350m).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, y_ref,
                  cout_ref, nout_ref, mout_ref,
                  c_scr, n_scr, m_scr, *, nc: int, scale: float):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    q = q_ref[0].astype(jnp.float32)              # (T, bh, D)
    k = k_ref[0].astype(jnp.float32) * scale      # (T, bh, D)
    v = v_ref[0].astype(jnp.float32)              # (T, bh, D)
    ig = i_ref[0].astype(jnp.float32)             # (T, bh)
    fg = f_ref[0].astype(jnp.float32)             # (T, bh)
    c_in = c_scr[...]                             # (bh, D, D)
    n_in = n_scr[...]                             # (bh, D)
    m_in = m_scr[:, 0]                            # (bh,)

    b = jnp.cumsum(jax.nn.log_sigmoid(fg), axis=0)          # (T, bh)
    g = ig - b                                               # (T, bh)
    cm = jnp.maximum(jax.lax.cummax(g, axis=0), m_in[None])  # (T, bh)
    m_t = b + cm

    t = q.shape[0]
    tt = (t, t)
    causal = (jax.lax.broadcasted_iota(jnp.int32, tt, 0)
              >= jax.lax.broadcasted_iota(jnp.int32, tt, 1))
    w = jnp.exp(g[None, :, :] - cm[:, None, :])              # (T, T, bh)
    w = jnp.where(causal[..., None], w, 0.0)

    qk = jnp.einsum("thd,uhd->tuh", q, k)                    # (T, T, bh)
    s = qk * w
    num = jnp.einsum("tuh,uhd->thd", s, v)
    inter = jnp.exp(m_in[None] - cm)                         # (T, bh)
    num += jnp.einsum("thd,hde->the", q, c_in) * inter[..., None]
    n_vec = jnp.einsum("tuh,uhd->thd", w, k) + n_in[None] * inter[..., None]
    den = jnp.maximum(jnp.abs(jnp.einsum("thd,thd->th", q, n_vec)),
                      jnp.exp(-m_t))
    y_ref[0] = (num / den[..., None]).astype(y_ref.dtype)

    # chunk-end state
    cm_last, b_last, m_last = cm[-1], b[-1], m_t[-1]         # (bh,)
    w_out = jnp.exp(g - cm_last[None])                       # (T, bh)
    carry = jnp.exp(b_last + m_in - m_last)                  # (bh,)
    c_scr[...] = (c_in * carry[:, None, None]
                  + jnp.einsum("thd,the->hde", k * w_out[..., None], v))
    n_scr[...] = n_in * carry[:, None] + jnp.sum(k * w_out[..., None], axis=0)
    m_scr[...] = jnp.broadcast_to(m_last[:, None], m_scr.shape)

    @pl.when(ci == nc - 1)
    def _emit_state():
        cout_ref[0] = c_scr[...]
        nout_ref[0] = n_scr[...]
        mout_ref[0] = m_scr[:, :1]


@functools.partial(jax.jit, static_argnames=("chunk", "block_h", "interpret"))
def mlstm_chunk(q: jax.Array, k: jax.Array, v: jax.Array, i_gate: jax.Array,
                f_gate: jax.Array, *, chunk: int = 64, block_h: int = 4,
                interpret: Optional[bool] = None):
    """q, k, v: (B, L, H, D); i_gate, f_gate: (B, L, H) pre-activation.

    Returns (y (B, L, H, D), (C (B,H,D,D), n (B,H,D), m (B,H)) final state).
    """
    bsz, l, h, d = q.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    t = min(chunk, l)
    bh = min(block_h, h)
    if l % t or h % bh:
        raise ValueError(f"chunk/block must divide dims: "
                         f"L={l} % {t}, H={h} % {bh}")
    nc, nh = l // t, h // bh
    scale = float(1.0 / (d ** 0.5))

    grid = (bsz, nh, nc)
    spec_qkv = pl.BlockSpec((1, t, bh, d), lambda bi, hi, ci: (bi, ci, hi, 0))
    spec_gate = pl.BlockSpec((1, t, bh), lambda bi, hi, ci: (bi, ci, hi))
    y, c_out, n_out, m_out = pl.pallas_call(
        functools.partial(_mlstm_kernel, nc=nc, scale=scale),
        grid=grid,
        in_specs=[spec_qkv, spec_qkv, spec_qkv, spec_gate, spec_gate],
        out_specs=[
            spec_qkv,
            pl.BlockSpec((1, bh, d, d), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bh, d), lambda bi, hi, ci: (bi, hi, 0)),
            pl.BlockSpec((1, bh, 1), lambda bi, hi, ci: (bi, hi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, d), q.dtype),
            jax.ShapeDtypeStruct((bsz, h, d, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bh, d, d), jnp.float32),
            pltpu.VMEM((bh, d), jnp.float32),
            pltpu.VMEM((bh, LANES), jnp.float32),
        ],
        interpret=interpret,
        name="mlstm_chunk",
    )(q, k, v, i_gate, f_gate)
    return y, (c_out, n_out, m_out[..., 0])
