"""Pallas TPU flash attention (forward) — causal / sliding-window / softcap / GQA.

TPU-native design notes (HBM->VMEM->MXU):
  * Grid = (batch, q_heads, q_blocks, k_blocks); the k_blocks axis is
    "arbitrary" (sequential) so the online-softmax running state lives in
    VMEM scratch and is carried across k iterations — the canonical TPU
    flash schedule (no atomics / warp shuffles; the GPU algorithm's
    shared-memory tiling becomes BlockSpec VMEM tiling).
  * Block shapes default to (128, head_dim) q-tiles x (128, head_dim)
    k-tiles: MXU-aligned (multiples of 128 on the contracting and lane
    dims), VMEM working set = bq*d + 2*bk*d + acc ~ a few hundred KiB.
  * m/l running stats are kept as (bq, 128) lane-replicated f32 tiles, the
    standard TPU trick to keep reductions on the VPU 8x128 registers.
  * Fully-masked (q,k) block pairs are skipped with pl.when on block
    indices (causal upper triangle, out-of-window lower band).

Validated in interpret mode against kernels.ref.attention_reference.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], bq: int, bk: int,
                  num_kb: int, q_offset: int):
    """One (q-block, k-block) step of online-softmax attention."""
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions: queries are aligned to the END of the kv sequence
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: is any (q, k) pair in this tile live?
    q_first, q_last = qi * bq + q_offset, qi * bq + bq - 1 + q_offset
    k_first, k_last = ki * bk, ki * bk + bk - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_first <= q_last
    if window is not None:
        live &= k_last > q_first - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]                           # (bq, LANES)
        l_prev = l_scr[...]
        m_cur = jnp.max(logits, axis=-1, keepdims=True)   # (bq, 1)
        m_cur = jnp.broadcast_to(m_cur, m_prev.shape)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard: rows with everything masked keep m = NEG_INF; exp(0)=1 would
        # pollute l, so clamp the correction for those rows.
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, alpha)
        p = jnp.exp(logits - m_new[:, :1])
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=-1, keepdims=True), l_prev.shape)
        acc_scr[...] = acc_scr[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == num_kb - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q",
                     "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D). Returns (B, Hq, Lq, D).

    Queries are aligned to the end of the key sequence (decode convention).
    """
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA needs Hq % Hkv == 0, got ({hq}, {hkv})")
    group = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bq = min(block_q, lq)
    bk = min(block_k, lk)
    if lq % bq or lk % bk:
        raise ValueError(f"block sizes must divide sequence lengths: "
                         f"Lq={lq} % {bq}, Lk={lk} % {bk}")
    num_qb, num_kb = lq // bq, lk // bk
    q_offset = lk - lq

    grid = (b, hq, num_qb, num_kb)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, num_kb=num_kb, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),   # running max m
            pltpu.VMEM((bq, LANES), jnp.float32),   # running denominator l
            pltpu.VMEM((bq, d), jnp.float32),       # un-normalised accumulator
        ],
        interpret=interpret,
        name="flash_attention_fwd",
    )(q, k, v)
