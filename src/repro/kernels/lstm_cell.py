"""Pallas TPU fused LSTM cell — the paper's ICU-workload hot-spot.

The paper's three medical applications are all LSTM classifiers; their
inference inner loop is the per-timestep cell update. On GPU this is a
cuDNN fused op; the TPU-native formulation is a single Pallas kernel that
keeps the (x, h) tiles and the gate weight tiles in VMEM, issues two MXU
matmuls per gate tile, and fuses the element-wise gate math on the VPU —
one HBM round-trip per step instead of five (4 gate matmuls + pointwise).

Weights are laid out (I, 4, H) / (H, 4, H) so a hidden-tile block slices all
four gates contiguously (gate axis is a leading block dim, H stays on lanes).

Validated in interpret mode against kernels.ref.lstm_cell_reference.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref,
                 h_out_ref, c_out_ref):
    x = x_ref[...].astype(jnp.float32)            # (bb, I)
    h = h_ref[...].astype(jnp.float32)            # (bb, H)
    c = c_ref[...].astype(jnp.float32)            # (bb, bh)

    def gate(g):
        wx = wx_ref[:, g, :].astype(jnp.float32)  # (I, bh)
        wh = wh_ref[:, g, :].astype(jnp.float32)  # (H, bh)
        return (jax.lax.dot_general(x, wx, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
                + jax.lax.dot_general(h, wh, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
                + b_ref[g, :].astype(jnp.float32))

    i = jax.nn.sigmoid(gate(0))
    f = jax.nn.sigmoid(gate(1))
    g = jnp.tanh(gate(2))
    o = jax.nn.sigmoid(gate(3))
    c_new = f * c + i * g
    h_out_ref[...] = (o * jnp.tanh(c_new)).astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_h", "interpret"))
def lstm_cell(x: jax.Array, h: jax.Array, c: jax.Array, wx: jax.Array,
              wh: jax.Array, b: jax.Array, *, block_b: int = 128,
              block_h: int = 128,
              interpret: Optional[bool] = None
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, I); h, c: (B, H); wx: (I, 4, H); wh: (H, 4, H); b: (4, H).

    Gate order i, f, g, o. Returns (h', c') with h/c dtypes.
    """
    bsz, i_dim = x.shape
    _, h_dim = h.shape
    if wx.shape != (i_dim, 4, h_dim):
        raise ValueError(f"wx shape {wx.shape} != {(i_dim, 4, h_dim)}")
    if wh.shape != (h_dim, 4, h_dim):
        raise ValueError(f"wh shape {wh.shape} != {(h_dim, 4, h_dim)}")
    if b.shape != (4, h_dim):
        raise ValueError(f"b shape {b.shape} != {(4, h_dim)}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bb = min(block_b, bsz)
    bh = min(block_h, h_dim)
    if bsz % bb or h_dim % bh:
        raise ValueError(f"block sizes must divide dims: "
                         f"B={bsz} % {bb}, H={h_dim} % {bh}")

    grid = (bsz // bb, h_dim // bh)
    return pl.pallas_call(
        _lstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, i_dim), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((bb, h_dim), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((bb, bh), lambda bi, hi: (bi, hi)),
            pl.BlockSpec((i_dim, 4, bh), lambda bi, hi: (0, 0, hi)),
            pl.BlockSpec((h_dim, 4, bh), lambda bi, hi: (0, 0, hi)),
            pl.BlockSpec((4, bh), lambda bi, hi: (0, hi)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bh), lambda bi, hi: (bi, hi)),
            pl.BlockSpec((bb, bh), lambda bi, hi: (bi, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h_dim), h.dtype),
            jax.ShapeDtypeStruct((bsz, h_dim), c.dtype),
        ],
        interpret=interpret,
        name="lstm_cell",
    )(x, h, c, wx, wh, b)
