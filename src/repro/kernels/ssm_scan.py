"""Pallas TPU chunked selective-state-space scan (Mamba2 / SSD form).

Used by zamba2 (hybrid) prefill. The GPU SSD algorithm's warp-level chunk
scan maps to TPU as: grid (batch, head-blocks, chunks) with the chunk axis
sequential ("arbitrary"); the running (heads, P, N) state lives in VMEM
scratch and is carried chunk to chunk. Within a chunk everything is matmul
form (MXU-friendly):

  s       = cumsum(dt * a)                          (T, bh)   decay log-space
  G       = C B^T                                   (T, T)    shared: 1 group
  y_intra = (G * exp(s_t - s_u) * [u<=t]) @ (dt*x)
  y_state = exp(s) * (C . h_in)
  h_out   = exp(s_T) h_in + sum_u exp(s_T - s_u) (dt*x)_u (x) B_u

a < 0 and dt > 0 guarantee every exp() argument is <= 0 — no overflow, no
max-subtraction needed (this is the SSD stability property).

Block sizes: chunk T x state N on lanes; (T, T, bh) decay tensor is the VMEM
high-water mark — T=256, bh=8 -> 2 MiB f32, comfortably inside 16 MiB VMEM.

Validated in interpret mode against kernels.ref.ssm_scan_reference.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                y_ref, hout_ref, state_scr, *, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (T, bh, P)
    dt = dt_ref[0].astype(jnp.float32)        # (T, bh)
    a = a_ref[...].astype(jnp.float32)        # (bh,)
    b = b_ref[0].astype(jnp.float32)          # (T, N)
    c = c_ref[0].astype(jnp.float32)          # (T, N)
    d = d_ref[...].astype(jnp.float32)        # (bh,)
    h_in = state_scr[...]                     # (bh, P, N)

    s = jnp.cumsum(dt * a[None, :], axis=0)   # (T, bh), decreasing, <= 0
    t_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape[:1] * 2, 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape[:1] * 2, 1)
    causal = t_idx >= u_idx                                   # (T, T)

    xdt = x * dt[..., None]                                   # (T, bh, P)
    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (T, T)
    decay = jnp.exp(s[:, None, :] - s[None, :, :])            # (T, T, bh)
    m = jnp.where(causal[..., None], g[..., None] * decay, 0.0)
    y_intra = jnp.einsum("tuh,uhp->thp", m, xdt)
    y_state = jnp.einsum("tn,hpn->thp", c, h_in) * jnp.exp(s)[..., None]
    y_ref[0] = (y_intra + y_state
                + x * d[None, :, None]).astype(y_ref.dtype)

    decay_out = jnp.exp(s[-1][None, :] - s)                   # (T, bh)
    h_new = (h_in * jnp.exp(s[-1])[:, None, None]
             + jnp.einsum("thp,tn->hpn", xdt * decay_out[..., None], b))
    state_scr[...] = h_new

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_h", "interpret"))
def ssm_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, d: jax.Array, *, chunk: int = 256,
             block_h: int = 8,
             interpret: Optional[bool] = None
             ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus, > 0); a: (H,) (< 0);
    b, c: (B, L, N) (single group shared across heads); d: (H,).
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    t = min(chunk, l)
    bh = min(block_h, h)
    if l % t or h % bh:
        raise ValueError(f"chunk/block must divide dims: "
                         f"L={l} % {t}, H={h} % {bh}")
    nc, nh = l // t, h // bh

    grid = (bsz, nh, nc)
    y, hout = pl.pallas_call(
        functools.partial(_ssm_kernel, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, bh, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, t, bh), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((bh,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, t, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, t, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((bh,), lambda bi, hi, ci: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, bh, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, bh, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, p, n), jnp.float32)],
        interpret=interpret,
        name="ssm_chunk_scan",
    )(x, dt, a, b, c, d)
    return y, hout
