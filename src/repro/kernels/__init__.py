# Pallas TPU kernels for the compute hot-spots (flash attention, fused LSTM
# cell, Mamba2 chunked SSM scan, xLSTM chunkwise mLSTM), each with a pure-jnp
# oracle in ref.py and jit'd public wrappers in ops.py.
from repro.kernels import ops, ref  # noqa: F401
