"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret=True
on CPU, shape/dtype sweeps in tests/test_kernels.py). They are deliberately
simple — full-materialisation attention, sequential SSM scan — and are also
used directly by the models when a hot-spot is too small to justify a kernel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """Full-softmax attention oracle.

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D) with Hq % Hkv == 0 (GQA).
    When Lq != Lk the queries are aligned to the END of the key sequence
    (decode convention: query position i corresponds to absolute position
    Lk - Lq + i).
    Returns (B, Hq, Lq, D) in q.dtype.
    """
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA needs Hq % Hkv == 0, got ({hq}, {hkv})")
    group = hq // hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)

    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    q_pos = jnp.arange(lq)[:, None] + (lk - lq)
    k_pos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def attention_blockwise(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        block_q: int = 512) -> jax.Array:
    """Memory-bounded jnp attention (the non-Pallas production path).

    Identical math to attention_reference but scans over q blocks so the
    (Lq, Lk) logits tensor is never fully materialised — required for the
    32k/500k dry-run shapes on the CPU lowering path. For windowed
    attention each q block only reads a static (window + block_q) k slice,
    so HLO FLOPs scale as S*W, not S^2.
    """
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA needs Hq % Hkv == 0, got ({hq}, {hkv})")
    group = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    bq = min(block_q, lq)
    if lq % bq:
        return attention_reference(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale)
    nb = lq // bq
    q_off = lk - lq
    # GQA here repeats K/V up to Hq heads: the repeated copies land on the
    # model axis (Hq divides it even when Hkv does not), keeping per-block
    # einsums local. Grouped no-repeat einsums are used ONLY in the decode
    # path (slot-sharded caches): here they would reshape the
    # model-sharded Hq into (Hkv, group) and break divisibility for
    # kv<16 archs (§Perf iteration 1.3 — measured neutral on the swept
    # cases, kept as the hazard-free form).
    kf = jnp.repeat(k, group, axis=1) if group > 1 else k
    vf = jnp.repeat(v, group, axis=1) if group > 1 else v
    use_slice = window is not None and (window + bq) < lk
    kwin = window + bq if use_slice else lk

    def body(_, qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * bq, bq, axis=2)
        q_pos = qi * bq + jnp.arange(bq)[:, None] + q_off
        if use_slice:
            start = jnp.clip(qi * bq + q_off - window + 1, 0, lk - kwin)
            kb = jax.lax.dynamic_slice_in_dim(kf, start, kwin, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(vf, start, kwin, axis=2)
            k_pos = start + jnp.arange(kwin)[None, :]
        else:
            kb, vb = kf, vf
            k_pos = jnp.arange(kwin)[None, :]
        logits = jnp.einsum("bhqd,bhkd->bhqk", qb.astype(jnp.float32),
                            kb.astype(jnp.float32)) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        mask = jnp.ones((bq, kwin), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        out = jnp.einsum("bhqk,bhkd->bhqd",
                         jax.nn.softmax(logits, axis=-1),
                         vb.astype(jnp.float32))
        return None, out.astype(q.dtype)

    # remat per q-block: don't keep (bq, Lk) probs of every block for bwd
    body = jax.checkpoint(body)
    _, blocks = jax.lax.scan(body, None, jnp.arange(nb))
    return jnp.moveaxis(blocks, 0, 2).reshape(b, hq, lq, d)


def lstm_cell_reference(x: jax.Array, h: jax.Array, c: jax.Array,
                        wx: jax.Array, wh: jax.Array,
                        b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One fused LSTM cell step (the paper's ICU workload hot-spot).

    x: (B, I); h, c: (B, H); wx: (I, 4H); wh: (H, 4H); b: (4H,).
    Gate order: input, forget, cell(g), output. Returns (h', c').
    """
    gates = (x.astype(jnp.float32) @ wx.astype(jnp.float32)
             + h.astype(jnp.float32) @ wh.astype(jnp.float32)
             + b.astype(jnp.float32))
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new.astype(h.dtype), c_new.astype(c.dtype)


def ssm_scan_reference(x: jax.Array, dt: jax.Array, a: jax.Array,
                       b: jax.Array, c: jax.Array, d: jax.Array,
                       h0: Optional[jax.Array] = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Sequential Mamba2-style selective-state-space scan oracle.

    x:  (B, L, H, P)   per-head inputs
    dt: (B, L, H)      positive step sizes (already softplus'ed)
    a:  (H,)           negative per-head decay
    b:  (B, L, N)      input projection (single group, shared across heads)
    c:  (B, L, N)      output projection
    d:  (H,)           skip connection
    h0: (B, H, P, N)   optional initial state
    Returns y (B, L, H, P) and the final state (B, H, P, N).
    """
    bs, l, h, p = x.shape
    n = b.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    af = a.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((bs, h, p, n), jnp.float32)

    def step(state, t):
        xt, dtt, bt, ct = t                       # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * af[None, :])        # (B, H)
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        state = state * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, yt

    ts = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    final, ys = jax.lax.scan(step, h0.astype(jnp.float32), ts)
    y = jnp.moveaxis(ys, 0, 1) + xf * d.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final


def mlstm_chunk_jnp(q: jax.Array, k: jax.Array, v: jax.Array,
                    i_gate: jax.Array, f_gate: jax.Array, *,
                    chunk: int = 256):
    """Chunkwise-parallel mLSTM in plain jnp — the same re-association as
    kernels.mlstm_chunk (see that module's docstring for the math), used on
    the non-Pallas path. Scanning chunks instead of timesteps keeps the
    saved-for-backward state O(L/chunk), which makes xLSTM training
    lowerable at production sequence lengths.

    Returns (y (B, L, H, D), (C, n, m) final state).
    """
    bsz, l, h, d = q.shape
    t = min(chunk, l)
    if l % t:
        return mlstm_chunk_reference(q, k, v, i_gate, f_gate)
    nc = l // t
    scale = 1.0 / (d ** 0.5)

    from repro.sharding.policy import DP, constrain

    def pin(x):
        # batch-on-dp, replicated elsewhere: without this GSPMD inherits a
        # d_inner sharding from upstream projections and replicate-reshards
        # at every scan step ("involuntary full rematerialization",
        # 18.8 GB/step measured — EXPERIMENTS.md §Perf iterations 2.2-2.4)
        return constrain(x, (DP,) + (None,) * (x.ndim - 1))

    q, k, v = pin(q), pin(k), pin(v)
    i_gate, f_gate = pin(i_gate), pin(f_gate)
    causal = (jnp.arange(t)[:, None] >= jnp.arange(t)[None, :])

    def body(state, ci):
        # index-scan + dynamic_slice keeps the (loop-invariant) q/k/v
        # closures batch-sharded and sliced locally — no stacked/transposed
        # xs arrays for GSPMD to reshard (§Perf iteration 2.4)
        c_in, n_in, m_in = state                      # (B,H,D,D),(B,H,D),(B,H)
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, ci * t, t, axis=1)
        qc, kc, vc, ic, fc = (sl(x).astype(jnp.float32)
                              for x in (q, k, v, i_gate, f_gate))
        kc = kc * scale
        b = jnp.cumsum(jax.nn.log_sigmoid(fc), axis=1)        # (B,T,H)
        g = ic - b
        cm = jnp.maximum(jax.lax.cummax(g, axis=1), m_in[:, None])
        m_t = b + cm
        w = jnp.exp(g[:, None, :, :] - cm[:, :, None, :])     # (B,T,T,H)
        w = jnp.where(causal[None, :, :, None], w, 0.0)
        qk = jnp.einsum("bthd,buhd->btuh", qc, kc)
        num = jnp.einsum("btuh,buhd->bthd", qk * w, vc)
        inter = jnp.exp(m_in[:, None] - cm)                   # (B,T,H)
        num += jnp.einsum("bthd,bhde->bthe", qc, c_in) * inter[..., None]
        n_vec = jnp.einsum("btuh,buhd->bthd", w, kc) \
            + n_in[:, None] * inter[..., None]
        den = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", qc, n_vec)),
                          jnp.exp(-m_t))
        y = num / den[..., None]

        cm_l, b_l, m_l = cm[:, -1], b[:, -1], m_t[:, -1]      # (B,H)
        w_out = jnp.exp(g - cm_l[:, None])                    # (B,T,H)
        carry = jnp.exp(b_l + m_in - m_l)                     # (B,H)
        c_new = c_in * carry[..., None, None] + jnp.einsum(
            "bthd,bthe->bhde", kc * w_out[..., None], vc)
        n_new = n_in * carry[..., None] + jnp.sum(
            kc * w_out[..., None], axis=1)
        return (c_new, n_new, m_l), y

    init = (jnp.zeros((bsz, h, d, d), jnp.float32),
            jnp.zeros((bsz, h, d), jnp.float32),
            jnp.full((bsz, h), NEG_INF, jnp.float32))
    state, ys = jax.lax.scan(jax.checkpoint(body), init, jnp.arange(nc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l, h, d)
    return y.astype(q.dtype), state


def mlstm_chunk_reference(q: jax.Array, k: jax.Array, v: jax.Array,
                          i_gate: jax.Array, f_gate: jax.Array,
                          c0: Optional[jax.Array] = None,
                          n0: Optional[jax.Array] = None,
                          m0: Optional[jax.Array] = None,
                          ) -> tuple[jax.Array, tuple[jax.Array, jax.Array, jax.Array]]:
    """Sequential mLSTM (xLSTM matrix-memory) oracle, stabilised gating.

    q, k, v: (B, L, H, D); i_gate, f_gate: (B, L, H) raw (pre-activation).
    State: C (B, H, D, D) matrix memory, n (B, H, D) normaliser, m (B, H) max.
    Follows arXiv:2405.04517 eq. (19)-(27).
    """
    bs, l, h, d = q.shape
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    ig = i_gate.astype(jnp.float32)
    fg = f_gate.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(d)
    if c0 is None:
        c0 = jnp.zeros((bs, h, d, d), jnp.float32)
    if n0 is None:
        n0 = jnp.zeros((bs, h, d), jnp.float32)
    if m0 is None:
        m0 = jnp.full((bs, h), NEG_INF, jnp.float32)

    def step(state, t):
        c, n, m = state
        qt, kt, vt, it, ft = t
        log_f = jax.nn.log_sigmoid(ft)            # (B, H)
        m_new = jnp.maximum(log_f + m, it)
        fdec = jnp.exp(log_f + m - m_new)
        iamp = jnp.exp(it - m_new)
        c = c * fdec[..., None, None] + iamp[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", kt * scale, vt)
        n = n * fdec[..., None] + iamp[..., None] * kt * scale
        num = jnp.einsum("bhde,bhd->bhe", c, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)),
                          jnp.exp(-m_new))
        return (c, n, m_new), num / den[..., None]

    ts = tuple(jnp.moveaxis(t, 1, 0) for t in
               (qf, kf, vf, ig, fg))
    (c, n, m), ys = jax.lax.scan(step, (c0, n0, m0), ts)
    return jnp.moveaxis(ys, 0, 1).astype(q.dtype), (c, n, m)
