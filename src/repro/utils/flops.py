"""Analytic parameter and FLOP accounting per architecture.

The allocator's cost model (core.cost_model) consumes these; the roofline
analysis cross-checks them against the compiled dry-run's
``cost_analysis()`` (EXPERIMENTS.md §Roofline, MODEL_FLOPS / HLO_FLOPs).

Param counts are exact by construction: we eval_shape the real model init
and sum leaf sizes (no duplicated formulas to drift out of sync).
"""
from __future__ import annotations

import math
from functools import lru_cache

import jax
import numpy as np

from repro.configs import base
from repro.configs.base import ModelConfig, ShapeConfig


@lru_cache(maxsize=64)
def _param_specs(cfg: ModelConfig):
    from repro.models import build_model
    return build_model(cfg).param_specs()


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count (from the real model's shapes)."""
    leaves = jax.tree.leaves(_param_specs(cfg))
    return int(sum(np.prod(l.shape) for l in leaves))


def param_bytes(cfg: ModelConfig) -> int:
    leaves = jax.tree.leaves(_param_specs(cfg))
    return int(sum(np.prod(l.shape) * np.dtype(l.dtype).itemsize
                   for l in leaves))


def _expert_params_per_layer(cfg: ModelConfig) -> int:
    return 3 * cfg.d_model * cfg.d_ff  # w_gate + w_up + w_down per expert


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE counts only top-k experts)."""
    n = param_count(cfg)
    if cfg.num_experts:
        n_moe_layers = sum(k == base.MOE for k in cfg.group_pattern) \
            * cfg.num_groups
        n -= (cfg.num_experts - cfg.num_experts_per_tok) \
            * _expert_params_per_layer(cfg) * n_moe_layers
    return n


def _embed_params(cfg: ModelConfig) -> int:
    n = cfg.vocab_size * cfg.d_model
    return n  # unembed (tied or not) is a real matmul, counted in compute


def _attn_layers(cfg: ModelConfig):
    """(n_attn_layers incl. shared/moe/cross, n_cross) over the stack."""
    kinds = list(cfg.group_pattern) * cfg.num_groups
    if cfg.is_encdec:
        kinds = [base.ATTN] * cfg.encoder_layers + \
            [base.ATTN, base.CROSS] * cfg.num_layers
    n_self = sum(k in (base.ATTN, base.ATTN_LOCAL, base.ATTN_GLOBAL,
                       base.MOE, base.SHARED_ATTN) for k in kinds)
    n_cross = sum(k == base.CROSS for k in kinds)
    return n_self, n_cross


def _avg_context(cfg: ModelConfig, kind: str, seq: int) -> float:
    """Average attended context per query token during a full-seq pass."""
    win = None
    if kind == base.ATTN_LOCAL or cfg.attn_window:
        win = cfg.attn_window
    win = win or cfg.long_context_window
    causal_avg = (seq + 1) / 2
    return min(win, causal_avg) if win else causal_avg


def forward_flops(cfg: ModelConfig, batch: int, seq: int,
                  kind: str = "prefill") -> float:
    """Matmul-dominant forward FLOPs for one step.

    kind: "prefill"/"train" = full sequence; "decode" = 1 token with a
    `seq`-long context.
    """
    tokens = batch * (seq if kind != "decode" else 1)
    n_active = active_param_count(cfg)
    # parameter matmuls: 2 FLOPs per param per token; embedding gather is
    # not a matmul, but the LM head is (tied weights still multiply)
    n_matmul = n_active - _embed_params(cfg)
    if cfg.tie_embeddings:
        n_matmul += cfg.vocab_size * cfg.d_model
    # MoE capacity padding computes cap-factor more slots than active tokens
    if cfg.num_experts:
        n_moe_layers = sum(k == base.MOE for k in cfg.group_pattern) \
            * cfg.num_groups
        pad = (cfg.moe_capacity_factor - 1.0) * cfg.num_experts_per_tok \
            * _expert_params_per_layer(cfg) * n_moe_layers
        n_matmul += max(0.0, pad)
    flops = 2.0 * n_matmul * tokens

    # attention score/value contractions
    n_self, n_cross = _attn_layers(cfg)
    hq, hd = cfg.num_heads, cfg.head_dim
    if kind == "decode":
        ctx = seq
        win = cfg.attn_window or cfg.long_context_window
        if win:
            ctx = min(win, seq)
        flops += 4.0 * hq * hd * ctx * n_self * tokens
        flops += 4.0 * hq * hd * cfg.cross_attn_states * n_cross * tokens
    else:
        kinds = list(cfg.group_pattern) * cfg.num_groups
        if cfg.is_encdec:
            kinds = [base.ATTN] * cfg.encoder_layers + \
                [base.ATTN, base.CROSS] * cfg.num_layers
        for k in kinds:
            if k == base.CROSS:
                flops += 4.0 * hq * hd * cfg.cross_attn_states * tokens
            elif k in (base.ATTN, base.ATTN_GLOBAL, base.MOE,
                       base.SHARED_ATTN, base.ATTN_LOCAL):
                flops += 4.0 * hq * hd * _avg_context(cfg, k, seq) * tokens
    # recurrent state ops (mamba / xlstm): ~6 * d_inner * state per token
    d_inner = cfg.ssm_expand * cfg.d_model
    kinds = list(cfg.group_pattern) * cfg.num_groups
    for k in kinds:
        if k == base.MAMBA:
            flops += 6.0 * d_inner * cfg.ssm_state_dim * tokens
        elif k == base.MLSTM:
            ph = d_inner // max(1, cfg.ssm_num_heads)
            flops += 6.0 * d_inner * ph * tokens
        elif k == base.SLSTM:
            ph = cfg.d_model // cfg.num_heads
            flops += 6.0 * cfg.d_model * ph * tokens
    return flops


def step_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """FLOPs of the step the dry-run lowers for this shape."""
    if shape.kind == "train":
        return 3.0 * forward_flops(cfg, shape.global_batch, shape.seq_len,
                                   "train")
    return forward_flops(cfg, shape.global_batch, shape.seq_len, shape.kind)


def model_flops_6nd(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The roofline report's MODEL_FLOPS: 6*N*D (6*N_active*D for MoE)."""
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind == "train" else
                                   (shape.seq_len if shape.kind == "prefill"
                                    else 1))
    n = active_param_count(cfg)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n * tokens


def lstm_flops(input_dim: int, hidden: int, seq_len: int = 1) -> float:
    """Paper Section III.C FC-layer formula, (2I-1)O summed over gates."""
    per_step = (2 * input_dim - 1) * 4 * hidden + \
        (2 * hidden - 1) * 4 * hidden
    return per_step * seq_len
