"""Llama-3.2-Vision 11B [vlm] — text decoder with cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

40L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=128256. Every 5th
layer is a gated cross-attention layer over projected vision-patch embeddings.
Per the assignment carve-out, the ViT vision frontend is a STUB:
``input_specs`` provides precomputed patch embeddings of shape
(batch, cross_attn_states, vision_dim); the in-model projector maps
vision_dim -> d_model.
"""
from repro.configs.base import ATTN, CROSS, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128_256,
    group_pattern=(ATTN, ATTN, ATTN, ATTN, CROSS),
    rope_theta=500_000.0,
    cross_attn_states=4096,   # ~4 image tiles x ~1600 patches, rounded for sharding
    vision_dim=1280,          # ViT-H patch embedding width
)
