"""Gemma 2B [dense] — GeGLU, head_dim=256, MQA (kv=1) [arXiv:2403.08295].

18L, d_model=2048, 8 heads (kv=1), d_ff=16384, vocab=256000.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256_000,
    group_pattern=(ATTN,),
    mlp_type="geglu",
    rope_theta=10_000.0,
    norm_eps=1e-6,
    tie_embeddings=True,
)
