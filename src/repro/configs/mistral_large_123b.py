"""Mistral-Large 123B [dense] [hf:mistralai/Mistral-Large-Instruct-2407].

88L, d_model=12288, 96 heads (GQA kv=8), d_ff=28672, vocab=32768. The
memory-pressure stressor of the assigned pool — FSDPxTP sharding essential.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32_768,
    group_pattern=(ATTN,),
    rope_theta=1_000_000.0,
)
