"""Gemma-2 27B [dense] — local+global alternating attention, logit softcaps
[arXiv:2408.00118].

46L, d_model=4608, 32 heads (GQA kv=16), d_ff=36864, vocab=256000.
head_dim=128 (model card; 32*128 != d_model — Gemma2 projects q/k/v
independently of d_model). Sliding window 4096 on local layers, attention
logit softcap 50.0, final logit softcap 30.0, GeGLU MLP.
"""
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    group_pattern=(ATTN_LOCAL, ATTN_GLOBAL),
    attn_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_type="geglu",
    rope_theta=10_000.0,
    norm_eps=1e-6,
    tie_embeddings=True,
)
