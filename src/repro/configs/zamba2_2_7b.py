"""Zamba2-2.7B [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

54 blocks, d_model=2560, attention 32 heads (kv=32), d_ff=10240, vocab=32000,
ssm_state=64. Layout: each scanned group is 5 Mamba2 blocks followed by one
SHARED attention block (the attention weights are a single set reused by
every shared_attn position — Zamba2's defining trick), 9 groups = 54 blocks
(45 mamba + 9 shared-attn applications).
"""
from repro.configs.base import MAMBA, SHARED_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32_000,
    group_pattern=(MAMBA,) * 5 + (SHARED_ATTN,),
    ssm_state_dim=64,
    ssm_num_heads=80,      # d_inner (=2*2560=5120) / ssm_head_dim 64
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
)
