"""xLSTM-350M [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L, d_model=1024, 4 heads (kv=4), d_ff=0 (xLSTM blocks carry their own
up/down projections), vocab=50304. Block ratio follows the paper's xLSTM[7:1]
recipe: each scanned group is 7 mLSTM + 1 sLSTM blocks, 3 groups = 24 layers.
Decode state is O(1) in context (matrix memory + scalar cell states).
"""
from repro.configs.base import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    group_pattern=(MLSTM,) * 7 + (SLSTM,),
    ssm_num_heads=4,
    ssm_head_dim=512,      # d_inner (=expand*d_model=2048) / 4 heads
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    ssm_state_dim=512,     # mLSTM matrix memory is (head_dim x head_dim) per head
    tie_embeddings=True,
)
