"""SeamlessM4T-Large v2 [audio] — encoder-decoder, multimodal
[arXiv:2308.11596].

24 decoder layers + 24 encoder layers, d_model=1024, 16 heads (kv=16),
d_ff=8192, vocab=256206. Per the assignment carve-out, the speech frontend
(mel-spectrogram + conv feature extractor) is a STUB: ``input_specs`` feeds
precomputed frame embeddings (batch, encoder_frames, d_model) to the encoder.
Deviation note: positions use RoPE rather than Seamless' learned positional
embeddings — positional scheme does not affect allocation/roofline structure.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    group_pattern=(ATTN,),
    mlp_type="gelu",
    encoder_layers=24,
    encoder_frames=1024,
    cross_attn_states=1024,   # decoder cross-attends to encoder outputs
)
