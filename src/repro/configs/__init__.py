"""Config registry: ``get_config("<arch-id>")`` and input shapes."""
from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig  # noqa: F401

_ARCH_MODULES = {
    "xlstm-350m": "xlstm_350m",
    "gemma2-27b": "gemma2_27b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "zamba2-2.7b": "zamba2_2_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-1.5b": "qwen2_1_5b",
    "mistral-large-123b": "mistral_large_123b",
    "gemma-2b": "gemma_2b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    import importlib
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


def all_configs():
    return {n: get_config(n) for n in ARCH_NAMES}
