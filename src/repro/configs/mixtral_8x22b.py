"""Mixtral 8x22B [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

56L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384 per expert, vocab=32768.
"""
from repro.configs.base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32_768,
    group_pattern=(MOE,),
    attn_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    rope_theta=1_000_000.0,
)
