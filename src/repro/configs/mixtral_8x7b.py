"""Mixtral 8x7B [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336 per expert, vocab=32000,
MoE 8 experts top-2, SWA window 4096 (per the assignment sheet).
"""
from repro.configs.base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    group_pattern=(MOE,),
    attn_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    rope_theta=1_000_000.0,
)
