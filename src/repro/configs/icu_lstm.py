"""The paper's three ICU AI workloads (Edge AIBench / MIMIC-III, Table IV).

Each is an LSTM classifier over clinical time series (Harutyunyan et al.,
Scientific Data 2019 benchmark family): 76 input features per timestep,
a small LSTM, and a linear head. The paper characterises each model only by
its FLOPs count (per unit of data) and priority weight; we pick LSTM sizes
whose analytic FLOPs (utils.flops.lstm_flops) land on the paper's numbers,
and ALSO carry the paper's published FLOPs verbatim for the benchmark
reproduction (benchmarks use ``paper_flops``; our model uses the real dims).

Paper Table IV:
  short-of-breath alerts        comp=105,089  w=2
  life-death prediction         comp=  7,569  w=2
  patient phenotype class.      comp=347,417  w=1
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ICULSTMConfig:
    name: str
    input_dim: int          # clinical features per timestep
    hidden: int             # LSTM hidden size
    depth: int              # stacked LSTM layers
    num_classes: int
    priority: int           # paper's w_i
    paper_flops: int        # paper Table IV "Model FLOPs" (per data unit)
    seq_len: int = 48       # 48 hourly measurements, per the clinical benchmark


SHORT_OF_BREATH = ICULSTMConfig(
    name="short-of-breath-alerts", input_dim=76, hidden=16, depth=1,
    num_classes=2, priority=2, paper_flops=105_089)

LIFE_DEATH = ICULSTMConfig(
    name="life-death-prediction", input_dim=17, hidden=8, depth=1,
    num_classes=2, priority=2, paper_flops=7_569)

PHENOTYPE = ICULSTMConfig(
    name="patient-phenotype-classification", input_dim=76, hidden=32, depth=1,
    num_classes=25, priority=1, paper_flops=347_417)

ICU_WORKLOADS: Tuple[ICULSTMConfig, ...] = (SHORT_OF_BREATH, LIFE_DEATH,
                                            PHENOTYPE)

# Paper Table IV data sizes (record-count proportional units)
DATA_SIZES = (64, 128, 256, 512, 1024, 2048)
