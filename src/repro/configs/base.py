"""Config dataclasses for models, input shapes, and runtime.

Every assigned architecture gets one file in this package defining a
``ModelConfig`` with the exact dimensions from the assignment sheet (source
paper / model card cited in the module docstring). ``layer_groups`` describes
the repeated block pattern that ``models.model_zoo`` scans over — keeping the
HLO small enough for 1-core CPU AOT compiles of 88-layer models.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kinds understood by models.model_zoo
ATTN = "attn"                # self-attention (+ MLP)
ATTN_LOCAL = "attn_local"    # sliding-window self-attention (+ MLP)
ATTN_GLOBAL = "attn_global"  # full self-attention (+ MLP), used by alternating archs
MOE = "moe"                  # self-attention + MoE MLP
MAMBA = "mamba"              # Mamba2 SSM block
SHARED_ATTN = "shared_attn"  # attention block with SHARED weights (zamba2)
CROSS = "cross"              # cross-attention (+ MLP) consuming encoder/vision states
SLSTM = "slstm"              # xLSTM sLSTM block
MLSTM = "mlstm"              # xLSTM mLSTM block

BLOCK_KINDS = (ATTN, ATTN_LOCAL, ATTN_GLOBAL, MOE, MAMBA, SHARED_ATTN, CROSS,
               SLSTM, MLSTM)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int                  # total decoder blocks (== groups * len(group))
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Layer pattern: the model scans `num_groups` copies of `group_pattern`.
    group_pattern: Tuple[str, ...] = (ATTN,)
    num_groups: int = 0              # filled in __post_init__ if 0

    # attention details
    attn_window: Optional[int] = None     # sliding-window size for ATTN_LOCAL
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mlp_type: str = "swiglu"              # swiglu | geglu | gelu

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    # expert parallelism: store experts EP-major as (E*r, d, f/r) with the
    # leading dim on "model" and dispatch tokens via all_to_all
    # (sharding/ep_moe.py). 0 = tensor-parallel MoE (baseline).
    moe_ep_shards: int = 0

    # SSM (mamba2) / xLSTM
    ssm_state_dim: int = 0
    ssm_num_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # VLM
    cross_attn_states: int = 0       # number of encoder/vision tokens
    vision_dim: int = 0              # raw patch-embedding dim before projector

    # audio / enc-dec
    encoder_layers: int = 0
    encoder_frames: int = 0          # audio frame count fed to the encoder

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # "int8": symmetric per-(head, slot) quantised KV cache — halves the
    # decode HBM roofline term (EXPERIMENTS.md §Perf iteration 1.4)
    kv_cache_dtype: str = "native"   # native | int8

    # set True (via replace) to force sliding-window KV for long_500k on
    # pure full-attention archs — the explicit variant flagged in DESIGN.md §4
    long_context_window: Optional[int] = None

    def __post_init__(self):
        if self.num_groups == 0:
            if self.num_layers % len(self.group_pattern):
                raise ValueError(
                    f"{self.name}: num_layers {self.num_layers} not a "
                    f"multiple of group_pattern {self.group_pattern}")
            object.__setattr__(self, "num_groups",
                               self.num_layers // len(self.group_pattern))
        if self.num_groups * len(self.group_pattern) != self.num_layers:
            raise ValueError(
                f"{self.name}: num_groups {self.num_groups} x pattern "
                f"{self.group_pattern} != num_layers {self.num_layers}")
        for k in self.group_pattern:
            if k not in BLOCK_KINDS:
                raise ValueError(f"{self.name}: unknown block kind {k!r} "
                                 f"(known: {sorted(BLOCK_KINDS)})")
        if self.num_heads and self.num_kv_heads:
            if self.num_heads % self.num_kv_heads:
                raise ValueError(
                    f"{self.name}: num_heads {self.num_heads} not a "
                    f"multiple of num_kv_heads {self.num_kv_heads}")

    # ---- convenience ----
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_recurrent(self) -> bool:
        """True if decode state is O(1) in context length (no growing KV)."""
        return all(k in (MAMBA, SLSTM, MLSTM) for k in self.group_pattern)

    @property
    def has_quadratic_prefill(self) -> bool:
        return any(k in (ATTN, ATTN_GLOBAL, MOE, CROSS, SHARED_ATTN)
                   for k in self.group_pattern) and self.attn_window is None

    def reduced(self, *, layers: Optional[int] = None, d_model: int = 256,
                vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests (<=2 groups,
        d_model<=512, <=4 experts)."""
        pat = self.group_pattern
        groups = 1 if layers is None else max(1, layers // len(pat))
        heads = max(1, min(4, self.num_heads))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        hd = max(8, d_model // heads)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=groups * len(pat),
            num_groups=groups,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=d_model * 2 if self.d_ff else 0,
            vocab_size=vocab,
            num_experts=min(4, self.num_experts) if self.num_experts else 0,
            num_experts_per_tok=min(2, self.num_experts_per_tok)
            if self.num_experts_per_tok else 0,
            # dropless in smoke tests so prefix logits are length-invariant
            moe_capacity_factor=float(min(4, self.num_experts) or 1),
            ssm_state_dim=min(16, self.ssm_state_dim) if self.ssm_state_dim else 0,
            ssm_num_heads=min(2, self.ssm_num_heads) if self.ssm_num_heads else 0,
            ssm_head_dim=(d_model * self.ssm_expand) // max(1, min(2, self.ssm_num_heads))
            if self.ssm_num_heads else 0,
            ssm_chunk=64,
            attn_window=min(64, self.attn_window) if self.attn_window else None,
            cross_attn_states=min(16, self.cross_attn_states)
            if self.cross_attn_states else 0,
            vision_dim=min(64, self.vision_dim) if self.vision_dim else 0,
            encoder_layers=min(2, self.encoder_layers) if self.encoder_layers else 0,
            encoder_frames=min(32, self.encoder_frames) if self.encoder_frames else 0,
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}
