"""Tier specifications for the hierarchical cloud/edge/device fleet.

The paper's three tiers (Section II) carry a compute rate (FLOPS) and a
network function (latency + bandwidth from the data source, which by
assumption (a) is the device tier). The TPU-native fleet maps the same
structure onto pod-slice / host-slice / single-chip tiers (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Tuple

# v5e hardware constants (also used by the roofline analysis)
TPU_PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
TPU_HBM_BW = 819e9           # bytes/s per chip
TPU_ICI_BW = 50e9            # bytes/s per link
DCN_BW = 25e9 / 8            # ~25 Gb/s host DCN, bytes/s
DCN_LATENCY = 1e-3           # cross-metro DCN round trip budget (one-way)
LAN_BW = 10e9 / 8            # edge LAN
LAN_LATENCY = 50e-6


@dataclass(frozen=True)
class TierSpec:
    """One tier of the hierarchy.

    flops:        aggregate peak FLOP/s of one machine at this tier.
    net_latency:  one-way latency (s) from the data source to this tier.
    net_bw:       bandwidth (bytes/s) from the data source to this tier.
    machines:     number of shared machines at this tier.
    private:      device tier — every job owns its machine (paper Sec. V).
    hbm_bw:       aggregate memory bandwidth (beyond-paper roofline model).
    efficiency:   de-rate on peak flops (e.g. measured roofline fraction).
    """
    name: str
    flops: float
    net_latency: float = 0.0
    net_bw: float = float("inf")
    machines: int = 1
    private: bool = False
    hbm_bw: float = 0.0
    efficiency: float = 1.0

    @property
    def effective_flops(self) -> float:
        return self.flops * self.efficiency


# Paper tier ids
CC, ES, ED = "cloud", "edge", "device"


def paper_tiers() -> Dict[str, TierSpec]:
    """The paper's experimental testbed (Section VII, Table III + [36])."""
    return {
        CC: TierSpec(CC, flops=422.4e9, net_latency=42e-3, net_bw=2.9e6),
        ES: TierSpec(ES, flops=140.8e9, net_latency=0.239e-3, net_bw=10e6),
        ED: TierSpec(ED, flops=96e9, private=True),
    }


def tpu_tiers(*, cloud_chips: int = 512, edge_chips: int = 16,
              device_chips: int = 1) -> Dict[str, TierSpec]:
    """TPU-fleet analogue: multi-pod cloud slice, host-slice edge, one-chip
    device co-located with the request source (DESIGN.md §2)."""
    return {
        CC: TierSpec(CC, flops=cloud_chips * TPU_PEAK_FLOPS,
                     net_latency=DCN_LATENCY, net_bw=DCN_BW,
                     hbm_bw=cloud_chips * TPU_HBM_BW),
        ES: TierSpec(ES, flops=edge_chips * TPU_PEAK_FLOPS,
                     net_latency=LAN_LATENCY, net_bw=LAN_BW,
                     hbm_bw=edge_chips * TPU_HBM_BW),
        ED: TierSpec(ED, flops=device_chips * TPU_PEAK_FLOPS,
                     private=True, hbm_bw=device_chips * TPU_HBM_BW),
    }


TIER_ORDER: Tuple[str, str, str] = (CC, ES, ED)
