"""Online (non-clairvoyant) scheduling — beyond-paper extension.

The paper's Algorithm 2 is offline: all release times are known up front.
In a real ER, jobs appear when patients deteriorate. This module provides
an event-driven online scheduler: at every job release it re-plans the
not-yet-started jobs with the paper's own machinery (Algorithm 1 costs +
greedy/tabu search), honouring commitments already made (running jobs are
non-preemptible, C2).

The replanned problem is the COMMITTED problem (DESIGN.md §7): each
replan hands `scheduler.search` the true fleet state — multi-server
tiers via `machines_per_tier` and the free time of every machine still
occupied by a started job via `busy_until` — and the plan's start/end
times are committed verbatim. The objective the search optimises is
therefore bit-for-bit the objective of the commits it produces
(`tests/test_online.py::test_replan_objective_parity`).

Transmission on replan (C4 under re-decision): a pending job's data
shipped toward its committed tier at release, so staying there keeps
arrival = release + transmission (clamped at `now` — data already in
flight counts); moving to any other tier re-ships from the device at
`now`, so arrival = now + transmission. New arrivals have no commitment
and ship wherever the plan puts them.

`competitive_ratio` measures the price of not knowing the future against
the clairvoyant offline optimum on the same instance — reported per
arrival scenario in benchmarks/scheduler_scale.py.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Mapping, Sequence

from repro.core import scheduler
from repro.core.simulator import (MACHINES, JobSpec, Reservation, Schedule,
                                  ScheduledJob, machine_free_times, simulate)
from repro.core.tiers import CC, ED, ES

_SHARED = (CC, ES)


@dataclass
class _Commit:
    job: JobSpec
    machine: str
    arrival: float
    start: float
    end: float


def _replan_spec(job: JobSpec, commit: _Commit | None, now: float) -> JobSpec:
    """The job as the replan at time `now` sees it.

    Release is shifted to `now` (nothing can be decided earlier); the
    per-tier transmission becomes the REMAINING shipping time: the tier
    the job is already committed to keeps its in-flight data (arrival
    max(now, release + trans), i.e. remaining = max(0, arrival - now)),
    every other tier re-ships from scratch. Shifting every movable job's
    release by the same event time changes each candidate's objective by
    the same constant, so the argmin — and the committed starts/ends —
    are those of the true problem.
    """
    if commit is None or commit.machine == ED:
        return replace(job, release=now)
    trans = dict(job.trans)
    # commit.arrival is when the data actually reaches the committed tier
    # (it re-ships on every move, so release + trans would undercount)
    trans[commit.machine] = max(0.0, commit.arrival - now)
    return replace(job, release=now, trans=trans)


def _busy_vectors(commits: Sequence[_Commit | None], movable: Sequence[int],
                  now: float, machines_per_tier: Mapping[str, int]
                  ) -> Dict[str, List[float]]:
    """Free times of shared machines still occupied by surviving commits.

    Survivors all started at or before `now` (movable jobs are exactly
    those with a future start), so the ones still running at `now` overlap
    there — at most one per machine. Machines whose last job already ended
    are free immediately.
    """
    movable_set = set(movable)
    busy: Dict[str, List[float]] = {t: [] for t in _SHARED}
    for i, c in enumerate(commits):
        if c is None or i in movable_set or c.machine not in busy:
            continue
        if c.end > now:
            busy[c.machine].append(c.end)
    for tier in _SHARED:
        # ValueError, not assert: this guards real caller bugs (commit
        # bookkeeping gone wrong) and must survive ``python -O``
        if len(busy[tier]) > machines_per_tier.get(tier, 1):
            raise ValueError(f"more running jobs than machines on {tier}: "
                             f"{len(busy[tier])} > "
                             f"{machines_per_tier.get(tier, 1)}")
    return busy


def online_schedule(jobs: Sequence[JobSpec], *,
                    replan: str = "greedy",
                    jax_threshold: int | None = None,
                    machines_per_tier: Mapping[str, int] | None = None,
                    trace: List[dict] | None = None) -> Schedule:
    """Event-driven scheduling: jobs become visible at their release.

    replan: "greedy" (assign on arrival, paper's greedy rule) |
            "tabu" (re-run the neighbourhood search over all visible,
            unstarted jobs at every release event).
    jax_threshold: passed to scheduler.search — replans over more than
    this many movable jobs run on the jitted JAX path (default: only when
    an accelerator backend is present; see DESIGN.md §3.3). At real event
    rates the replan at each release is the hot path, so it dispatches
    through the same fast search as the offline planner.
    machines_per_tier: shared-server counts (TierSpec.machines); both
    replan modes honour multi-server fleets.
    trace: if a list is passed, one dict per tabu replan event is appended
    with the search-reported objective, the objective of the commits
    recorded, and the busy vectors used — the replan==commit invariant's
    audit trail (DESIGN.md §7).
    """
    mpt = dict(machines_per_tier or {CC: 1, ES: 1})
    order = sorted(range(len(jobs)), key=lambda i: (jobs[i].release, i))
    commits: List[_Commit | None] = [None] * len(jobs)
    # greedy mode: per-tier machine free times, maintained incrementally
    free = {t: machine_free_times(None, t, mpt.get(t, 1)) for t in _SHARED}
    pending: List[int] = []

    for idx in order:
        job = jobs[idx]
        now = job.release
        pending.append(idx)
        if replan == "tabu":
            # replan every job whose machine slot hasn't begun (C2: started
            # jobs are committed for good and only constrain availability)
            movable = [i for i in pending
                       if commits[i] is None or commits[i].start > now]
            shifted = [_replan_spec(jobs[i], commits[i], now)
                       for i in movable]
            busy = _busy_vectors(commits, movable, now, mpt)
            plan = scheduler.search(shifted, max_count=5,
                                    jax_threshold=jax_threshold,
                                    machines_per_tier=mpt, busy_until=busy)
            # commit the plan verbatim: the entries' starts/ends ARE the
            # schedule the search scored (plan.entries aligns with shifted)
            for entry, i in zip(plan.entries, movable):
                commits[i] = _Commit(jobs[i], entry.machine, entry.arrival,
                                     entry.start, entry.end)
            if trace is not None:
                committed = sum(
                    s.weight * (commits[i].end - s.release)
                    for s, i in zip(shifted, movable))
                trace.append({"now": now, "movable": list(movable),
                              "busy": busy, "reported": plan.weighted_sum,
                              "committed": committed})
            pending = movable
        else:
            # paper greedy on arrival — the same rule as the offline
            # initial solution, one event at a time (scheduler.greedy_schedule)
            tier = scheduler.greedy_schedule(
                [job], machines_per_tier=mpt,
                busy_until={t: free[t] for t in _SHARED})[0]
            arr = now + job.trans.get(tier, 0.0)
            if tier == ED:
                start = arr
            else:
                vec = free[tier]
                k = min(range(len(vec)), key=vec.__getitem__)
                start = max(arr, vec[k])
                vec[k] = start + job.proc[tier]
            commits[idx] = _Commit(job, tier, arr, start,
                                   start + job.proc[tier])

    entries = [ScheduledJob(c.job, c.machine, c.arrival, c.start, c.end)
               for c in commits]
    weighted = sum(e.job.weight * e.response for e in entries)
    unweighted = sum(e.response for e in entries)
    return Schedule(entries=entries, weighted_sum=weighted,
                    unweighted_sum=unweighted,
                    last_end=max(e.end for e in entries))


def online_schedule_fleet(ward_jobs: Sequence[Sequence[JobSpec]], *,
                          machines_per_tier: Mapping[str, int] | None = None,
                          max_count: int = 5,
                          jax_threshold: int | None = None
                          ) -> List[Schedule]:
    """Ward-aware online replanning on a shared metropolitan cloud
    (DESIGN.md §9) — the online counterpart of `scheduler.search_fleet`.

    One global event stream over every ward's releases. At each release in
    ward b, ward b's unstarted jobs are replanned against the TRUE fleet
    state:

      * the shared cloud pool's busy vector collects machines still
        running ANY ward's started cloud job (cross-ward, so no two wards
        can ever double-book a cloud server);
      * every other ward's committed-but-unstarted cloud job enters the
        replan as an interval RESERVATION (DESIGN.md §12) — immovable
        (C2 belongs to its own ward), but fully present in the merged
        FIFO queue, so ward b pays the queueing delay it inflicts and
        vice versa;
      * reservations are re-timed (never re-decided) from the plan's
        ``reserved_times``, so each commitment's recorded start/end
        stays consistent with the merged queue as other wards' arrivals
        interleave.

    Per-ward edge pools and private devices replan exactly as the
    single-ward `online_schedule` (tabu mode). With B = 1 the background
    is empty every event and this IS `online_schedule(replan="tabu")`.
    Returns one Schedule of verbatim commits per ward."""
    mpt = dict(machines_per_tier or {CC: 1, ES: 1})
    B = len(ward_jobs)
    commits: List[List[_Commit | None]] = [
        [None] * len(jobs) for jobs in ward_jobs]
    pending: List[List[int]] = [[] for _ in range(B)]
    events = sorted((jobs[i].release, b, i)
                    for b, jobs in enumerate(ward_jobs)
                    for i in range(len(jobs)))

    for now, b, i in events:
        pending[b].append(i)
        movable = [j for j in pending[b]
                   if commits[b][j] is None or commits[b][j].start > now]
        movable_set = set(movable)
        shifted = [_replan_spec(ward_jobs[b][j], commits[b][j], now)
                   for j in movable]
        # fleet-wide cloud occupancy + other wards' unstarted cloud jobs
        cloud_busy: List[float] = []
        bg: List[tuple] = []
        for c in range(B):
            for j, cm in enumerate(commits[c]):
                if cm is None or cm.machine != CC or \
                        (c == b and j in movable_set):
                    continue
                if cm.start <= now:
                    if cm.end > now:
                        cloud_busy.append(cm.end)
                elif c != b:
                    bg.append((c, j))
        edge_busy = [cm.end for j, cm in enumerate(commits[b])
                     if cm is not None and cm.machine == ES
                     and j not in movable_set and cm.start <= now < cm.end]
        busy = {CC: cloud_busy, ES: edge_busy}
        if bg:
            bg_specs = [_replan_spec(ward_jobs[c][j], commits[c][j], now)
                        for c, j in bg]
            resv = {CC: [Reservation(
                arrival=s.release + s.trans.get(CC, 0.0), proc=s.proc[CC],
                release=s.release, weight=s.weight) for s in bg_specs]}
            initial = [commits[b][j].machine if commits[b][j] is not None
                       else ED for j in movable]
            plan = scheduler.search(shifted, initial=initial, reserved=resv,
                                    max_count=max_count,
                                    jax_threshold=jax_threshold,
                                    machines_per_tier=mpt, busy_until=busy)
        else:
            plan = scheduler.search(shifted, max_count=max_count,
                                    jax_threshold=jax_threshold,
                                    machines_per_tier=mpt, busy_until=busy)
        # ward b's movable jobs commit verbatim; reservations RE-TIME
        # (machine unchanged) so their commitments track the merged queue
        for entry, j in zip(plan.entries, movable):
            commits[b][j] = _Commit(ward_jobs[b][j], entry.machine,
                                    entry.arrival, entry.start, entry.end)
        if bg:
            for (arr, start, end), (c, j) in zip(plan.reserved_times[CC],
                                                 bg):
                cm = commits[c][j]
                commits[c][j] = _Commit(cm.job, cm.machine, arr, start, end)
        pending[b] = movable

    out = []
    for b in range(B):
        entries = [ScheduledJob(c.job, c.machine, c.arrival, c.start, c.end)
                   for c in commits[b]]
        out.append(Schedule(
            entries=entries,
            weighted_sum=sum(e.job.weight * e.response for e in entries),
            unweighted_sum=sum(e.response for e in entries),
            last_end=max((e.end for e in entries), default=0.0)))
    return out


def competitive_ratio(jobs: Sequence[JobSpec], replan: str = "tabu", *,
                      jax_threshold: int | None = None,
                      machines_per_tier: Mapping[str, int] | None = None
                      ) -> float:
    """online / clairvoyant-offline weighted response ratio (>= ~1).

    The offline side goes through the size-dispatched `scheduler.search`,
    so fleet-scale ratios use the same jitted path as the replanner.
    """
    online = online_schedule(jobs, replan=replan,
                             jax_threshold=jax_threshold,
                             machines_per_tier=machines_per_tier)
    offline = scheduler.search(jobs, jax_threshold=jax_threshold,
                               machines_per_tier=machines_per_tier)
    return online.weighted_sum / max(offline.weighted_sum, 1e-9)


def competitive_ratio_fleet(ward_jobs: Sequence[Sequence[JobSpec]], *,
                            machines_per_tier: Mapping[str, int] | None
                            = None,
                            max_count: int = 5,
                            max_sweeps: int = 8,
                            jax_threshold: int | None = None) -> Dict:
    """Online fleet replanning vs the clairvoyant fixed point
    (DESIGN.md §9): `online_schedule_fleet`'s committed fleet-true
    objective over `scheduler.search_fleet`'s — the multi-ward price of
    not knowing the future, on the same shared metropolitan cloud.

    Both sides are fleet-true (the online commits never double-book the
    cloud; the clairvoyant plan is scored by `simulate_fleet`), so the
    ratio is meaningfully >= ~1. Returns {"online", "clairvoyant",
    "ratio", "sweeps"} — recorded per seed by
    benchmarks/scheduler_scale.py --online."""
    online_scheds = online_schedule_fleet(
        ward_jobs, machines_per_tier=machines_per_tier,
        max_count=max_count, jax_threshold=jax_threshold)
    online_total = sum(s.weighted_sum for s in online_scheds)
    plan = scheduler.search_fleet(
        ward_jobs, machines_per_tier=machines_per_tier,
        max_count=max_count * 10, max_sweeps=max_sweeps,
        jax_threshold=jax_threshold)
    clair = plan.fleet.weighted_sum
    return {"online": float(online_total), "clairvoyant": float(clair),
            "ratio": float(online_total / max(clair, 1e-9)),
            "sweeps": plan.sweeps}


def competitive_ratio_batch(instances: Sequence[Sequence[JobSpec]],
                            replans: Sequence[str] = ("greedy", "tabu"), *,
                            jax_threshold: int | None = None,
                            machines_per_tier: Mapping[str, int] | None
                            = None,
                            min_batch: int | None = None
                            ) -> Dict[str, List[float]]:
    """Competitive ratios for a whole sweep of instances, with ONE
    batched clairvoyant baseline call shared by every replan mode.

    The offline optimum is the expensive side of a ratio sweep — it sees
    the full instance while the online replanner only ever optimises the
    visible suffix. `scheduler.search_batched` plans all instances in a
    single jitted device call (DESIGN.md §8), so the sweep cost is one
    batched search plus the (inherently event-sequential) online runs.

    Returns {replan mode: [ratio per instance]}."""
    # jax_threshold reaches BOTH sides of the ratio: the online replanner
    # below and the clairvoyant baseline's sequential fallback (small
    # batches loop per-instance `search`, which would otherwise dispatch
    # on a different backend than the online side — §3.3)
    offline = scheduler.search_batched(
        list(instances), machines_per_tier=machines_per_tier,
        min_batch=min_batch, jax_threshold=jax_threshold)
    out: Dict[str, List[float]] = {}
    for replan in replans:
        out[replan] = [
            online_schedule(jobs, replan=replan,
                            jax_threshold=jax_threshold,
                            machines_per_tier=machines_per_tier)
            .weighted_sum / max(off.weighted_sum, 1e-9)
            for jobs, off in zip(instances, offline)]
    return out
