"""Online (non-clairvoyant) scheduling — beyond-paper extension.

The paper's Algorithm 2 is offline: all release times are known up front.
In a real ER, jobs appear when patients deteriorate. This module provides
an event-driven online scheduler: at every job release it re-plans the
not-yet-started jobs with the paper's own machinery (Algorithm 1 costs +
greedy/tabu search), honouring commitments already made (running jobs are
non-preemptible, C2).

`competitive_ratio` measures the price of not knowing the future against
the clairvoyant offline optimum on the same instance — reported in
benchmarks/scheduler_scale.py.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Sequence

from repro.core import scheduler
from repro.core.simulator import (MACHINES, JobSpec, Schedule, ScheduledJob,
                                  simulate)
from repro.core.tiers import CC, ED, ES


@dataclass
class _Commit:
    job: JobSpec
    machine: str
    arrival: float
    start: float
    end: float


def online_schedule(jobs: Sequence[JobSpec], *,
                    replan: str = "greedy",
                    jax_threshold: int | None = None) -> Schedule:
    """Event-driven scheduling: jobs become visible at their release.

    replan: "greedy" (assign on arrival, paper's greedy rule) |
            "tabu" (re-run the neighbourhood search over all visible,
            unstarted jobs at every release event).
    jax_threshold: passed to scheduler.search — replans over more than
    this many movable jobs run on the jitted JAX path (default: only when
    an accelerator backend is present; see DESIGN.md §3.3). At real event
    rates the replan at each release is the hot path, so it dispatches
    through the same fast search as the offline planner.
    """
    order = sorted(range(len(jobs)), key=lambda i: (jobs[i].release, i))
    free: Dict[str, float] = {CC: 0.0, ES: 0.0}
    commits: List[_Commit] = [None] * len(jobs)  # type: ignore

    pending: List[int] = []
    for idx in order:
        job = jobs[idx]
        now = job.release
        pending.append(idx)
        if replan == "tabu" and len(pending) > 1:
            # re-plan every pending (committed-but-not-started) job whose
            # machine slot hasn't begun yet
            movable = [i for i in pending
                       if commits[i] is None or commits[i].start > now]
            visible = [jobs[i] for i in movable]
            # shift releases so the replan can't schedule before `now`
            shifted = [replace(j, release=max(j.release, now))
                       for j in visible]
            plan = scheduler.search(shifted, max_count=5,
                                    jax_threshold=jax_threshold)
            # machine availability = only commitments that survive (jobs
            # already started on a shared machine)
            movable_set = set(movable)
            base_free = {CC: 0.0, ES: 0.0}
            for i, c in enumerate(commits):
                if c is not None and i not in movable_set \
                        and c.machine in base_free:
                    base_free[c.machine] = max(base_free[c.machine], c.end)
            # wipe and re-commit in the plan's machine order
            for i in movable:
                commits[i] = None
            for entry, i in sorted(
                    zip(plan.entries, movable), key=lambda t: t[0].start):
                tier = entry.machine
                arr = jobs[i].release + jobs[i].trans.get(tier, 0.0)
                start = arr if tier == ED else max(arr, base_free[tier], now)
                end = start + jobs[i].proc[tier]
                if tier != ED:
                    base_free[tier] = end
                commits[i] = _Commit(jobs[i], tier, arr, start, end)
            free = base_free
        else:
            # paper greedy on arrival
            best_t, best_end = None, float("inf")
            for tier in (ED, ES, CC):
                arr = now + job.trans.get(tier, 0.0)
                start = arr if tier == ED else max(arr, free[tier])
                end = start + job.proc[tier]
                if end < best_end:
                    best_t, best_end = tier, end
            arr = now + job.trans.get(best_t, 0.0)
            start = arr if best_t == ED else max(arr, free[best_t])
            commits[idx] = _Commit(job, best_t, arr, start,
                                   start + job.proc[best_t])
            if best_t != ED:
                free[best_t] = commits[idx].end

    entries = [ScheduledJob(c.job, c.machine, c.arrival, c.start, c.end)
               for c in commits]
    weighted = sum(e.job.weight * e.response for e in entries)
    unweighted = sum(e.response for e in entries)
    return Schedule(entries=entries, weighted_sum=weighted,
                    unweighted_sum=unweighted,
                    last_end=max(e.end for e in entries))


def competitive_ratio(jobs: Sequence[JobSpec], replan: str = "tabu") -> float:
    """online / clairvoyant-offline weighted response ratio (>= ~1)."""
    online = online_schedule(jobs, replan=replan)
    offline = scheduler.neighborhood_search(jobs)
    return online.weighted_sum / max(offline.weighted_sum, 1e-9)
