# The paper's primary contribution: hierarchical cloud/edge/device workload
# allocation. tiers/cost_model/allocator implement Section III-IV
# (Algorithm 1); simulator/scheduler implement Section V-VI (Algorithm 2);
# scheduler_jax adds vectorised on-device schedule search (beyond paper).
from repro.core.allocator import Allocation, allocate_single  # noqa: F401
from repro.core.cost_model import (AnalyticCostModel,  # noqa: F401
                                   CalibratedCostModel, Job,
                                   RooflineCostModel, Workload)
from repro.core.simulator import JobSpec, Schedule, simulate  # noqa: F401
from repro.core.tiers import (CC, ED, ES, TierSpec, paper_tiers,  # noqa: F401
                              tpu_tiers)
