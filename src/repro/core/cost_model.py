"""Latency cost models (paper Section III).

Three interchangeable models, all exposing

    transmission_time(tier_id, job) -> D_i   (eq. 2)
    processing_time(tier_id, job)  -> I_i    (eq. 3)
    response_time(tier_id, job)    -> T_i    (eq. 4, = D_i + I_i)

* ``AnalyticCostModel`` — the paper's FLOPS-only model in physical seconds:
  I = lam2 * s * comp / AI_i, D = lam1 * (latency + s*bytes/bw).
* ``CalibratedCostModel`` — the paper's actual experimental procedure: unit
  costs are *measured* per (workload, tier) on a small dataset (this is how
  lam1/lam2 are folded in, Algorithm 1 steps 2-8), then scaled linearly in s.
  Table V is exactly linear in s, confirming this reading.
* ``RooflineCostModel`` — beyond-paper: processing time is the max of the
  compute and HBM roofline terms derived from the dry-run artifacts
  (launch/dryrun.py), not FLOPS alone. On TPUs decode is memory-bound, so
  the FLOPS-only model misranks tiers for decode jobs; see EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

from repro.core.tiers import ED, TIER_ORDER, TierSpec


@dataclass(frozen=True)
class Workload:
    """A model/application whose inference jobs get placed on tiers."""
    name: str
    comp: float            # FLOPs per data unit (paper: model FLOPs)
    unit_bytes: float      # bytes per data unit
    priority: int = 1      # paper's w_i
    hbm_bytes: float = 0.0  # bytes moved per data unit (roofline model)


@dataclass(frozen=True)
class Job:
    workload: Workload
    size: float            # data units (paper Table IV "Data Size")
    release: float = 0.0   # R_i
    name: str = ""

    @property
    def priority(self) -> int:
        return self.workload.priority


class CostModel:
    def __init__(self, tiers: Mapping[str, TierSpec]):
        self.tiers = dict(tiers)

    def transmission_time(self, tier_id: str, job: Job) -> float:
        raise NotImplementedError

    def processing_time(self, tier_id: str, job: Job) -> float:
        raise NotImplementedError

    def response_time(self, tier_id: str, job: Job) -> float:
        return self.transmission_time(tier_id, job) + \
            self.processing_time(tier_id, job)

    def times(self, job: Job) -> Dict[str, Tuple[float, float]]:
        """{tier: (transmission D_i, processing I_i)} for every tier."""
        return {t: (self.transmission_time(t, job),
                    self.processing_time(t, job)) for t in self.tiers}


class AnalyticCostModel(CostModel):
    """Paper eq. (2)-(3) in physical units."""

    def __init__(self, tiers, lam1: float = 1.0, lam2: float = 1.0):
        super().__init__(tiers)
        self.lam1, self.lam2 = lam1, lam2

    def transmission_time(self, tier_id, job):
        tier = self.tiers[tier_id]
        if tier.private:          # assumption (a): data originates here
            return 0.0
        bytes_ = job.size * job.workload.unit_bytes
        return self.lam1 * (tier.net_latency + bytes_ / tier.net_bw)

    def processing_time(self, tier_id, job):
        tier = self.tiers[tier_id]
        return self.lam2 * job.size * job.workload.comp / tier.effective_flops


class CalibratedCostModel(CostModel):
    """Unit costs measured per (workload, tier), scaled linearly in size.

    unit_proc[(workload_name, tier)] and unit_trans[(workload_name, tier)]
    are per-data-unit measurements (the paper's small-dataset calibration);
    lam1/lam2 are already folded into them.
    """

    def __init__(self, tiers, unit_proc: Mapping[Tuple[str, str], float],
                 unit_trans: Mapping[Tuple[str, str], float]):
        super().__init__(tiers)
        self.unit_proc = dict(unit_proc)
        self.unit_trans = dict(unit_trans)

    @classmethod
    def from_measurements(cls, tiers, measurements):
        """measurements: {(workload_name, tier): (proc_total, trans_total,
        size)} from a calibration run; converts to unit costs."""
        up, ut = {}, {}
        for (w, t), (proc, trans, size) in measurements.items():
            up[(w, t)] = proc / size
            ut[(w, t)] = trans / size
        return cls(tiers, up, ut)

    def transmission_time(self, tier_id, job):
        if self.tiers[tier_id].private:
            return 0.0
        return job.size * self.unit_trans[(job.workload.name, tier_id)]

    def processing_time(self, tier_id, job):
        return job.size * self.unit_proc[(job.workload.name, tier_id)]


class RooflineCostModel(CostModel):
    """Beyond-paper: I_i = max(compute-term, memory-term) per tier.

    Needs workload.hbm_bytes (bytes moved per data unit, e.g. from the
    dry-run cost_analysis) and tier.hbm_bw.
    """

    def __init__(self, tiers, lam1: float = 1.0, lam2: float = 1.0):
        super().__init__(tiers)
        self.lam1, self.lam2 = lam1, lam2

    def transmission_time(self, tier_id, job):
        tier = self.tiers[tier_id]
        if tier.private:
            return 0.0
        bytes_ = job.size * job.workload.unit_bytes
        return self.lam1 * (tier.net_latency + bytes_ / tier.net_bw)

    def processing_time(self, tier_id, job):
        tier = self.tiers[tier_id]
        compute = job.size * job.workload.comp / tier.effective_flops
        memory = 0.0
        if job.workload.hbm_bytes and tier.hbm_bw:
            memory = job.size * job.workload.hbm_bytes / tier.hbm_bw
        return self.lam2 * max(compute, memory)
