"""JAX-vectorised schedule evaluation and search (beyond-paper).

The paper's heuristic evaluates one candidate schedule at a time in Python.
For fleet-scale serving (thousands of jobs, many candidate assignments) we
evaluate assignment *batches* on-device: the C1-C5 semantics (FIFO by
arrival per shared machine) vectorise as argsort + lax.scan per machine,
vmapped over candidates. Used for:

  * exact small-n optimum: enumerate all 3^n assignments in one vmap;
  * random-restart stochastic local search at scales where the Python
    tabu search is too slow;
  * jittable evaluation inside the serving engine's control loop.

Machine encoding: 0 = cloud, 1 = edge, 2 = device (private).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import JobSpec
from repro.core.tiers import CC, ED, ES

N_MACHINES = 3


def specs_to_arrays(jobs: Sequence[JobSpec]):
    """-> release (n,), weight (n,), proc (n,3), trans (n,3)."""
    rel = jnp.asarray([j.release for j in jobs], jnp.float32)
    w = jnp.asarray([j.weight for j in jobs], jnp.float32)
    proc = jnp.asarray([[j.proc[CC], j.proc[ES], j.proc[ED]] for j in jobs],
                       jnp.float32)
    trans = jnp.asarray([[j.trans[CC], j.trans[ES],
                          j.trans.get(ED, 0.0)] for j in jobs], jnp.float32)
    return rel, w, proc, trans


@functools.partial(jax.jit, static_argnames=())
def evaluate_assignments(assign, rel, w, proc, trans):
    """assign: (A, n) int32 in {0, 1, 2}. Returns dict of (A,) metrics."""

    def eval_one(a):
        n = a.shape[0]
        idx = jnp.arange(n)
        arr = rel + trans[idx, a]
        p = proc[idx, a]
        end = jnp.where(a == 2, arr + p, 0.0)       # private device tier

        def machine_pass(end, m):
            mask = a == m
            key = jnp.where(mask, arr, jnp.inf)
            # FIFO by arrival; stable ties by index (argsort is stable)
            order = jnp.argsort(key)

            def step(free, j):
                valid = mask[j]
                start = jnp.maximum(arr[j], free)
                e = start + p[j]
                return jnp.where(valid, e, free), jnp.where(valid, e, 0.0)

            _, e_sorted = jax.lax.scan(step, 0.0, order)
            return end.at[order].add(e_sorted), None

        end, _ = jax.lax.scan(machine_pass, end, jnp.arange(2))
        resp = end - rel
        return {"weighted": jnp.sum(w * resp),
                "unweighted": jnp.sum(resp),
                "last": jnp.max(end)}

    return jax.vmap(eval_one)(assign)


def exact_optimum_jax(jobs: Sequence[JobSpec], objective: str = "weighted",
                      batch: int = 65536):
    """Enumerate all 3^n assignments on-device. Practical to n ~ 14."""
    n = len(jobs)
    rel, w, proc, trans = specs_to_arrays(jobs)
    total = N_MACHINES ** n
    powers = N_MACHINES ** np.arange(n)
    best_v, best_a = np.inf, None
    for lo in range(0, total, batch):
        codes = np.arange(lo, min(lo + batch, total))
        assign = jnp.asarray((codes[:, None] // powers[None]) % N_MACHINES,
                             jnp.int32)
        m = evaluate_assignments(assign, rel, w, proc, trans)
        vals = np.asarray(m[objective])
        i = int(np.argmin(vals))
        if vals[i] < best_v:
            best_v, best_a = float(vals[i]), np.asarray(assign[i])
    return best_v, best_a


def stochastic_search(jobs: Sequence[JobSpec], key,
                      initial: np.ndarray, *, iters: int = 200,
                      pop: int = 256, objective: str = "weighted"):
    """Random-restart 1-move local search, evaluated in vmapped batches.

    Each iteration proposes `pop` single-job reassignments of the incumbent
    and keeps the best. Converges to (at least) a 1-swap local optimum of
    the same neighbourhood Algorithm 2 explores, but evaluates the whole
    neighbourhood batch in one device call.
    """
    n = len(jobs)
    rel, w, proc, trans = specs_to_arrays(jobs)
    incumbent = jnp.asarray(initial, jnp.int32)
    best = evaluate_assignments(incumbent[None], rel, w, proc, trans)
    best_v = float(best[objective][0])

    for _ in range(iters):
        key, k1, k2 = jax.random.split(key, 3)
        jobs_i = jax.random.randint(k1, (pop,), 0, n)
        machines = jax.random.randint(k2, (pop,), 0, N_MACHINES)
        cand = jnp.tile(incumbent[None], (pop, 1))
        cand = cand.at[jnp.arange(pop), jobs_i].set(machines)
        m = evaluate_assignments(cand, rel, w, proc, trans)
        vals = np.asarray(m[objective])
        i = int(np.argmin(vals))
        if vals[i] < best_v:
            best_v = float(vals[i])
            incumbent = cand[i]
    return best_v, np.asarray(incumbent)
