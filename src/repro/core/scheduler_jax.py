"""JAX-vectorised schedule evaluation and search (beyond-paper).

The paper's heuristic evaluates one candidate schedule at a time in Python.
For fleet-scale serving (thousands of jobs, many candidate assignments) we
evaluate assignment *batches* on-device. Two observations make the C1-C5
semantics fast to vectorise (DESIGN.md §3.2):

  * each shared tier's FIFO order key (arrival, release, index) depends
    only on the JOB SET, never on the candidate assignment — so the sort
    happens once per instance, not once per candidate;
  * the single-server FIFO recurrence e_j = max(arr_j, e_{j-1}) + p_j is
    an associative scan: with P_j = cumsum(p) in queue order,
    e_j = cummax_k<=j(arr_k - P_{k-1}) + P_j — evaluated with two
    parallel prefix ops, no sequential lax.scan. Non-members are masked
    transparent (p=0, arr=-inf). Multi-server tiers fall back to a
    free-slot lax.scan identical to the Python simulator's heap.

Used for:
  * exact small-n optimum: enumerate all 3^n assignments in one vmap;
  * `tabu_search_jax`: the fully jitted Algorithm-2 neighbourhood search —
    every round evaluates the whole n x 3 single-move neighbourhood in one
    vmap inside a lax.while_loop, so there are NO host<->device round
    trips until the search terminates;
  * random-restart stochastic local search (kept for comparison; it syncs
    to NumPy every iteration);
  * jittable evaluation inside the serving engine's control loop.

Machine encoding: 0 = cloud, 1 = edge, 2 = device (private). Shared tiers
may have several identical machines (`machines_per_tier`, static): jobs
are dispatched FIFO to the earliest-free machine, exactly matching the
Python simulator's free-time heap. Queue order ties break by
(arrival, release, job index), again matching `simulate`.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import JobSpec
from repro.core.tiers import CC, ED, ES

N_MACHINES = 3


def specs_to_arrays(jobs: Sequence[JobSpec]):
    """-> release (n,), weight (n,), proc (n,3), trans (n,3)."""
    rel = jnp.asarray([j.release for j in jobs], jnp.float32)
    w = jnp.asarray([j.weight for j in jobs], jnp.float32)
    proc = jnp.asarray([[j.proc[CC], j.proc[ES], j.proc[ED]] for j in jobs],
                       jnp.float32)
    trans = jnp.asarray([[j.trans[CC], j.trans[ES],
                          j.trans.get(ED, 0.0)] for j in jobs], jnp.float32)
    return rel, w, proc, trans


def _tier_setup(rel, proc, trans, m: int):
    """Assignment-independent per-tier constants: the FIFO queue order
    (arrival, release, index — lexsort majors on its last key, stability
    gives the index tiebreak) and arrival/processing times in that order."""
    arr = rel + trans[:, m]
    order = jnp.lexsort((rel, arr))
    return order, arr[order], proc[:, m][order]


def _shared_ends_single(mask_s, arr_s, p_s, free0):
    """Completion times on a 1-machine tier, in queue order, via parallel
    prefix ops (no sequential scan): e = max(cummax(arr - P_prev), free0)
    + P. ``free0`` is the machine's initial free time (busy_until folded
    into the prefix as the virtual element before the first job)."""
    p_eff = jnp.where(mask_s, p_s, 0.0)
    csum = jnp.cumsum(p_eff)
    q = jnp.where(mask_s, arr_s, -jnp.inf) - (csum - p_eff)
    e = jnp.maximum(jax.lax.cummax(q), free0) + csum
    return jnp.where(mask_s, e, 0.0)


def _shared_ends_multi(mask_s, arr_s, p_s, busy):
    """Multi-machine tier: FIFO dispatch to the earliest-free machine (the
    vectorised analogue of the simulator's free-time heap). ``busy`` is the
    (cnt,) vector of initial machine free times (zeros when idle)."""

    def step(free, x):
        valid, arr, p = x
        slot = jnp.argmin(free)
        start = jnp.maximum(arr, free[slot])
        e = start + p
        return (jnp.where(valid, free.at[slot].set(e), free),
                jnp.where(valid, e, 0.0))

    _, ends = jax.lax.scan(step, busy.astype(arr_s.dtype),
                           (mask_s, arr_s, p_s))
    return ends


def _normalize_busy(busy_until, machines_per_tier: Tuple[int, int]):
    """-> ((m_cloud,), (m_edge,)) float32 arrays of initial machine free
    times, sorted, zero-padded to the machine count. Accepts None or a
    (cloud_times, edge_times) pair with <= machine entries per tier."""
    busy_until = busy_until or ((), ())
    out = []
    for vals, m in zip(busy_until, machines_per_tier):
        v = sorted(float(x) for x in np.asarray(vals).reshape(-1))
        assert len(v) <= m, f"busy_until lists {len(v)} occupied machines " \
                            f"for a {m}-machine tier"
        out.append(jnp.asarray([0.0] * (m - len(v)) + v, jnp.float32))
    return tuple(out)


def _make_eval(rel, w, proc, trans, machines_per_tier: Tuple[int, int],
               busy_until=None):
    """-> eval_one(a) computing {weighted, unweighted, last} for one
    assignment vector; the per-tier sorts are hoisted out so they run once
    per instance, not per candidate. busy_until: optional (cloud, edge)
    initial machine free-time arrays (see _normalize_busy)."""
    setups = [_tier_setup(rel, proc, trans, m) for m in (0, 1)]
    dev_end = rel + trans[:, 2] + proc[:, 2]
    if busy_until is None:
        busy_until = tuple(jnp.zeros((m,), jnp.float32)
                           for m in machines_per_tier)

    def eval_one(a):
        end = jnp.where(a == 2, dev_end, 0.0)       # private device tier
        for m, (order, arr_s, p_s), cnt, busy in zip(
                (0, 1), setups, machines_per_tier, busy_until):
            mask_s = (a == m)[order]
            if cnt == 1:
                e_s = _shared_ends_single(mask_s, arr_s, p_s, busy[0])
            else:
                e_s = _shared_ends_multi(mask_s, arr_s, p_s, busy)
            end = end.at[order].add(e_s)
        resp = end - rel
        return {"weighted": jnp.sum(w * resp),
                "unweighted": jnp.sum(resp),
                "last": jnp.max(end)}

    return eval_one


@functools.partial(jax.jit, static_argnames=("machines_per_tier",))
def _evaluate_assignments_jit(assign, rel, w, proc, trans, busy_until,
                              machines_per_tier: Tuple[int, int]):
    return jax.vmap(_make_eval(rel, w, proc, trans, machines_per_tier,
                               busy_until))(assign)


def evaluate_assignments(assign, rel, w, proc, trans,
                         machines_per_tier: Tuple[int, int] = (1, 1),
                         busy_until=None):
    """assign: (A, n) int32 in {0, 1, 2}. Returns dict of (A,) metrics.

    machines_per_tier: static (cloud, edge) shared-machine counts — the
    vectorised analogue of `simulate(..., machines_per_tier=...)`.
    busy_until: optional (cloud_times, edge_times) initial machine free
    times (the analogue of `simulate(..., busy_until=...)`); traced, so
    replans with changing availability reuse the same compiled kernel.
    """
    busy = _normalize_busy(busy_until, machines_per_tier)
    return _evaluate_assignments_jit(assign, rel, w, proc, trans, busy,
                                     machines_per_tier)


def exact_optimum_jax(jobs: Sequence[JobSpec], objective: str = "weighted",
                      batch: int = 65536,
                      machines_per_tier: Tuple[int, int] = (1, 1),
                      busy_until=None):
    """Enumerate all 3^n assignments on-device. Practical to n ~ 14."""
    n = len(jobs)
    rel, w, proc, trans = specs_to_arrays(jobs)
    total = N_MACHINES ** n
    powers = N_MACHINES ** np.arange(n)
    best_v, best_a = np.inf, None
    for lo in range(0, total, batch):
        codes = np.arange(lo, min(lo + batch, total))
        assign = jnp.asarray((codes[:, None] // powers[None]) % N_MACHINES,
                             jnp.int32)
        m = evaluate_assignments(assign, rel, w, proc, trans,
                                 machines_per_tier=machines_per_tier,
                                 busy_until=busy_until)
        vals = np.asarray(m[objective])
        i = int(np.argmin(vals))
        if vals[i] < best_v:
            best_v, best_a = float(vals[i]), np.asarray(assign[i])
    return best_v, best_a


# ----------------------------------------------- fully-jitted tabu search
@functools.partial(jax.jit,
                   static_argnames=("objective", "machines_per_tier"))
def _tabu_run(assign0, rel, w, proc, trans, max_rounds, busy_until,
              objective: str, machines_per_tier: Tuple[int, int]):
    """Steepest-descent over the n x 3 single-move neighbourhood, entirely
    on-device: one vmapped neighbourhood evaluation per while_loop round,
    accept the best strictly-improving move, stop at a local optimum or
    after max_rounds moves. The incumbent objective is re-read from the
    fresh candidate evaluation every round — no accumulator drift by
    construction."""
    n = assign0.shape[0]
    eval_one = _make_eval(rel, w, proc, trans, machines_per_tier, busy_until)
    job_idx = jnp.repeat(jnp.arange(n), N_MACHINES)     # (3n,)
    mach = jnp.tile(jnp.arange(N_MACHINES), n)          # (3n,)

    def value(a):
        return eval_one(a)[objective]

    def cond(state):
        _, _, rnd, improved = state
        return improved & (rnd < max_rounds)

    def body(state):
        assign, best_v, rnd, _ = state
        cand = jnp.tile(assign[None], (N_MACHINES * n, 1))
        cand = cand.at[jnp.arange(N_MACHINES * n), job_idx].set(mach)
        vals = jax.vmap(value)(cand)
        vals = jnp.where(mach == assign[job_idx], jnp.inf, vals)
        i = jnp.argmin(vals)
        improved = vals[i] < best_v
        return (jnp.where(improved, cand[i], assign),
                jnp.where(improved, vals[i], best_v),
                rnd + 1, improved)

    state = (assign0, value(assign0), jnp.int32(0), jnp.bool_(True))
    assign, best_v, rounds, _ = jax.lax.while_loop(cond, body, state)
    return assign, best_v, rounds


def tabu_search_jax(jobs: Sequence[JobSpec],
                    initial: Sequence[int] | np.ndarray | None = None,
                    *, max_rounds: int | None = None,
                    objective: str = "weighted",
                    machines_per_tier: Tuple[int, int] = (1, 1),
                    busy_until=None):
    """Fully-jitted Algorithm-2 neighbourhood search. Returns
    (best objective value, best assignment as an (n,) int array).

    Unlike `stochastic_search` (which syncs to NumPy every iteration),
    the whole search — candidate generation, n x 3 neighbourhood
    evaluation, move acceptance, termination — runs inside one jitted
    lax.while_loop; the only transfer is the final result. Each accepted
    move strictly improves the objective, so the search terminates at a
    1-move local optimum of the same neighbourhood the Python tabu search
    explores.

    busy_until: optional (cloud_times, edge_times) initial machine free
    times — online replans pass the committed fleet state here, so the
    searched objective is the commit objective (DESIGN.md §7). Traced, so
    successive replans hit the same compiled search."""
    n = len(jobs)
    rel, w, proc, trans = specs_to_arrays(jobs)
    busy = _normalize_busy(busy_until, machines_per_tier)
    if initial is None:
        from repro.core import scheduler                   # no import cycle:
        from repro.core.simulator import MACHINES          # scheduler lazy-
        initial = [MACHINES.index(t)                       # loads this module
                   for t in scheduler.greedy_schedule(
                       jobs,
                       machines_per_tier={CC: machines_per_tier[0],
                                          ES: machines_per_tier[1]},
                       busy_until={CC: np.asarray(busy[0]),
                                   ES: np.asarray(busy[1])})]
    assign0 = jnp.asarray(initial, jnp.int32)
    if max_rounds is None:
        max_rounds = 50 * n
    assign, best_v, _ = _tabu_run(assign0, rel, w, proc, trans,
                                  jnp.int32(max_rounds), busy, objective,
                                  machines_per_tier)
    return float(best_v), np.asarray(assign)


def stochastic_search(jobs: Sequence[JobSpec], key,
                      initial: np.ndarray, *, iters: int = 200,
                      pop: int = 256, objective: str = "weighted"):
    """Random-restart 1-move local search, evaluated in vmapped batches.

    Each iteration proposes `pop` single-job reassignments of the incumbent
    and keeps the best. Converges to (at least) a 1-swap local optimum of
    the same neighbourhood Algorithm 2 explores, but evaluates the whole
    neighbourhood batch in one device call. Kept as the host-synced
    baseline for `tabu_search_jax` (see benchmarks/scheduler_scale.py).
    """
    n = len(jobs)
    rel, w, proc, trans = specs_to_arrays(jobs)
    incumbent = jnp.asarray(initial, jnp.int32)
    best = evaluate_assignments(incumbent[None], rel, w, proc, trans)
    best_v = float(best[objective][0])

    for _ in range(iters):
        key, k1, k2 = jax.random.split(key, 3)
        jobs_i = jax.random.randint(k1, (pop,), 0, n)
        machines = jax.random.randint(k2, (pop,), 0, N_MACHINES)
        cand = jnp.tile(incumbent[None], (pop, 1))
        cand = cand.at[jnp.arange(pop), jobs_i].set(machines)
        m = evaluate_assignments(cand, rel, w, proc, trans)
        vals = np.asarray(m[objective])
        i = int(np.argmin(vals))
        if vals[i] < best_v:
            best_v = float(vals[i])
            incumbent = cand[i]
    return best_v, np.asarray(incumbent)
