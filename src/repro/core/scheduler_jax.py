"""JAX-vectorised schedule evaluation and search (beyond-paper).

The paper's heuristic evaluates one candidate schedule at a time in Python.
For fleet-scale serving (thousands of jobs, many candidate assignments) we
evaluate assignment *batches* on-device. Two observations make the C1-C5
semantics fast to vectorise (DESIGN.md §3.2):

  * each shared tier's FIFO order key (arrival, release, index) depends
    only on the JOB SET, never on the candidate assignment — so the sort
    happens once per instance, not once per candidate;
  * the single-server FIFO recurrence e_j = max(arr_j, e_{j-1}) + p_j is
    an associative scan: with P_j = cumsum(p) in queue order,
    e_j = cummax_k<=j(arr_k - P_{k-1}) + P_j — evaluated with two
    parallel prefix ops, no sequential lax.scan. Non-members are masked
    transparent (p=0, arr=-inf). Multi-server tiers fall back to a
    free-slot lax.scan identical to the Python simulator's heap.

Used for:
  * exact small-n optimum: enumerate all 3^n assignments in one vmap;
  * `tabu_search_jax`: the fully jitted Algorithm-2 neighbourhood search —
    every lax.while_loop round scores the whole n x 3 single-move
    neighbourhood by DELTA EVALUATION (each candidate re-scores only its
    two affected tiers; one scan per shared tier yields all n toggled
    stats — DESIGN.md §3.2), so there are NO host<->device round trips
    until the search terminates;
  * `tabu_search_batched`: B independent ward instances searched in ONE
    device call — variable sizes padded with transparent phantom jobs,
    mixed fleets padded with +inf-busy phantom machines, per-instance
    convergence flags (DESIGN.md §8);
  * random-restart stochastic local search (kept for comparison; it syncs
    to NumPy every iteration);
  * jittable evaluation inside the serving engine's control loop.

Machine encoding: 0 = cloud, 1 = edge, 2 = device (private). Shared tiers
may have several identical machines (`machines_per_tier`, static): jobs
are dispatched FIFO to the earliest-free machine, exactly matching the
Python simulator's free-time heap. Queue order ties break by
(arrival, release, job index), again matching `simulate`.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import JobSpec, Reservation
from repro.core.tiers import CC, ED, ES

N_MACHINES = 3


def _specs_to_np(jobs: Sequence[JobSpec]):
    """Host-side (numpy) spec arrays — no device transfers (batch padding
    assembles B instances without B round trips), one pass over the jobs."""
    flat = np.asarray(
        [(j.release, j.weight, j.proc[CC], j.proc[ES], j.proc[ED],
          j.trans[CC], j.trans[ES], j.trans.get(ED, 0.0)) for j in jobs],
        np.float32).reshape(-1, 8)
    return flat[:, 0], flat[:, 1], flat[:, 2:5], flat[:, 5:8]


def specs_to_arrays(jobs: Sequence[JobSpec]):
    """-> release (n,), weight (n,), proc (n,3), trans (n,3)."""
    return tuple(jnp.asarray(x) for x in _specs_to_np(jobs))


def _tier_setup(rel, proc, trans, m: int):
    """Assignment-independent per-tier constants: the FIFO queue order
    (arrival, release, index — lexsort majors on its last key, stability
    gives the index tiebreak) and arrival/processing times in that order."""
    arr = rel + trans[:, m]
    order = jnp.lexsort((rel, arr))
    return order, arr[order], proc[:, m][order]


def _shared_ends_single(mask_s, arr_s, p_s, free0):
    """Completion times on a 1-machine tier, in queue order, via parallel
    prefix ops (no sequential scan): e = max(cummax(arr - P_prev), free0)
    + P. ``free0`` is the machine's initial free time (busy_until folded
    into the prefix as the virtual element before the first job)."""
    p_eff = jnp.where(mask_s, p_s, 0.0)
    csum = jnp.cumsum(p_eff)
    q = jnp.where(mask_s, arr_s, -jnp.inf) - (csum - p_eff)
    e = jnp.maximum(jax.lax.cummax(q), free0) + csum
    return jnp.where(mask_s, e, 0.0)


def _shared_ends_multi(mask_s, arr_s, p_s, busy):
    """Multi-machine tier: FIFO dispatch to the earliest-free machine (the
    vectorised analogue of the simulator's free-time heap). ``busy`` is the
    (cnt,) vector of initial machine free times (zeros when idle)."""

    def step(free, x):
        valid, arr, p = x
        slot = jnp.argmin(free)
        start = jnp.maximum(arr, free[slot])
        e = start + p
        return (jnp.where(valid, free.at[slot].set(e), free),
                jnp.where(valid, e, 0.0))

    _, ends = jax.lax.scan(step, busy.astype(arr_s.dtype),
                           (mask_s, arr_s, p_s))
    return ends


def _normalize_busy(busy_until, machines_per_tier: Tuple[int, int]):
    """-> ((m_cloud,), (m_edge,)) float32 arrays of initial machine free
    times, sorted, zero-padded to the machine count. Accepts None or a
    (cloud_times, edge_times) pair with <= machine entries per tier.

    Raises ValueError (not assert — guards must survive ``python -O``) when
    a caller lists more occupied machines than the tier has servers."""
    busy_until = busy_until or ((), ())
    out = []
    for vals, m in zip(busy_until, machines_per_tier):
        v = sorted(float(x) for x in np.asarray(vals).reshape(-1))
        if len(v) > m:
            raise ValueError(f"busy_until lists {len(v)} occupied machines "
                             f"for a {m}-machine tier")
        out.append(np.asarray([0.0] * (m - len(v)) + v, np.float32))
    return tuple(out)


def _make_eval(rel, w, proc, trans, machines_per_tier: Tuple[int, int],
               busy_until=None):
    """-> eval_one(a) computing {weighted, unweighted, last} for one
    assignment vector; the per-tier sorts are hoisted out so they run once
    per instance, not per candidate. busy_until: optional (cloud, edge)
    initial machine free-time arrays (see _normalize_busy)."""
    setups = [_tier_setup(rel, proc, trans, m) for m in (0, 1)]
    dev_end = rel + trans[:, 2] + proc[:, 2]
    if busy_until is None:
        busy_until = tuple(jnp.zeros((m,), jnp.float32)
                           for m in machines_per_tier)

    def eval_one(a):
        end = jnp.where(a == 2, dev_end, 0.0)       # private device tier
        for m, (order, arr_s, p_s), cnt, busy in zip(
                (0, 1), setups, machines_per_tier, busy_until):
            mask_s = (a == m)[order]
            if cnt == 1:
                e_s = _shared_ends_single(mask_s, arr_s, p_s, busy[0])
            else:
                e_s = _shared_ends_multi(mask_s, arr_s, p_s, busy)
            end = end.at[order].add(e_s)
        resp = end - rel
        return {"weighted": jnp.sum(w * resp),
                "unweighted": jnp.sum(resp),
                "last": jnp.max(end)}

    return eval_one


@functools.partial(jax.jit, static_argnames=("machines_per_tier",))
def _evaluate_assignments_jit(assign, rel, w, proc, trans, busy_until,
                              machines_per_tier: Tuple[int, int]):
    return jax.vmap(_make_eval(rel, w, proc, trans, machines_per_tier,
                               busy_until))(assign)


def evaluate_assignments(assign, rel, w, proc, trans,
                         machines_per_tier: Tuple[int, int] = (1, 1),
                         busy_until=None):
    """assign: (A, n) int32 in {0, 1, 2}. Returns dict of (A,) metrics.

    machines_per_tier: static (cloud, edge) shared-machine counts — the
    vectorised analogue of `simulate(..., machines_per_tier=...)`.
    busy_until: optional (cloud_times, edge_times) initial machine free
    times (the analogue of `simulate(..., busy_until=...)`); traced, so
    replans with changing availability reuse the same compiled kernel.
    """
    busy = _normalize_busy(busy_until, machines_per_tier)
    return _evaluate_assignments_jit(assign, rel, w, proc, trans, busy,
                                     machines_per_tier)


def exact_optimum_jax(jobs: Sequence[JobSpec], objective: str = "weighted",
                      batch: int = 65536,
                      machines_per_tier: Tuple[int, int] = (1, 1),
                      busy_until=None):
    """Enumerate all 3^n assignments on-device. Practical to n ~ 14."""
    n = len(jobs)
    rel, w, proc, trans = specs_to_arrays(jobs)
    total = N_MACHINES ** n
    powers = N_MACHINES ** np.arange(n)
    best_v, best_a = np.inf, None
    for lo in range(0, total, batch):
        codes = np.arange(lo, min(lo + batch, total))
        assign = jnp.asarray((codes[:, None] // powers[None]) % N_MACHINES,
                             jnp.int32)
        m = evaluate_assignments(assign, rel, w, proc, trans,
                                 machines_per_tier=machines_per_tier,
                                 busy_until=busy_until)
        vals = np.asarray(m[objective])
        i = int(np.argmin(vals))
        if vals[i] < best_v:
            best_v, best_a = float(vals[i]), np.asarray(assign[i])
    return best_v, best_a


# ------------------------------------- delta-evaluated jitted tabu search
#
# DESIGN.md §3.2/§8: a single-move candidate perturbs only its source and
# destination tiers, so a tabu round never re-evaluates whole assignments.
# Per round, each shared tier computes the incumbent stat plus all n
# "toggle job k's membership" stats in ONE scan over the tier's (hoisted)
# queue order — O(n^2) flops, O(n) memory, no (3n, n) candidate
# materialisation and no per-candidate cumsum/cummax. Candidate (k, m) is
# then scored from per-tier scalars: the toggled source stat, the toggled
# destination stat, and the incumbent's untouched third-tier stat.

_OBJ_IDX = {"weighted": 0, "unweighted": 1, "last": 2}


def _tier_rounds(mask_T, arr_T, p_T, w_T, rel_T, busy_T, ps, oi: int):
    """Incumbent + movable-position toggled stats of BOTH shared tiers of
    every instance in one scan.

    Inputs are stacked per-tier queue-order constants, shape (B, 2, n)
    (and (B, 2, m) machine free times — mixed fleets pad the smaller tier
    with +inf phantom machines, which FIFO dispatch never selects).
    ``ps`` (B, 2, S) lists the queue POSITIONS of each instance's movable
    jobs (DESIGN.md §12): toggled stats are only ever consumed for moves
    of movable jobs, so the carry tracks S toggle columns instead of n —
    a mostly-frozen ward (reservations, fleet background) costs
    O(movable) per round instead of O(n). Column s of the carry tracks
    the queue with the job at queue position ps[..., s] toggled (member
    removed / non-member inserted). Columns walk the queue once, so the
    whole B-instance 2-tier S-toggle neighbourhood costs one length-n
    scan whose per-step op count is independent of B and tier count (op
    dispatch, not flops, bounds CPU throughput — the batch rides along
    inside each op).

    All-single-server fleets (m == 1, the static shape of busy_T) carry
    the running cummax of q = arr − P_prev (the §3.2 prefix recurrence);
    multi-machine fleets carry per-row free-slot vectors (the vectorised
    free-time heap, start = max(arrival, earliest free) exactly as
    `simulate`). Returns ((B, 2) incumbent stats, (B, 2, S) toggled
    stats aligned with ps). Per toggle column the arithmetic is
    elementwise-identical to the old all-positions carry, so restricting
    to movable columns is a pure column gather — bit-identical values."""
    B, _, n = mask_T.shape
    m = busy_T.shape[2]
    S = ps.shape[2]

    def lead(x):                                # (B, 2, n) -> (n, B, 2)
        return jnp.moveaxis(x, 2, 0)

    def gat(x):                                 # (B, 2, n) -> (B, 2, S)
        return jnp.take_along_axis(x, ps, axis=2)

    if m == 1:
        p_eff = jnp.where(mask_T, p_T, 0.0)
        csum = jnp.cumsum(p_eff, axis=2)
        q = jnp.where(mask_T, arr_T, -jnp.inf) - (csum - p_eff)
        free0 = busy_T[:, :, :1]                # finite on 1-machine tiers
        delta = jnp.where(mask_T, -p_T, p_T)    # toggle's suffix p shift
        q_self = jnp.where(mask_T, -jnp.inf, arr_T - (csum - p_eff))
        cm = jax.lax.cummax(q, axis=2)          # M_j, the §3.2 prefix max
        e_inc = jnp.maximum(cm, free0) + csum   # incumbent completions
        # A toggle at position s leaves the queue prefix untouched and
        # shifts the suffix cumsum by delta_s, so with
        # K_s = max(M_{s-1}, q'_s, f0) and G_s = K_s + delta_s the
        # toggled completion of j > s is
        #   e'_j = max(K_s, R_{s+1,j} - delta_s) + C_j + delta_s
        #        = max(G_s, R_{s+1,j}) + C_j
        # (R = range max of q). Everything but the 2D range max reduces
        # to O(n) prefix/suffix sums of incumbent quantities.
        cm_prev = jnp.concatenate(
            [jnp.full((B, 2, 1), -jnp.inf), cm[:, :, :-1]], axis=2)
        K = jnp.maximum(jnp.maximum(cm_prev, q_self), free0)
        G = K + delta

        if oi != 2:
            wm = jnp.where(mask_T, w_T if oi == 0 else 1.0, 0.0)
            contrib = wm * (e_inc - rel_T)
            stat = jnp.sum(contrib, axis=2)
            cpre = jnp.cumsum(contrib, axis=2)
            pre = cpre - contrib                       # sum over j < s
            lin = wm * (csum - rel_T)
            clin = jnp.cumsum(lin, axis=2)
            suf_lin = clin[:, :, -1:] - clin           # sum over j > s
            wpre = jnp.cumsum(wm, axis=2)              # sum over j <= s
            own = jnp.where(
                mask_T, 0.0,
                (w_T if oi == 0 else 1.0) * (G + csum - rel_T))
            # T_s = sum_{j>s} wm_j max(G_s, R_{s+1,j}) for each movable
            # toggle position s = ps[..., col]: one scan over queue
            # positions with an O(B S) carry and five small fused ops per
            # step — no O(n^2) tensors, and the carry width is the
            # MOVABLE count, not the instance size. For j <= s the
            # unmasked accumulator collects wm_j G_s (R is still -inf
            # there), subtracted afterwards via wpre.
            Gm = gat(G)

            def step(carry, xs):
                R, acc = carry                         # (B, 2, S) each
                j, q_j, wm_j = xs                      # scalar, (B,2) x2
                R = jnp.maximum(
                    R, jnp.where(j > ps, q_j[..., None], -jnp.inf))
                acc = acc + wm_j[..., None] * jnp.maximum(Gm, R)
                return (R, acc), None

            init = (jnp.full((B, 2, S), -jnp.inf),
                    jnp.zeros((B, 2, S), p_T.dtype))
            (_, accT), _ = jax.lax.scan(
                step, init, (jnp.arange(n), lead(q), lead(wm)), unroll=4)
            tog = gat(pre) + gat(own) + (accT - Gm * gat(wpre)) \
                + gat(suf_lin)
            return stat, tog

        # "last" objective: the same toggle decomposition holds under max
        # (DESIGN.md §12) — members before s keep their incumbent
        # completions, an inserted s completes at G_s + csum_s, and for
        # members j > s the max of e'_j = max(G_s, R_{s+1,j}) + C_j
        # splits into G_s + max_j C_j plus the max-plus exchange
        #   max_{j>s}(R_{s+1,j} + C_j) = max_{i>s}(q_i + SC_i),
        # SC = inclusive suffix cummax of member csum — all O(n)
        # prefix/suffix cummaxes, no sequential walk (ROADMAP
        # accelerator-truth item).
        neg = jnp.full((B, 2, 1), -jnp.inf)
        e_mem = jnp.where(mask_T, e_inc, -jnp.inf)
        pmax = jnp.concatenate(
            [neg, jax.lax.cummax(e_mem, axis=2)[:, :, :-1]], 2)
        csum_mem = jnp.where(mask_T, csum, -jnp.inf)
        SC = jnp.flip(jax.lax.cummax(jnp.flip(csum_mem, 2), axis=2), 2)
        SCx = jnp.concatenate([SC[:, :, 1:], neg], 2)
        g = q + SC
        Hx = jnp.concatenate(
            [jnp.flip(jax.lax.cummax(jnp.flip(g, 2), axis=2),
                      2)[:, :, 1:], neg], 2)
        own = jnp.where(mask_T, -jnp.inf, G + csum)
        tog = jnp.maximum(jnp.maximum(pmax, own),
                          jnp.maximum(G + SCx, Hx))
        tog = jnp.maximum(tog, 0.0)            # empty-queue floor
        stat = jnp.maximum(
            jnp.max(e_mem, axis=2, initial=-jnp.inf), 0.0)
        return stat, gat(tog)

    slots = jnp.arange(m)
    # column S is a sentinel toggle position (n, never a queue index):
    # its row walks the untouched incumbent with identical arithmetic
    ps_ext = jnp.concatenate(
        [ps, jnp.full((B, 2, 1), n, ps.dtype)], axis=2)

    def step(carry, xs):
        free, acc = carry                   # (B, 2, S+1, m), (B, 2, S+1)
        j, a_j, p_j, w_j, rel_j, m_j = xs   # scalar, then (B, 2) each
        live = m_j[..., None] != (j == ps_ext)
        slot = jnp.argmin(free, axis=3)
        fmin = jnp.take_along_axis(free, slot[..., None], axis=3)[..., 0]
        e = jnp.maximum(a_j[..., None], fmin) + p_j[..., None]
        free = jnp.where((slots == slot[..., None]) & live[..., None],
                         e[..., None], free)
        if oi == 2:
            acc = jnp.maximum(acc, jnp.where(live, e, 0.0))
        else:
            resp = e - rel_j[..., None]
            acc = acc + jnp.where(
                live, w_j[..., None] * resp if oi == 0 else resp, 0.0)
        return (free, acc), None

    init = (jnp.broadcast_to(busy_T[:, :, None, :], (B, 2, S + 1, m)),
            jnp.zeros((B, 2, S + 1), p_T.dtype))
    (_, acc), _ = jax.lax.scan(
        step, init, (jnp.arange(n), lead(arr_T), lead(p_T), lead(w_T),
                     lead(rel_T), lead(mask_T)))
    return acc[:, :, S], acc[:, :, :S]


def _device_round(assign, dev_end, dev_resp, dev_wresp, oi: int):
    """Incumbent + toggled stats of the private device tier, O(B n):
    per-job contributions are constants, so sum objectives are one ± of a
    precomputed constant and "last" needs only the masked top-2."""
    member = assign == 2
    if oi == 2:
        iota = jnp.arange(assign.shape[1])
        ends = jnp.where(member, dev_end, -jnp.inf)
        amax = jnp.argmax(ends, axis=1)
        max1 = jnp.take_along_axis(ends, amax[:, None], axis=1)[:, 0]
        is_max = iota == amax[:, None]
        max2 = jnp.max(jnp.where(is_max, -jnp.inf, ends), axis=1,
                       initial=-jnp.inf)
        stat = jnp.maximum(max1, 0.0)
        tog = jnp.where(
            member,
            jnp.maximum(jnp.where(is_max, max2[:, None], max1[:, None]),
                        0.0),
            jnp.maximum(stat[:, None], dev_end))
        return stat, tog
    con = dev_wresp if oi == 0 else dev_resp
    stat = jnp.sum(jnp.where(member, con, 0.0), axis=1)
    return stat, stat[:, None] + jnp.where(member, -con, con)


def _round_batched(assign, mov_idx, mov_ok, tc, dev, oi: int):
    """One delta-evaluated neighbourhood round for the whole batch.

    Returns ((B,) incumbent objectives, (B, S, 3) candidate values):
    entry (b, i, m) is the exact objective of instance b with job
    mov_idx[b, i] moved to machine m, assembled from the two affected
    tiers' toggled stats and the incumbent's third-tier stat. Only
    movable jobs get candidate slots (DESIGN.md §12) — phantom padding,
    frozen background jobs, and interval reservations participate fully
    in every queue evaluation (they occupy machines and count toward the
    objective) but never appear in mov_idx, so a mostly-frozen ward
    prices O(movable) candidates per round. No-op moves and invalid
    padding slots (~mov_ok) score +inf. tc holds the stacked (B, 2, n)
    per-tier queue-order constants; dev the device-tier constants."""
    B, n = assign.shape
    S = mov_idx.shape[1]
    mask_T = jnp.take_along_axis(
        jnp.stack([assign == 0, assign == 1], axis=1), tc["order"], axis=2)
    # queue positions of the movable jobs on each tier — tog comes back
    # already aligned with the movable slots, no pos->job scatter needed
    ps = jnp.take_along_axis(
        tc["pos"], jnp.broadcast_to(mov_idx[:, None, :], (B, 2, S)), axis=2)
    stat_T, tog_T = _tier_rounds(mask_T, tc["arr"], tc["p"], tc["w"],
                                 tc["rel"], tc["busy"], ps, oi)
    stat_d, tog_d = _device_round(assign, dev["end"], dev["resp"],
                                  dev["wresp"], oi)
    tog_d = jnp.take_along_axis(tog_d, mov_idx, axis=1)      # (B, S)
    a_mov = jnp.take_along_axis(assign, mov_idx, axis=1)     # (B, S)
    stats = jnp.concatenate([stat_T, stat_d[:, None]], 1)    # (B, 3)
    tog = jnp.concatenate([tog_T, tog_d[:, None, :]], 1)     # (B, 3, S)
    if oi == 2:
        total = jnp.max(stats, axis=1)
        src_t = jnp.take_along_axis(tog, a_mov[:, None, :],
                                    axis=1)[:, 0, :]
        third = jnp.clip(
            3 - a_mov[:, :, None] - jnp.arange(3)[None, None, :], 0, 2)
        stats_third = jnp.take_along_axis(
            stats, third.reshape(B, -1), axis=1).reshape(B, S, 3)
        vals = jnp.maximum(jnp.maximum(src_t[:, :, None],
                                       tog.transpose(0, 2, 1)),
                           stats_third)
    else:
        total = stats[:, 0] + stats[:, 1] + stats[:, 2]
        d = tog - stats[:, :, None]             # per-tier toggle deltas
        src_d = jnp.take_along_axis(d, a_mov[:, None, :], axis=1)[:, 0, :]
        vals = total[:, None, None] + src_d[:, :, None] + \
            d.transpose(0, 2, 1)
    vals = jnp.where(jnp.arange(3)[None, None, :] == a_mov[:, :, None],
                     jnp.inf, vals)
    vals = jnp.where(mov_ok[:, :, None], vals, jnp.inf)
    return total, vals


def _greedy_assign_batched(rel, w, proc, trans, valid, busy_c, busy_e):
    """Vectorised `scheduler.greedy_schedule` for the whole batch: jobs in
    (release, -weight, index) order, each to the machine minimising its
    completion time given the free slots so far, ties to the lower tier
    (device < edge < cloud) — the same rule, same tie-breaks. One lax.scan
    over job ranks runs every instance in lockstep; phantom jobs are
    skipped and stay pinned to the (zero-cost) device tier."""
    B, n = rel.shape
    order = jax.vmap(lambda r, ww: jnp.lexsort((-ww, r)))(rel, w)
    binds = jnp.arange(B)

    m_mm = max(busy_c.shape[1], busy_e.shape[1])
    free_T0 = jnp.stack([                            # (B, 2, m), +inf pads
        jnp.pad(busy_c, ((0, 0), (0, m_mm - busy_c.shape[1])),
                constant_values=jnp.inf),
        jnp.pad(busy_e, ((0, 0), (0, m_mm - busy_e.shape[1])),
                constant_values=jnp.inf)], axis=1)
    slots = jnp.arange(m_mm)

    def step(carry, j):
        free_T, assign = carry                       # (B, 2, m), (B, n)
        k = order[:, j]                              # (B,) this rank's job
        v = valid[binds, k]
        r = rel[binds, k]
        arr_T = r[:, None] + trans[binds, k, :2]     # (B, 2)
        slot = jnp.argmin(free_T, axis=2)            # earliest-free machine
        fmin = jnp.take_along_axis(free_T, slot[..., None], axis=2)[..., 0]
        end_T = jnp.maximum(arr_T, fmin) + proc[binds, k, :2]
        end_dev = r + trans[binds, k, 2] + proc[binds, k, 2]
        # argmin over [device, edge, cloud] keeps the first (lowest) tier
        # on ties, exactly like greedy_schedule's (ED, ES, CC) probe order
        pick = jnp.argmin(
            jnp.stack([end_dev, end_T[:, 1], end_T[:, 0]], 1), axis=1)
        tier = jnp.asarray([2, 1, 0], jnp.int32)[pick]
        assign = assign.at[binds, k].set(
            jnp.where(v, tier, assign[binds, k]))
        claim = (v[:, None] & (tier[:, None] == jnp.arange(2)))[..., None] \
            & (slots == slot[..., None])
        free_T = jnp.where(claim, end_T[..., None], free_T)
        return (free_T, assign), None

    init = (free_T0, jnp.full((B, n), 2, jnp.int32))
    (_, assign), _ = jax.lax.scan(step, init, jnp.arange(n))
    return assign


def _run_rounds(assign0, mov_idx, mov_ok, tc, dev, oi, max_moves, binds):
    """mode="round" inner loop (see `_tabu_run_batched`): steepest
    descent over the S x 3 single-move neighbourhood, one wide
    delta-evaluated round per while_loop iteration, accept each
    instance's best strictly improving move plus a second,
    exactly-composing move on the other shared tier when one improves
    (cloud/edge queues are disjoint and the private device tier is
    additive per job, so the pair composes exactly for sum
    objectives)."""
    B, _ = assign0.shape
    S = mov_idx.shape[1]

    def round_all(assign):
        return _round_batched(assign, mov_idx, mov_ok, tc, dev, oi)

    def cond(state):
        _, _, rnd, active = state
        return jnp.any(active) & (rnd < max_moves)

    def body(state):
        assign, _, rnd, active = state
        total, vals = round_all(assign)
        flat = vals.reshape(B, -1)              # candidate (s, m) = s*3+m
        i1 = jnp.argmin(flat, axis=1)
        v1 = jnp.take_along_axis(flat, i1[:, None], axis=1)[:, 0]
        s1 = i1 // N_MACHINES
        k1 = jnp.take_along_axis(mov_idx, s1[:, None], axis=1)[:, 0]
        m1 = (i1 % N_MACHINES).astype(assign.dtype)
        improved = active & (v1 < total)
        src1 = assign[binds, k1]
        new_assign = assign.at[binds, k1].set(
            jnp.where(improved, m1, src1))
        # the carried value is the FRESH per-tier evaluation of the
        # incumbent whenever a ward converges (its last round rejects
        # every move, so `total` is its final assignment's exact score);
        # only a max_rounds cap can surface a delta-assembled value
        value = jnp.where(improved, v1, total)
        if oi != 2:
            # paired acceptance: a second strictly-improving move whose
            # shared-tier footprint is disjoint from the first composes
            # EXACTLY for sum objectives — its standalone delta still
            # holds after the first move commits
            sh0 = (src1 == 0) | (m1 == 0)
            sh1 = (src1 == 1) | (m1 == 1)
            other = jnp.where(sh0, 1, 0).astype(assign.dtype)
            pairable = improved & ~(sh0 & sh1)
            a_slot = jnp.take_along_axis(assign, mov_idx, axis=1)
            ok_src = (a_slot == other[:, None]) | (a_slot == 2)
            mr = jnp.arange(N_MACHINES)[None, None, :]
            ok_dst = (mr == other[:, None, None]) | (mr == 2)
            elig = (ok_src[:, :, None] & ok_dst &
                    (jnp.arange(S)[None, :, None] != s1[:, None, None]))
            flat2 = jnp.where(elig.reshape(B, -1), flat, jnp.inf)
            i2 = jnp.argmin(flat2, axis=1)
            v2 = jnp.take_along_axis(flat2, i2[:, None], axis=1)[:, 0]
            s2 = i2 // N_MACHINES
            k2 = jnp.take_along_axis(mov_idx, s2[:, None], axis=1)[:, 0]
            m2 = (i2 % N_MACHINES).astype(assign.dtype)
            accept2 = pairable & (v2 < total)
            new_assign = new_assign.at[binds, k2].set(
                jnp.where(accept2, m2, new_assign[binds, k2]))
            value = jnp.where(accept2, value + (v2 - total), value)
        return new_assign, value, rnd + 1, improved

    state = (assign0, jnp.full((B,), jnp.inf), jnp.int32(0),
             jnp.ones((B,), bool))
    assign, totals, rounds, _ = jax.lax.while_loop(cond, body, state)
    # max_rounds == 0 (greedy probe): the loop never evaluated anything
    totals = jax.lax.cond(rounds == 0,
                          lambda args: round_all(args[0])[0],
                          lambda args: args[1], (assign, totals))
    return assign, totals, rounds


@functools.partial(jax.jit,
                   static_argnames=("objective", "greedy_init", "mode"))
def _tabu_run_batched(assign0, rel, w, proc, trans, movable, mov_idx,
                      mov_ok, max_rounds, busy_c, busy_e, objective: str,
                      greedy_init: bool = False, mode: str = "pass"):
    """Algorithm-2 search for B instances at once, entirely on-device,
    in one of two shape-dispatched regimes (DESIGN.md §12):

    mode="pass" — the mostly-background regime (movable slots are a
    small fraction of the padded rows). Each while_loop iteration is
    one PASS over the movable slots; per slot the job's 3 destination
    moves are delta-evaluated exactly against the CURRENT assignment (a
    width-1 toggle carry) and a strictly improving best move commits
    immediately, exactly like the incremental Python tabu round. The
    toggle scan is carry-bandwidth-bound, so S cheap width-1 evals that
    can each commit a move beat one width-S eval that commits one —
    the steepest-descent rounds spent ~95% of mostly-converged fleet
    sweeps re-pricing unchanged candidates.

    mode="round" — the movable-dominated regime. One steepest-descent
    round per while_loop iteration: all S toggles priced in one wide
    carry, accept each instance's best strictly improving move (plus a
    second, exactly-composing move on the other shared tier when one
    improves). At small row counts the per-eval dispatch floor — not
    carry width — dominates, so one wide eval per accepted move beats
    S narrow evals per pass; `max_rounds` passes translate to a
    `max_rounds * S` move budget.

    Both regimes share the tier/device precomputation, per-instance
    convergence flags (a converged ward idles while stragglers keep
    searching), and drift-free values: the incumbent objective is
    re-derived from fresh per-tier stats at every evaluation, so a
    converged ward's reported value is a fresh full evaluation.
    Machine counts are carried by the busy vector shapes (phantom
    machines = +inf), so changing fleet sizes does not retrace beyond
    the new shapes. max_rounds counts passes (the Python search's
    max_count)."""
    oi = _OBJ_IDX[objective]
    B, n = assign0.shape
    if greedy_init:
        # greedy init is only reachable when every non-phantom job is
        # movable (frozen jobs require an explicit initial assignment)
        assign0 = _greedy_assign_batched(rel, w, proc, trans, movable,
                                         busy_c, busy_e)
    m_mm = max(busy_c.shape[1], busy_e.shape[1])
    busy_T = jnp.stack([
        jnp.pad(busy_c, ((0, 0), (0, m_mm - busy_c.shape[1])),
                constant_values=jnp.inf),
        jnp.pad(busy_e, ((0, 0), (0, m_mm - busy_e.shape[1])),
                constant_values=jnp.inf)], axis=1)           # (B, 2, m)
    parts = []
    for m in (0, 1):
        arr = rel + trans[:, :, m]
        order = jax.vmap(lambda r, a: jnp.lexsort((r, a)))(rel, arr)
        pos = jax.vmap(jnp.argsort)(order)      # job id -> queue position

        def gat(x, o=order):
            return jnp.take_along_axis(x, o, axis=1)

        parts.append({"order": order, "pos": pos, "arr": gat(arr),
                      "p": gat(proc[:, :, m]), "w": gat(w),
                      "rel": gat(rel)})
    tc = {key: jnp.stack([parts[0][key], parts[1][key]], axis=1)
          for key in parts[0]}                  # each (B, 2, n)
    tc["busy"] = busy_T
    dev_end = rel + trans[:, :, 2] + proc[:, :, 2]
    dev = {"end": dev_end, "resp": dev_end - rel,
           "wresp": w * (dev_end - rel)}

    binds = jnp.arange(B)
    S = mov_idx.shape[1]
    # real (non-padding) slots are a per-ward PREFIX of mov_idx
    # (_movable_slots packs them first), so slot s of pass r visits the
    # same job for a ward no matter how much batch padding it rides with
    # (the batched==solo parity suite pins this)
    if mode == "round":
        return _run_rounds(assign0, mov_idx, mov_ok, tc, dev, oi,
                           max_rounds * jnp.int32(S), binds)

    def cond(state):
        _, _, rnd, active = state
        return jnp.any(active) & (rnd < max_rounds)

    def body(state):
        assign, _, rnd, active = state

        def slot(carry, s):
            assign, total, changed = carry
            k = jnp.take(mov_idx, s, axis=1)            # (B,) job id
            ok = jnp.take(mov_ok, s, axis=1) & active
            # width-1 toggle: fresh incumbent stats + job k's 3 moves,
            # exact against the assignment as of THIS slot
            tot, vals = _round_batched(assign, k[:, None], ok[:, None],
                                       tc, dev, oi)
            flat = vals[:, 0, :]                        # (B, 3)
            m1 = jnp.argmin(flat, axis=1)
            v1 = jnp.take_along_axis(flat, m1[:, None], axis=1)[:, 0]
            improved = v1 < tot         # +inf masks no-ops and ~ok slots
            assign = assign.at[binds, k].set(
                jnp.where(improved, m1.astype(assign.dtype),
                          assign[binds, k]))
            # the carried value is the FRESH per-tier evaluation of the
            # incumbent whenever the slot rejects its moves — so a
            # converged ward (a full pass of rejections) always reports
            # its final assignment's exact score; only a max_rounds cap
            # can surface a (one-composition) delta-assembled value
            total = jnp.where(improved, v1, tot)
            return (assign, total, changed | improved), None

        (assign, total, changed), _ = jax.lax.scan(
            slot, (assign, jnp.full((B,), jnp.inf), jnp.zeros((B,), bool)),
            jnp.arange(S))
        return assign, total, rnd + 1, changed

    state = (assign0, jnp.full((B,), jnp.inf), jnp.int32(0),
             jnp.ones((B,), bool))
    assign, totals, rounds, _ = jax.lax.while_loop(cond, body, state)
    # max_rounds == 0 (greedy probe): the loop never evaluated anything
    totals = jax.lax.cond(
        rounds == 0,
        lambda args: _round_batched(args[0], mov_idx, mov_ok, tc, dev,
                                    oi)[0],
        lambda args: args[1], (assign, totals))
    return assign, totals, rounds


def _reservation_rows(resv):
    """Host-side kernel rows for one ward's {tier: [Reservation]} map
    (DESIGN.md §12) — the interval representation compiles into ordinary
    pinned rows appended AFTER the instance's jobs: arrival enters via
    trans = arrival − release (so queue key (arrival, release, index)
    ties break jobs-first, then reservation input order, exactly like
    `simulate`), the row occupies its tier's pool for ``proc`` and
    contributes weight*(end − release) to the objective, and movable
    stays False so no round ever prices a move on it. Returns the
    (K, 8) _specs_to_np-layout block plus the (K,) tier codes."""
    rows, tiers = [], []
    for m, tier in ((0, CC), (1, ES)):
        for r in (resv or {}).get(tier, ()):
            p = [0.0] * N_MACHINES
            t = [0.0] * N_MACHINES
            p[m] = float(r.proc)
            t[m] = float(r.arrival) - float(r.release)
            rows.append((float(r.release), float(r.weight), *p, *t))
            tiers.append(m)
    bad = sorted(set(resv or {}) - {CC, ES})
    if bad:
        raise ValueError(f"reservations may only name shared tiers "
                         f"[{CC!r}, {ES!r}], got {bad}")
    return (np.asarray(rows, np.float32).reshape(-1, 8),
            np.asarray(tiers, np.int32))


def _movable_slots(movable: np.ndarray, n_max: int):
    """Bucketed movable-slot index arrays for the batch (DESIGN.md §12):
    S = the max per-instance movable count rounded up to a multiple of 16
    (capped at n_max), so the compiled (B, n, S) kernel shape stays
    stable while reservation/background counts drift under metro load.
    Returns (mov_idx (B, S) int32 job ids, mov_ok (B, S) bool — padding
    slots point at job 0 and are masked +inf by the round)."""
    B = movable.shape[0]
    smax = int(movable.sum(axis=1).max()) if B else 0
    S = min(n_max, ((max(smax, 1) + 15) // 16) * 16)
    mov_idx = np.zeros((B, S), np.int32)
    mov_ok = np.zeros((B, S), bool)
    for b in range(B):
        idx = np.flatnonzero(movable[b])
        mov_idx[b, :len(idx)] = idx
        mov_ok[b, :len(idx)] = True
    return mov_idx, mov_ok


def _per_instance_mpt(machines_per_tier, B: int):
    """-> B (cloud, edge) machine-count pairs from one pair or a per-ward
    sequence."""
    if machines_per_tier is None:
        return [(1, 1)] * B
    seq = list(machines_per_tier)
    if len(seq) == 2 and all(isinstance(x, (int, np.integer)) for x in seq):
        return [(int(seq[0]), int(seq[1]))] * B
    if len(seq) != B:
        raise ValueError(f"machines_per_tier lists {len(seq)} fleets "
                         f"for {B} instances")
    return [(int(c), int(e)) for c, e in seq]


def tabu_search_batched(batch_jobs: Sequence[Sequence[JobSpec]],
                        initial: Sequence[Sequence[int]] | None = None,
                        *, max_rounds: int | None = None,
                        objective: str = "weighted",
                        machines_per_tier=(1, 1),
                        busy_until=None,
                        frozen=None,
                        reserved=None,
                        pad_to: int | None = None):
    """Plan B independent ward instances in ONE jitted device call.

    batch_jobs: B job lists; sizes may differ — instances are padded to
    the largest with phantom jobs (p = 0, w = 0, masked transparent:
    arr = −inf in every shared queue) that contribute exactly 0 to every
    objective and whose moves score +inf. machines_per_tier: one
    (cloud, edge) pair for the whole fleet or a per-ward sequence; mixed
    fleets are padded to the per-tier maximum with phantom machines whose
    initial busy time is +inf, so FIFO dispatch never selects them.
    busy_until: optional per-ward (cloud_times, edge_times) pairs.

    frozen: optional per-ward boolean masks (DESIGN.md §9). A frozen job
    participates FULLY in every queue evaluation — it occupies its
    machine pool and its response counts toward the objective — but every
    move on it scores +inf, so the search can never reassign it. This is
    how the fleet fixed-point solver shows ward b the other wards'
    committed shared-tier jobs as background occupancy. Frozen jobs
    require an explicit ``initial`` (the greedy initialiser would
    reassign them). pad_to: pad instances to at least this many job slots
    — contention sweeps bucket their background size with it so the
    compiled shape stays stable while the background churns.

    reserved: optional per-ward {tier: [Reservation]} maps (DESIGN.md
    §12) — committed background occupancy on the shared tiers. Each
    reservation compiles into one pinned row appended after the ward's
    jobs (occupies its pool, counts toward the objective, never movable),
    but because the toggle carry only tracks MOVABLE slots, reservations
    cost O(1) carry width instead of widening the O(n) candidate set the
    way frozen phantom jobs did. Requires an explicit ``initial`` (for
    the ward's own jobs only — reservation rows pin themselves).

    Returns (objectives (B,) float ndarray, [per-ward (n_i,) int arrays])
    where objectives INCLUDE reservation contributions and assignments
    cover only the ward's own jobs. Termination is per-instance: a ward
    that reaches a 1-move local optimum goes inactive while stragglers
    keep searching; the device call returns when every ward has converged
    (or after max_rounds accept-as-you-go passes over the movable slots —
    the Python search's max_count, default 50). Each ward's
    trajectory is identical to a solo `tabu_search_jax` run — same pass
    code, same tie-breaks — which the parity suite pins (DESIGN.md §8).
    Recompiles per (B, n_max, movable bucket S, padded machine counts,
    objective); replans reusing one shape hit the cache.
    """
    B = len(batch_jobs)
    if B == 0:
        return np.zeros((0,)), []
    if reserved is None:
        reserved = [None] * B
    elif initial is None and any(r for r in reserved):
        raise ValueError("reservations require an explicit initial "
                         "assignment (greedy init ignores their "
                         "occupancy)")
    rsv = [_reservation_rows(r) for r in reserved]
    sizes = [len(jobs) for jobs in batch_jobs]
    rows = [nb + rr.shape[0] for nb, (rr, _) in zip(sizes, rsv)]
    n_max = max(rows)
    if pad_to is not None:
        n_max = max(n_max, int(pad_to))
    if frozen is not None and initial is None:
        raise ValueError("frozen jobs require an explicit initial "
                         "assignment (greedy init would reassign them)")
    mpts = _per_instance_mpt(machines_per_tier, B)
    m_max = (max(c for c, _ in mpts), max(e for _, e in mpts))
    if busy_until is None:
        busy_until = [None] * B
    if n_max == 0:
        return np.zeros((B,)), [np.zeros((0,), np.int64) for _ in range(B)]

    rel = np.zeros((B, n_max), np.float32)
    w = np.zeros((B, n_max), np.float32)
    proc = np.zeros((B, n_max, N_MACHINES), np.float32)
    trans = np.zeros((B, n_max, N_MACHINES), np.float32)
    movable = np.zeros((B, n_max), bool)
    assign0 = np.full((B, n_max), 2, np.int32)  # phantoms pinned to device
    busy_c = np.full((B, m_max[0]), np.inf, np.float32)
    busy_e = np.full((B, m_max[1]), np.inf, np.float32)
    for b, jobs in enumerate(batch_jobs):
        nb = sizes[b]
        bc, be = _normalize_busy(busy_until[b], mpts[b])
        busy_c[b, :mpts[b][0]] = bc
        busy_e[b, :mpts[b][1]] = be
        rr, rt = rsv[b]
        if nb:
            rel[b, :nb], w[b, :nb], proc[b, :nb], trans[b, :nb] = \
                _specs_to_np(jobs)
            movable[b, :nb] = True
            if frozen is not None and frozen[b] is not None:
                fr = np.asarray(list(frozen[b]), bool)
                if fr.shape != (nb,):
                    raise ValueError(f"ward {b}: frozen mask has shape "
                                     f"{fr.shape}, expected ({nb},)")
                movable[b, :nb] &= ~fr
            if initial is not None:
                assign0[b, :nb] = list(initial[b])
        if rt.shape[0]:
            hi = nb + rt.shape[0]
            rel[b, nb:hi] = rr[:, 0]
            w[b, nb:hi] = rr[:, 1]
            proc[b, nb:hi] = rr[:, 2:5]
            trans[b, nb:hi] = rr[:, 5:8]
            assign0[b, nb:hi] = rt
    mov_idx, mov_ok = _movable_slots(movable, n_max)
    if max_rounds is None:
        max_rounds = 50
    # static regime dispatch (DESIGN.md §12): movable-dominated batches
    # (movable bucket at least half the padded rows) take the wide
    # steepest-descent rounds; background-heavy batches take the
    # width-1 movable-slot passes. Both sides of the threshold are a
    # pure function of the batch's padded shape, so every ward of one
    # call follows one regime and B = 1 replays it exactly.
    mode = "round" if 2 * mov_idx.shape[1] >= n_max else "pass"
    assign, totals, _ = _tabu_run_batched(
        assign0, rel, w, proc, trans, movable, mov_idx, mov_ok,
        np.int32(max_rounds), busy_c, busy_e, objective,
        greedy_init=initial is None, mode=mode)
    assign = np.asarray(assign)
    return (np.asarray(totals, np.float64),
            [assign[b, :sizes[b]] for b in range(B)])


def tabu_search_jax(jobs: Sequence[JobSpec],
                    initial: Sequence[int] | np.ndarray | None = None,
                    *, max_rounds: int | None = None,
                    objective: str = "weighted",
                    machines_per_tier: Tuple[int, int] = (1, 1),
                    busy_until=None, frozen=None, reserved=None):
    """Fully-jitted Algorithm-2 neighbourhood search. Returns
    (best objective value, best assignment as an (n,) int array).

    The whole search — delta-evaluated n x 3 neighbourhood rounds, move
    acceptance, termination — runs inside one jitted lax.while_loop; the
    only transfer is the final result. Each accepted move strictly
    improves the objective, so the search terminates at a 1-move local
    optimum of the same neighbourhood the Python tabu search explores.
    This is the B = 1 case of `tabu_search_batched` (same compiled round
    code), so solo and batched runs follow identical trajectories.

    busy_until: optional (cloud_times, edge_times) initial machine free
    times — online replans pass the committed fleet state here, so the
    searched objective is the commit objective (DESIGN.md §7). Traced, so
    successive replans hit the same compiled search."""
    vals, assigns = tabu_search_batched(
        [jobs], None if initial is None else [list(initial)],
        max_rounds=max_rounds, objective=objective,
        machines_per_tier=(int(machines_per_tier[0]),
                           int(machines_per_tier[1])),
        busy_until=None if busy_until is None else [busy_until],
        frozen=None if frozen is None else [frozen],
        reserved=None if reserved is None else [reserved])
    return float(vals[0]), assigns[0]


def stochastic_search(jobs: Sequence[JobSpec], key,
                      initial: np.ndarray, *, iters: int = 200,
                      pop: int = 256, objective: str = "weighted",
                      machines_per_tier: Tuple[int, int] = (1, 1),
                      busy_until=None):
    """Random-restart 1-move local search, evaluated in vmapped batches.

    Each iteration proposes `pop` single-job reassignments of the incumbent
    and keeps the best. Converges to (at least) a 1-swap local optimum of
    the same neighbourhood Algorithm 2 explores, but evaluates the whole
    neighbourhood batch in one device call. Kept as the host-synced
    baseline for `tabu_search_jax` (see benchmarks/scheduler_scale.py).

    machines_per_tier / busy_until describe the fleet the schedule runs on
    (DESIGN.md §7) and are threaded into every candidate evaluation — the
    searched objective is the deployed fleet's objective, not the
    (1, 1)-idle default's.
    """
    n = len(jobs)
    rel, w, proc, trans = specs_to_arrays(jobs)
    incumbent = jnp.asarray(initial, jnp.int32)
    best = evaluate_assignments(incumbent[None], rel, w, proc, trans,
                                machines_per_tier=machines_per_tier,
                                busy_until=busy_until)
    best_v = float(best[objective][0])

    for _ in range(iters):
        key, k1, k2 = jax.random.split(key, 3)
        jobs_i = jax.random.randint(k1, (pop,), 0, n)
        machines = jax.random.randint(k2, (pop,), 0, N_MACHINES)
        cand = jnp.tile(incumbent[None], (pop, 1))
        cand = cand.at[jnp.arange(pop), jobs_i].set(machines)
        m = evaluate_assignments(cand, rel, w, proc, trans,
                                 machines_per_tier=machines_per_tier,
                                 busy_until=busy_until)
        vals = np.asarray(m[objective])
        i = int(np.argmin(vals))
        if vals[i] < best_v:
            best_v = float(vals[i])
            incumbent = cand[i]
    return best_v, np.asarray(incumbent)
