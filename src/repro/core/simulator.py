"""Discrete-event evaluator for multi-job schedules (paper Section V).

Semantics (constraints C1-C5, validated against the paper's Table VII —
see DESIGN.md §1):
  * arrival_at_machine = release + transmission  (C4: data ships ahead and
    queues; transmission overlaps other jobs' processing)
  * shared machines (cloud, edge) run one job at a time, non-preemptive
    (C1, C2), FIFO by arrival (tie: release, then job index)
  * the device tier is private — every job has its own end device, so
    device jobs never queue (paper Section V.A)
  * response of job i = E_i - R_i, weighted by priority w_i (eq. 5)
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Sequence

from repro.core.tiers import CC, ED, ES, TIER_ORDER

MACHINES = list(TIER_ORDER)          # ["cloud", "edge", "device"]


@dataclass(frozen=True)
class JobSpec:
    """Scheduler-facing view of a job: the (proc, trans) row per tier.

    Built either from a CostModel (core.problems.jobs_to_specs) or directly
    from a paper table (benchmarks/table7).
    """
    name: str
    release: float
    weight: float
    proc: Mapping[str, float]        # tier -> I_i
    trans: Mapping[str, float]       # tier -> D_i (device: 0)

    def response_if_alone(self, tier: str) -> float:
        return self.proc[tier] + self.trans[tier]


@dataclass(frozen=True)
class ScheduledJob:
    job: JobSpec
    machine: str
    arrival: float
    start: float
    end: float

    @property
    def response(self) -> float:
        return self.end - self.job.release


@dataclass(frozen=True)
class Schedule:
    entries: List[ScheduledJob]
    weighted_sum: float              # eq. (5): sum w_i (E_i - R_i)
    unweighted_sum: float            # what the paper's Table VII reports
    last_end: float                  # "Last Response Time"

    def assignment(self) -> List[str]:
        return [e.machine for e in self.entries]


def simulate(jobs: Sequence[JobSpec], assignment: Sequence[str],
             machines_per_tier: Mapping[str, int] | None = None) -> Schedule:
    """Evaluate a fixed job->tier assignment under the C1-C5 semantics."""
    assert len(jobs) == len(assignment)
    machines_per_tier = machines_per_tier or {CC: 1, ES: 1}
    entries: List[ScheduledJob | None] = [None] * len(jobs)

    # private tier: no queueing
    for idx, (job, tier) in enumerate(zip(jobs, assignment)):
        if tier == ED:
            arr = job.release + job.trans.get(ED, 0.0)
            entries[idx] = ScheduledJob(job, ED, arr, arr,
                                        arr + job.proc[ED])

    # shared tiers: FIFO by (arrival, release, index) over a free-time heap
    for tier in (CC, ES):
        queue = sorted(
            (i for i, t in enumerate(assignment) if t == tier),
            key=lambda i: (jobs[i].release + jobs[i].trans[tier],
                           jobs[i].release, i))
        free = [0.0] * machines_per_tier.get(tier, 1)
        heapq.heapify(free)
        for i in queue:
            job = jobs[i]
            arr = job.release + job.trans[tier]
            avail = heapq.heappop(free)
            start = max(arr, avail)
            end = start + job.proc[tier]
            heapq.heappush(free, end)
            entries[i] = ScheduledJob(job, tier, arr, start, end)

    done = [e for e in entries if e is not None]
    assert len(done) == len(jobs)
    weighted = sum(e.job.weight * e.response for e in done)
    unweighted = sum(e.response for e in done)
    last = max(e.end for e in done) if done else 0.0
    return Schedule(entries=done, weighted_sum=weighted,
                    unweighted_sum=unweighted, last_end=last)
