"""Discrete-event evaluator for multi-job schedules (paper Section V).

Semantics (constraints C1-C5, validated against the paper's Table VII —
see DESIGN.md §1):
  * arrival_at_machine = release + transmission  (C4: data ships ahead and
    queues; transmission overlaps other jobs' processing)
  * shared machines (cloud, edge) run one job at a time, non-preemptive
    (C1, C2), FIFO by arrival (tie: release, then job index)
  * the device tier is private — every job has its own end device, so
    device jobs never queue (paper Section V.A)
  * response of job i = E_i - R_i, weighted by priority w_i (eq. 5)
  * shared machines may start busy: ``busy_until`` gives each machine's
    initial free time (DESIGN.md §7 — online replanning scores candidate
    schedules against machines already occupied by committed jobs)
  * shared machines may carry RESERVED INTERVALS (``reserved``,
    DESIGN.md §12): committed background occupancy that enters the FIFO
    queue exactly like a frozen job — it holds a machine for its
    processing time at its queue position and its (weighted) response
    counts toward the objective — but is not part of the instance's
    jobs/assignment, so a search can never move it. Queue ties between a
    job and a reservation go to the job (a reservation behaves like a
    job appended after the instance's own jobs, which is how the frozen
    phantom-job construction it replaces ordered them).
"""
from __future__ import annotations

import bisect
import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Sequence, Tuple

from repro.core.tiers import CC, ED, ES, TIER_ORDER

MACHINES = list(TIER_ORDER)          # ["cloud", "edge", "device"]


@dataclass(frozen=True)
class JobSpec:
    """Scheduler-facing view of a job: the (proc, trans) row per tier.

    Built either from a CostModel (core.problems.jobs_to_specs) or directly
    from a paper table (benchmarks/table7).
    """
    name: str
    release: float
    weight: float
    proc: Mapping[str, float]        # tier -> I_i
    trans: Mapping[str, float]       # tier -> D_i (device: 0)
    workload: str = ""               # originating workload (serving maps
                                     # schedule entries back to engines)
    deadline: float = float("inf")   # SLA budget on response = end - release
                                     # (relative, not absolute; metro traffic
                                     # scores miss-rate against it)

    def response_if_alone(self, tier: str) -> float:
        return self.proc[tier] + self.trans[tier]


@dataclass(frozen=True)
class Reservation:
    """Committed background occupancy on ONE shared tier (DESIGN.md §12).

    The interval-reservation replacement for frozen phantom jobs: a
    reservation is queue-active (it joins the tier's FIFO queue at
    (arrival, release) and holds a machine for ``proc``), contributes
    ``weight * (end - release)`` to the weighted objective (and its
    response/end to the unweighted/last objectives) so planners price the
    delay they inflict on it — but it is not a job of the instance, so no
    search can ever reassign it. Ties against real jobs at equal
    (arrival, release) dispatch the job first; ties among reservations
    keep list order — both exactly the order the frozen-phantom
    construction (jobs + appended background) produced.
    """
    arrival: float                   # when its data reaches the tier
    proc: float                      # processing time on the tier
    release: float                   # FIFO tiebreak + response baseline
    weight: float = 0.0              # objective contribution (0: occupancy
                                     # only)


def _resv_map(reserved, allowed=()) -> Dict[str, List[Tuple[int,
                                                            "Reservation"]]]:
    """-> {tier: [(input position, Reservation)]} in dispatch order
    (sorted by (arrival, release), stable — input order breaks ties),
    validating tier names. ``allowed``: tiers reservations may name
    (shared tiers only). Results keep the input position so callers can
    report timings aligned with the caller's lists."""
    out: Dict[str, List[Tuple[int, Reservation]]] = {}
    for tier, vals in (reserved or {}).items():
        if tier not in allowed:
            raise ValueError(
                f"reservations may only name shared tiers {list(allowed)}, "
                f"got {tier!r}")
        rs = list(enumerate(vals))
        if rs:
            out[tier] = sorted(rs, key=lambda kr: (kr[1].arrival,
                                                   kr[1].release, kr[0]))
    return out


@dataclass(frozen=True)
class ScheduledJob:
    job: JobSpec
    machine: str
    arrival: float
    start: float
    end: float

    @property
    def response(self) -> float:
        return self.end - self.job.release


def schedule_objective(sched, objective: str = "weighted") -> float:
    """One of the three reported objectives off a Schedule/FleetSchedule."""
    return {"weighted": sched.weighted_sum,
            "unweighted": sched.unweighted_sum,
            "last": sched.last_end}[objective]


@dataclass(frozen=True)
class Schedule:
    entries: List[ScheduledJob]
    weighted_sum: float              # eq. (5): sum w_i (E_i - R_i) — when
                                     # the instance carried reservations,
                                     # INCLUDES their contributions (the
                                     # objective a search prices, §12)
    unweighted_sum: float            # what the paper's Table VII reports
    last_end: float                  # "Last Response Time"
    # (arrival, start, end) per input reservation, {tier: list aligned
    # with the reserved= argument's input lists} — online fleet
    # replanning re-times other wards' commitments from this (§12)
    reserved_times: Dict[str, List[Tuple[float, float, float]]] | None \
        = None

    def assignment(self) -> List[str]:
        return [e.machine for e in self.entries]

    def objective(self, objective: str = "weighted") -> float:
        return schedule_objective(self, objective)


def machine_free_times(busy_until: Mapping[str, Sequence[float]] | None,
                       tier: str, machines: int) -> List[float]:
    """Initial per-machine free times for a shared tier, sorted ascending.

    ``busy_until[tier]`` may list fewer entries than there are machines —
    the rest start idle (free at t=0). More entries than machines is a
    caller bug (a tier cannot be running more jobs than it has servers) —
    reported as ValueError, not assert, so the guard survives
    ``python -O``.
    """
    vals = sorted(float(v) for v in (busy_until or {}).get(tier, ()))
    if len(vals) > machines:
        raise ValueError(
            f"busy_until[{tier!r}] lists {len(vals)} occupied machines "
            f"but the tier has only {machines}")
    return [0.0] * (machines - len(vals)) + vals


def _fifo_pool(items, free: List[float]):
    """FIFO dispatch of one machine POOL: ``items`` iterates (arrival,
    proc) in queue order, ``free`` is the pool's initial machine
    free-time vector (consumed). Yields (arrival, start, end) per item —
    the C5 semantics every evaluator in this module shares: each job pops
    the earliest-free machine and starts at max(arrival, free)."""
    heapq.heapify(free)
    for arr, proc in items:
        avail = heapq.heappop(free)
        start = arr if arr > avail else avail
        end = start + proc
        heapq.heappush(free, end)
        yield arr, start, end


def simulate(jobs: Sequence[JobSpec], assignment: Sequence[str],
             machines_per_tier: Mapping[str, int] | None = None,
             busy_until: Mapping[str, Sequence[float]] | None = None,
             reserved: Mapping[str, Sequence[Reservation]] | None = None
             ) -> Schedule:
    """Evaluate a fixed job->tier assignment under the C1-C5 semantics.

    busy_until: optional {tier: [machine free times]} — shared machines
    already occupied by previously committed jobs (DESIGN.md §7). A job
    cannot start on a machine before that machine's entry.
    reserved: optional {tier: [Reservation]} — committed background
    occupancy merged into the shared FIFO queues (DESIGN.md §12). The
    returned sums include reservation responses (jobs first in index
    order, then cloud reservations, then edge reservations — exactly the
    frozen-phantom accumulation order this replaces), and the returned
    ``reserved_times`` reports each reservation's (arrival, start, end)
    aligned with the input lists.
    """
    if len(jobs) != len(assignment):
        raise ValueError(f"{len(jobs)} jobs but {len(assignment)} "
                         f"assignment entries")
    machines_per_tier = machines_per_tier or {CC: 1, ES: 1}
    resv = _resv_map(reserved, allowed=(CC, ES))
    entries: List[ScheduledJob | None] = [None] * len(jobs)
    resv_times: Dict[str, List[Tuple[float, float, float]]] = {
        tier: [(0.0, 0.0, 0.0)] * len(rs) for tier, rs in resv.items()}

    # private tier: no queueing
    for idx, (job, tier) in enumerate(zip(jobs, assignment)):
        if tier == ED:
            arr = job.release + job.trans.get(ED, 0.0)
            entries[idx] = ScheduledJob(job, ED, arr, arr,
                                        arr + job.proc[ED])

    # shared tiers: FIFO by (arrival, release, kind, index) over a
    # free-time heap — kind 0 = the instance's own jobs, kind 1 =
    # reservations, so ties dispatch the job first (§12)
    for tier in (CC, ES):
        queue = sorted(
            [((jobs[i].release + jobs[i].trans[tier], jobs[i].release,
               0, i), i) for i, t in enumerate(assignment) if t == tier]
            + [((r.arrival, r.release, 1, k), ~pos)
               for k, (pos, r) in enumerate(resv.get(tier, ()))])
        free = machine_free_times(busy_until, tier,
                                  machines_per_tier.get(tier, 1))
        rs = resv.get(tier, ())
        timed = _fifo_pool(
            (((jobs[i].release + jobs[i].trans[tier], jobs[i].proc[tier])
              if i >= 0 else (key[0], rs[key[3]][1].proc))
             for key, i in queue), free)
        for (key, i), (arr, start, end) in zip(queue, timed):
            if i >= 0:
                entries[i] = ScheduledJob(jobs[i], tier, arr, start, end)
            else:
                resv_times[tier][~i] = (arr, start, end)

    done = [e for e in entries if e is not None]
    if len(done) != len(jobs):
        raise ValueError("assignment names an unknown tier: "
                         f"{sorted(set(assignment) - set(MACHINES))}")
    weighted = sum(e.job.weight * e.response for e in done)
    unweighted = sum(e.response for e in done)
    last = max(e.end for e in done) if done else 0.0
    # reservation contributions accumulate in INPUT order (cloud list,
    # then edge list) — the order the frozen-phantom construction appended
    # them, so objectives stay bit-identical to that path
    for tier in (CC, ES):
        for pos, r in enumerate((reserved or {}).get(tier) or ()):
            end = resv_times[tier][pos][2]
            resp = end - r.release
            weighted += r.weight * resp
            unweighted += resp
            if end > last:
                last = end
    return Schedule(entries=done, weighted_sum=weighted,
                    unweighted_sum=unweighted, last_end=last,
                    reserved_times=resv_times or None)


# --------------------------------------------------- fleet-true evaluation
@dataclass(frozen=True)
class FleetSchedule:
    """A joint multi-ward plan scored on the REAL fleet (DESIGN.md §9):
    shared tiers are one machine pool with a merged FIFO queue across all
    wards, so the per-ward numbers here are achievable simultaneously —
    unlike B independent `simulate` calls, which silently double-book the
    shared servers."""
    wards: List[Schedule]            # per-ward entries with fleet-true times
    weighted_sum: float              # fleet totals INCLUDE reservation
    unweighted_sum: float            # contributions (§12) — reservations
    last_end: float                  # belong to no ward's Schedule
    # (arrival, start, end) per input reservation for the SHARED pools /
    # the per-ward pools, aligned with the reserved=/ward_reserved= input
    reserved_times: Dict[str, List[Tuple[float, float, float]]] | None \
        = None
    ward_reserved_times: List[Dict[str, List[Tuple[float, float, float]]]] \
        | None = None

    def objective(self, objective: str = "weighted") -> float:
        return schedule_objective(self, objective)


def _fleet_mpts(machines_per_tier, B: int,
                shared_tiers: Tuple[str, ...]) -> List[Dict[str, int]]:
    """-> per-ward {tier: count} dicts from one mapping or a per-ward
    sequence; counts of a SHARED tier must agree across wards (there is
    exactly one pool)."""
    if machines_per_tier is None or isinstance(machines_per_tier, Mapping):
        mpts = [dict(machines_per_tier or {CC: 1, ES: 1})] * B
    else:
        mpts = [dict(m or {CC: 1, ES: 1}) for m in machines_per_tier]
        if len(mpts) != B:
            raise ValueError(f"machines_per_tier lists {len(mpts)} fleets "
                             f"for {B} wards")
        for tier in shared_tiers:
            counts = {m.get(tier, 1) for m in mpts}
            if len(counts) > 1:
                raise ValueError(
                    f"shared tier {tier!r} is one pool but wards disagree "
                    f"on its machine count: {sorted(counts)}")
    return mpts


def simulate_fleet(ward_jobs: Sequence[Sequence[JobSpec]],
                   ward_assignments: Sequence[Sequence[str]],
                   machines_per_tier=None,
                   busy_until: Mapping[str, Sequence[float]] | None = None,
                   ward_busy_until=None,
                   shared_tiers: Tuple[str, ...] = (CC,),
                   reserved: Mapping[str, Sequence[Reservation]] | None = None,
                   ward_reserved=None) -> FleetSchedule:
    """Evaluate a JOINT multi-ward plan under C1-C5 on the real fleet.

    Machine pools (DESIGN.md §9): every tier in ``shared_tiers`` (default:
    the metropolitan cloud) is ONE pool serving all wards through a single
    merged FIFO queue, ordered by (arrival, release, ward, index) — exactly
    the queue of the wards-concatenated single instance, so this is the
    ground truth that per-ward-independent planning double-books. Shared
    tiers not in ``shared_tiers`` (default: edge) are per-ward pools; the
    device tier stays private per job.

    machines_per_tier: one {tier: count} mapping for every ward or a
    per-ward sequence (shared-tier counts must agree — one pool).
    busy_until: {tier: [free times]} for the SHARED pools.
    ward_busy_until: optional per-ward {tier: [free times]} for the
    per-ward pools.
    shared_tiers: which of (cloud, edge) are metropolitan-shared; the
    private device tier cannot be shared.
    reserved: {tier: [Reservation]} committed background occupancy merged
    into the SHARED pools' queues (DESIGN.md §12); ward_reserved is the
    per-ward-pool analog (same channel split as busy_until). Reservation
    responses count toward the fleet totals (they belong to no ward) and
    their timings come back in ``reserved_times`` aligned with the input
    lists (shared tiers; per-ward pools report under ``ward_reserved_times``).
    """
    B = len(ward_jobs)
    if len(ward_assignments) != B:
        raise ValueError(f"{B} wards but {len(ward_assignments)} "
                         f"assignments")
    for b, (jobs, assign) in enumerate(zip(ward_jobs, ward_assignments)):
        if len(jobs) != len(assign):
            raise ValueError(f"ward {b}: {len(jobs)} jobs but "
                             f"{len(assign)} assignment entries")
    bad = set(shared_tiers) - set(_SHARED)
    if bad:
        raise ValueError(f"only cloud/edge tiers can be pooled: {bad}")
    mpts = _fleet_mpts(machines_per_tier, B, shared_tiers)
    busys = [None] * B if ward_busy_until is None else list(ward_busy_until)
    if len(busys) != B:
        raise ValueError(f"{len(busys)} ward busy vectors for {B} wards")
    # occupancy must arrive through the right channel — a busy_until entry
    # for a per-ward tier (or ward_busy_until for a pooled tier) would be
    # silently ignored and understate every response time
    stray = [t for t in (busy_until or {}) if t not in shared_tiers]
    if stray:
        raise ValueError(
            f"busy_until names non-shared tiers {stray}; per-ward pool "
            f"occupancy goes in ward_busy_until")
    stray = sorted({t for wb in busys for t in (wb or {})
                    if t in shared_tiers})
    if stray:
        raise ValueError(
            f"ward_busy_until names shared tiers {stray}; the shared "
            f"pools' occupancy goes in busy_until")
    # reservations use the same channel split: `reserved` may only name
    # the shared pools, `ward_reserved` only the per-ward pools
    resv = _resv_map(reserved, allowed=tuple(shared_tiers))
    wrs = [None] * B if ward_reserved is None else list(ward_reserved)
    if len(wrs) != B:
        raise ValueError(f"{len(wrs)} ward reservation maps for {B} wards")
    per_ward_shared = tuple(t for t in _SHARED if t not in shared_tiers)
    ward_resv = [_resv_map(wr, allowed=per_ward_shared) for wr in wrs]
    resv_times: Dict[str, List[Tuple[float, float, float]]] = {
        tier: [(0.0, 0.0, 0.0)] * len(rs) for tier, rs in resv.items()}
    ward_resv_times: List[Dict[str, List[Tuple[float, float, float]]]] = [
        {tier: [(0.0, 0.0, 0.0)] * len(rs) for tier, rs in rm.items()}
        for rm in ward_resv]

    entries: List[List[ScheduledJob | None]] = [
        [None] * len(jobs) for jobs in ward_jobs]

    # private tier: no queueing, per ward exactly as `simulate`
    for b, (jobs, assign) in enumerate(zip(ward_jobs, ward_assignments)):
        for i, (job, tier) in enumerate(zip(jobs, assign)):
            if tier == ED:
                arr = job.release + job.trans.get(ED, 0.0)
                entries[b][i] = ScheduledJob(job, ED, arr, arr,
                                             arr + job.proc[ED])

    def run_pool(tier: str, members, free: List[float],
                 rs=(), times=None) -> None:
        """members: (b, i) pairs; dispatches the pool's merged queue with
        the pool's reservations ``rs`` ([(input pos, Reservation)] in
        dispatch order — §12: a tie on (arrival, release) goes to the
        job). Writes reservation (arrival, start, end) into ``times`` at
        the input position."""
        recs = sorted(
            [((ward_jobs[b][i].release + ward_jobs[b][i].trans[tier],
               ward_jobs[b][i].release, 0, (b, i)), None)
             for b, i in members]
            + [((r.arrival, r.release, 1, k), (pos, r))
               for k, (pos, r) in enumerate(rs)])
        timed = _fifo_pool(
            ((key[0],
              rp[1].proc if rp is not None
              else ward_jobs[key[3][0]][key[3][1]].proc[tier])
             for key, rp in recs), free)
        for (key, rp), (arr, start, end) in zip(recs, timed):
            if rp is None:
                b, i = key[3]
                entries[b][i] = ScheduledJob(ward_jobs[b][i], tier, arr,
                                             start, end)
            else:
                times[rp[0]] = (arr, start, end)

    for tier in _SHARED:
        if tier in shared_tiers:
            if not mpts:                       # B == 0: nothing to pool
                continue
            run_pool(tier,
                     [(b, i) for b in range(B)
                      for i, t in enumerate(ward_assignments[b])
                      if t == tier],
                     machine_free_times(busy_until, tier,
                                        mpts[0].get(tier, 1)),
                     rs=resv.get(tier, ()),
                     times=resv_times.get(tier))
        else:
            for b in range(B):
                run_pool(tier,
                         [(b, i) for i, t in enumerate(ward_assignments[b])
                          if t == tier],
                         machine_free_times(busys[b], tier,
                                            mpts[b].get(tier, 1)),
                         rs=ward_resv[b].get(tier, ()),
                         times=ward_resv_times[b].get(tier))

    wards = []
    for b, jobs in enumerate(ward_jobs):
        done = [e for e in entries[b] if e is not None]
        if len(done) != len(jobs):
            raise ValueError(
                f"ward {b} assignment names an unknown tier: "
                f"{sorted(set(ward_assignments[b]) - set(MACHINES))}")
        wards.append(Schedule(
            entries=done,
            weighted_sum=sum(e.job.weight * e.response for e in done),
            unweighted_sum=sum(e.response for e in done),
            last_end=max((e.end for e in done), default=0.0)))
    w_tot = sum(s.weighted_sum for s in wards)
    u_tot = sum(s.unweighted_sum for s in wards)
    last = max((s.last_end for s in wards), default=0.0)
    # reservation contributions in input order: shared pools (cloud then
    # edge), then per-ward pools in ward order
    for tier in _SHARED:
        for pos, r in enumerate((reserved or {}).get(tier) or ()):
            end = resv_times[tier][pos][2]
            resp = end - r.release
            w_tot += r.weight * resp
            u_tot += resp
            if end > last:
                last = end
    for b, wr in enumerate(wrs):
        for tier in _SHARED:
            for pos, r in enumerate((wr or {}).get(tier) or ()):
                end = ward_resv_times[b][tier][pos][2]
                resp = end - r.release
                w_tot += r.weight * resp
                u_tot += resp
                if end > last:
                    last = end
    return FleetSchedule(
        wards=wards,
        weighted_sum=w_tot,
        unweighted_sum=u_tot,
        last_end=last,
        reserved_times=resv_times or None,
        ward_reserved_times=(ward_resv_times
                             if any(ward_resv_times) else None))


# ------------------------------------------------- incremental evaluation
_SHARED = (CC, ES)
_OBJ = {"weighted": 0, "unweighted": 1, "last": 2}


class ScheduleState:
    """Incremental evaluator over job->tier assignments (DESIGN.md §3.1).

    Moving one job between tiers only perturbs the two affected machine
    queues (C1-C5 are per-machine FIFO semantics), so this caches each
    tier's FIFO queue, per-job completion times, and per-tier objective
    sums. A single-move trial then costs O(|src queue| + |dst queue|) —
    and O(1) on the private device tier, whose per-job contributions are
    constants — instead of a full O(n log n) re-simulation. This is the
    hot path of the Algorithm-2 tabu search.

    Invariants (DESIGN.md §3.1): COMMITTED per-tier stats are always
    recomputed from the tier's full queue (never updated by +=/-= deltas),
    so the incumbent objective is drift-free; ``end`` always mirrors what
    ``simulate`` would produce for the current assignment. Only trial
    scores from ``try_move`` may use a single non-accumulated +/- of a
    precomputed constant (device tier), bounded by one rounding error.
    """

    def __init__(self, jobs: Sequence[JobSpec], assignment: Sequence[str],
                 machines_per_tier: Mapping[str, int] | None = None,
                 busy_until: Mapping[str, Sequence[float]] | None = None,
                 reserved: Mapping[str, Sequence[Reservation]] | None = None):
        if len(jobs) != len(assignment):
            raise ValueError(f"{len(jobs)} jobs but {len(assignment)} "
                             f"assignment entries")
        self.jobs = list(jobs)
        self.assign = list(assignment)
        self.machines = dict(machines_per_tier or {CC: 1, ES: 1})
        # reservations never move, so each shared tier keeps its dispatch-
        # ordered (arrival, release, proc, weight) rows once; _sim_shared
        # merges them into every FIFO pass (§12)
        self.reserved = {t: list(v) for t, v in (reserved or {}).items()}
        _rm = _resv_map(reserved, allowed=_SHARED)
        self._resv = {t: [(r.arrival, r.release, r.proc, r.weight)
                          for _, r in _rm.get(t, ())] for t in _SHARED}
        self.busy = {t: tuple(machine_free_times(busy_until, t,
                                                 self.machines.get(t, 1)))
                     for t in _SHARED}
        n = len(self.jobs)
        self.end: List[float] = [0.0] * n
        # per-job constants: releases, weights, per-tier proc, FIFO keys,
        # and the device tier's fixed completion/response contributions
        self._rel = [j.release for j in self.jobs]
        self._w = [j.weight for j in self.jobs]
        self._proc = {t: [j.proc[t] for j in self.jobs] for t in _SHARED}
        self._keys = {
            t: [(j.release + j.trans[t], j.release, i)
                for i, j in enumerate(self.jobs)] for t in _SHARED}
        self._dev_end = [j.release + j.trans.get(ED, 0.0) + j.proc[ED]
                         for j in self.jobs]
        self._dev_resp = [e - r for e, r in zip(self._dev_end, self._rel)]
        self._dev_wresp = [w * r for w, r in zip(self._w, self._dev_resp)]
        # shared tiers: sorted [(key, idx)] with key = (arrival, release, i)
        self._members: Dict[str, List[Tuple[Tuple[float, float, int], int]]]
        self._members = {
            tier: sorted((self._keys[tier][i], i)
                         for i, t in enumerate(self.assign) if t == tier)
            for tier in _SHARED}
        self._device: List[int] = sorted(
            i for i, t in enumerate(self.assign) if t == ED)
        self._stats: Dict[str, Tuple[float, float, float]] = {}
        for tier in _SHARED:
            ends, self._stats[tier] = self._sim_shared(
                tier, self._members[tier])
            for (_, i), e in zip(self._members[tier], ends):
                self.end[i] = e
        for i in self._device:
            self.end[i] = self._dev_end[i]
        self._stats[ED] = self._device_stats(self._device)

    # ------------------------------------------------------------ internals
    def _device_stats(self, members: Sequence[int]):
        w = sum(self._dev_wresp[i] for i in members)
        u = sum(self._dev_resp[i] for i in members)
        last = max((self._dev_end[i] for i in members), default=0.0)
        return w, u, last

    def _sim_shared(self, tier: str, members):
        """One FIFO pass over a shared tier's sorted queue.

        Returns (ends aligned with members, (weighted, unweighted, last)).
        Identical machine semantics to ``simulate``: a free-time heap of
        ``machines[tier]`` servers, start = max(arrival, earliest free);
        the single-server case runs heap-free. The tier's reservations are
        merged into the walk by (arrival, release) — a reservation at an
        exact (arrival, release) tie with a job dispatches after it — and
        their (weighted) responses accumulate into the stats in merged
        queue order, so the stats match the frozen-phantom queue this
        replaces bit-for-bit.
        """
        rel, wgt, proc = self._rel, self._w, self._proc[tier]
        m = self.machines.get(tier, 1)
        busy = self.busy[tier]
        rs = self._resv[tier]
        nr = len(rs)
        ri = 0
        ends: List[float] = []
        append = ends.append
        w = u = last = 0.0
        if m == 1:
            free = busy[0]
            for key, i in members:
                arr = key[0]
                while ri < nr and (rs[ri][0], rs[ri][1]) < (arr, key[1]):
                    ra, rr, rp, rw = rs[ri]
                    start = ra if ra > free else free
                    free = e = start + rp
                    resp = e - rr
                    w += rw * resp
                    u += resp
                    ri += 1
                start = arr if arr > free else free
                free = e = start + proc[i]
                append(e)
                resp = e - rel[i]
                w += wgt[i] * resp
                u += resp
            while ri < nr:
                ra, rr, rp, rw = rs[ri]
                start = ra if ra > free else free
                free = e = start + rp
                resp = e - rr
                w += rw * resp
                u += resp
                ri += 1
            last = free if (ends or nr) else 0.0
        else:
            heap = list(busy)
            heapq.heapify(heap)

            def dispatch(arr, p):
                avail = heapq.heappop(heap)
                start = arr if arr > avail else avail
                e = start + p
                heapq.heappush(heap, e)
                return e

            for key, i in members:
                arr = key[0]
                while ri < nr and (rs[ri][0], rs[ri][1]) < (arr, key[1]):
                    ra, rr, rp, rw = rs[ri]
                    e = dispatch(ra, rp)
                    resp = e - rr
                    w += rw * resp
                    u += resp
                    if e > last:
                        last = e
                    ri += 1
                e = dispatch(arr, proc[i])
                append(e)
                resp = e - rel[i]
                w += wgt[i] * resp
                u += resp
                if e > last:
                    last = e
            while ri < nr:
                ra, rr, rp, rw = rs[ri]
                e = dispatch(ra, rp)
                resp = e - rr
                w += rw * resp
                u += resp
                if e > last:
                    last = e
                ri += 1
        return ends, (w, u, last)

    def _shared_move_stats(self, tier: str, k: int, insert: bool):
        """(stats, members, ends) for tier with job k removed/inserted."""
        if insert:
            mem = list(self._members[tier])
            bisect.insort(mem, (self._keys[tier][k], k))
        else:
            mem = [m for m in self._members[tier] if m[1] != k]
        ends, stats = self._sim_shared(tier, mem)
        return stats, mem, ends

    def _device_move_val(self, k: int, insert: bool, oi: int) -> float:
        """Device-tier stat component after removing/inserting job k.

        O(1) for the sum objectives (per-job contributions are constants
        on the private tier); "last" removal rescans only when k held the
        maximum."""
        w, u, last = self._stats[ED]
        if oi == 0:
            return w + self._dev_wresp[k] if insert else w - self._dev_wresp[k]
        if oi == 1:
            return u + self._dev_resp[k] if insert else u - self._dev_resp[k]
        if insert:
            return last if last > self._dev_end[k] else self._dev_end[k]
        if self._dev_end[k] < last:
            return last
        return max((self._dev_end[i] for i in self._device if i != k),
                   default=0.0)

    # ------------------------------------------------------------------ api
    def score(self, objective: str = "weighted") -> float:
        """Current objective, recomputed from per-tier sums (drift-free)."""
        oi = _OBJ[objective]
        a, b, c = (self._stats[CC][oi], self._stats[ES][oi],
                   self._stats[ED][oi])
        return max(a, b, c) if oi == 2 else a + b + c

    def try_move(self, k: int, dst: str,
                 objective: str = "weighted") -> float:
        """Objective value if job k were moved to dst (no mutation).

        Costs one FIFO pass per affected shared queue; the device tier is
        O(1) (sum objectives)."""
        src = self.assign[k]
        if dst == src:
            return self.score(objective)
        oi = _OBJ[objective]
        vals = []
        for tier in (CC, ES, ED):
            if tier == src or tier == dst:
                if tier == ED:
                    vals.append(self._device_move_val(k, tier == dst, oi))
                else:
                    stats, _, _ = self._shared_move_stats(
                        tier, k, insert=(tier == dst))
                    vals.append(stats[oi])
            else:
                vals.append(self._stats[tier][oi])
        return max(vals) if oi == 2 else vals[0] + vals[1] + vals[2]

    def apply_move(self, k: int, dst: str) -> None:
        """Commit job k to dst, updating queues, ends, and tier stats.

        All committed stats are full-queue recomputations (drift-free)."""
        src = self.assign[k]
        if dst == src:
            return
        for tier, insert in ((src, False), (dst, True)):
            if tier in _SHARED:
                stats, mem, ends = self._shared_move_stats(tier, k, insert)
                self._stats[tier] = stats
                self._members[tier] = mem
                for (_, i), e in zip(mem, ends):
                    self.end[i] = e
            else:
                if insert:
                    bisect.insort(self._device, k)
                    self.end[k] = self._dev_end[k]
                else:
                    self._device.remove(k)
                self._stats[ED] = self._device_stats(self._device)
        self.assign[k] = dst

    def to_schedule(self) -> Schedule:
        """Exact Schedule for the current assignment (via ``simulate``, so
        reported sums match the reference evaluator bit-for-bit)."""
        return simulate(self.jobs, self.assign,
                        machines_per_tier=self.machines,
                        busy_until=self.busy,
                        reserved=self.reserved or None)
