"""Algorithm 1 — optimal single-job tier allocation (paper Section IV).

Given a workload (model FLOPs per unit + data size), a cost model, and the
tier fleet, compute the estimated response time at every tier and pick the
argmin. This is the paper's core single-job contribution; Table V is this
algorithm run over 18 workloads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.core.cost_model import CostModel, Job
from repro.core.tiers import TIER_ORDER


@dataclass(frozen=True)
class Allocation:
    job: Job
    tier: str                                   # argmin tier
    response: float                             # T_min (eq. 4)
    per_tier: Dict[str, Tuple[float, float]]    # tier -> (D_i, I_i)

    @property
    def per_tier_response(self) -> Dict[str, float]:
        return {t: d + i for t, (d, i) in self.per_tier.items()}


def allocate_single(cost_model: CostModel, job: Job) -> Allocation:
    """Paper Algorithm 1: T_i = D_i + I_i per tier, return the argmin.

    Ties break toward the lower tier (device > edge > cloud) — computing
    near the user wins when equal, per the paper's Section VIII analysis.
    """
    per_tier = cost_model.times(job)
    best_tier, best_t = None, float("inf")
    # iterate device-first so ties keep the lowest tier
    for tier in reversed(TIER_ORDER):
        if tier not in per_tier:
            continue
        d, i = per_tier[tier]
        if d + i < best_t:
            best_tier, best_t = tier, d + i
    return Allocation(job=job, tier=best_tier, response=best_t,
                      per_tier=per_tier)
