"""Algorithm 2 — multi-job allocation heuristic (paper Section VI).

Pipeline:
  1. greedy initial solution: jobs in release order (tie: priority desc),
     each assigned to the machine minimising its completion time given the
     machine free-times so far ("the earliest released job gets the
     shortest response time");
  2. tabu-guarded neighbourhood search: repeatedly pick the
     earliest-completing non-tabu job, try moving it to every other
     machine, keep the move with the largest positive reduction of the
     weighted whole response time (paper lines 10-28);
  3. every candidate is scored with the incremental evaluator
     (simulator.ScheduleState) whose per-move cost is O(two machine
     queues); the returned Schedule is always a final exact re-simulation,
     so reported numbers always reflect C1-C5 semantics.

`search` dispatches between this Python path (small n) and the fully
jitted JAX neighbourhood search (scheduler_jax.tabu_search_jax) above
JAX_SEARCH_THRESHOLD jobs — see DESIGN.md §3.3 for the policy.

Also provides baseline strategies (Table VII comparison set), an exact
brute-force optimum for small n (the paper has none — we add it to measure
the heuristic's optimality gap), and `neighborhood_search_reference`, the
seed full-re-simulation implementation kept as a benchmark baseline and
parity oracle.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Dict, List, Mapping, Sequence, Tuple

from repro.core.simulator import (MACHINES, FleetSchedule, JobSpec, Schedule,
                                  ScheduleState, _fleet_mpts,
                                  machine_free_times, simulate,
                                  simulate_fleet)
from repro.core.tiers import CC, ED, ES

# above this many jobs, `search` uses the jitted JAX neighbourhood search
JAX_SEARCH_THRESHOLD = 64

# batches at least this large dispatch to the single-call batched JAX
# search (DESIGN.md §8); smaller ones loop the per-instance `search`
BATCHED_SEARCH_MIN_WARDS = 4

# (n, cloud machines, edge machines, objective) shapes the jitted solo
# search has already compiled IN THIS PROCESS. On CPU the delta-evaluated
# kernel beats the incremental Python path once compiled (DESIGN.md
# §3.3), but a fresh XLA trace costs seconds — so `search` only
# dispatches a CPU call to JAX when its shape is in here, i.e. when some
# earlier call (benchmark warm-up, explicit jax_threshold, TPU run)
# already paid the compile. Replanning loops with repeating shapes (the
# metro engine) then ride the compiled kernel for free.
#
# Note the trade this makes explicit: the two backends are both exact
# C1-C5 searches but follow different trajectories (paired moves, §8),
# so they can return DIFFERENT valid local optima — a cache hit changes
# which one a later same-shape call gets. `search` results are therefore
# deterministic per (inputs, dispatch state), not per inputs alone;
# callers that need call-order-independent output pin the backend with
# an explicit jax_threshold. The committed benchmarks run each section
# in a fixed order in a fresh process, so their numbers are stable.
_COMPILED_SHAPES: set = set()


# --------------------------------------------------------------- strategies
def all_on_tier(jobs: Sequence[JobSpec], tier: str,
                machines_per_tier: Mapping[str, int] | None = None
                ) -> Schedule:
    return simulate(jobs, [tier] * len(jobs),
                    machines_per_tier=machines_per_tier)


def per_job_optimal(jobs: Sequence[JobSpec],
                    machines_per_tier: Mapping[str, int] | None = None
                    ) -> Schedule:
    """Table VII row 2: each job on its own Algorithm-1-optimal tier,
    ignoring queueing."""
    assign = [min(MACHINES, key=lambda t: j.response_if_alone(t))
              for j in jobs]
    return simulate(jobs, assign, machines_per_tier=machines_per_tier)


# ------------------------------------------------------------------ greedy
def greedy_schedule(jobs: Sequence[JobSpec],
                    machines_per_tier: Mapping[str, int] | None = None,
                    busy_until: Mapping[str, Sequence[float]] | None = None
                    ) -> List[str]:
    """Initial feasible solution (Algorithm 2 step 1).

    Honors multi-server tiers (earliest-free machine per tier) and
    machines already busy at the start (``busy_until``, DESIGN.md §7) —
    the same greedy rule online scheduling commits on each arrival.
    """
    mpt = dict(machines_per_tier or {CC: 1, ES: 1})
    order = sorted(range(len(jobs)),
                   key=lambda i: (jobs[i].release, -jobs[i].weight, i))
    free = {t: machine_free_times(busy_until, t, mpt.get(t, 1))
            for t in (CC, ES)}
    for heap in free.values():
        heapq.heapify(heap)
    assign: List[str] = [""] * len(jobs)
    for i in order:
        job = jobs[i]
        best_t, best_end = None, float("inf")
        for tier in (ED, ES, CC):    # tie -> prefer lower tier
            arr = job.release + job.trans.get(tier, 0.0)
            start = arr if tier == ED else max(arr, free[tier][0])
            end = start + job.proc[tier]
            if end < best_end:
                best_t, best_end = tier, end
        assign[i] = best_t
        if best_t != ED:
            heapq.heapreplace(free[best_t], best_end)
    return assign


# ------------------------------------------------- Algorithm 2 (tabu search)
def neighborhood_search(jobs: Sequence[JobSpec],
                        initial: Sequence[str] | None = None,
                        max_count: int = 50,
                        objective: str = "weighted",
                        machines_per_tier: Mapping[str, int] | None = None,
                        busy_until: Mapping[str, Sequence[float]] | None
                        = None,
                        frozen: Sequence[bool] | None = None) -> Schedule:
    """Paper Algorithm 2. objective: "weighted" (eq. 5) | "unweighted".

    Each candidate move is scored incrementally (only the two affected
    machine queues are re-simulated), and the incumbent objective is
    re-derived from the committed state after every accepted move — no
    running ``best -= v_max`` accumulator, so no float drift over long
    searches.

    machines_per_tier / busy_until describe the fleet the schedule will
    actually run on (multi-server tiers, machines pre-occupied by committed
    jobs) — the searched objective IS the commit objective (DESIGN.md §7).
    frozen: jobs the search must never reassign (they still occupy their
    queues and count toward the objective — DESIGN.md §9 background jobs);
    requires an explicit ``initial`` carrying their pinned tiers.
    """
    if frozen is not None and any(frozen) and initial is None:
        raise ValueError("frozen jobs require an explicit initial "
                         "assignment carrying their pinned tiers")
    assign = list(initial or greedy_schedule(
        jobs, machines_per_tier=machines_per_tier, busy_until=busy_until))
    state = ScheduleState(jobs, assign, machines_per_tier=machines_per_tier,
                          busy_until=busy_until)
    best = state.score(objective)
    for _ in range(max_count):
        tabu_job = [bool(frozen[i]) if frozen is not None else False
                    for i in range(len(jobs))]
        improved_this_round = False
        for _inner in range(len(jobs)):
            # earliest-completing non-tabu job (paper line 15)
            cand = [i for i in range(len(jobs)) if not tabu_job[i]]
            if not cand:
                break
            k = min(cand, key=lambda i: state.end[i])
            tabu_job[k] = True
            # best move for job k across machines (paper lines 17-25)
            v_max, move = 0.0, None
            for tier in MACHINES:
                if tier == state.assign[k]:
                    continue
                v = best - state.try_move(k, tier, objective)
                if v > v_max:
                    v_max, move = v, tier
            if move is not None:
                state.apply_move(k, move)
                best = state.score(objective)
                improved_this_round = True
        if not improved_this_round:
            break
    return state.to_schedule()


def neighborhood_search_reference(jobs: Sequence[JobSpec],
                                  initial: Sequence[str] | None = None,
                                  max_count: int = 50,
                                  objective: str = "weighted") -> Schedule:
    """The seed implementation of Algorithm 2, kept verbatim as a benchmark
    baseline and parity oracle: every candidate move re-runs the full
    discrete-event simulation, and the incumbent objective is tracked by a
    running ``best -= v_max`` accumulator (which drifts on non-integer
    instances — fixed in `neighborhood_search`). O(rounds * n^2 * |tiers|)
    complete simulations; use only at small n."""
    assign = list(initial or greedy_schedule(jobs))

    def score(a: Sequence[str]) -> float:
        s = simulate(jobs, a)
        return s.weighted_sum if objective == "weighted" else s.unweighted_sum

    best = score(assign)
    for _ in range(max_count):
        tabu_job = [False] * len(jobs)
        improved_this_round = False
        for _inner in range(len(jobs)):
            sched = simulate(jobs, assign)
            ends = {id(e.job): e.end for e in sched.entries}
            cand = [i for i in range(len(jobs)) if not tabu_job[i]]
            if not cand:
                break
            k = min(cand, key=lambda i: ends[id(jobs[i])])
            tabu_job[k] = True
            v_max, move = 0.0, None
            for tier in MACHINES:
                if tier == assign[k]:
                    continue
                trial = list(assign)
                trial[k] = tier
                v = best - score(trial)
                if v > v_max:
                    v_max, move = v, tier
            if move is not None:
                assign[k] = move
                best -= v_max
                improved_this_round = True
        if not improved_this_round:
            break
    return simulate(jobs, assign)


# ------------------------------------------------------------- fast dispatch
def search(jobs: Sequence[JobSpec],
           initial: Sequence[str] | None = None,
           max_count: int = 50,
           objective: str = "weighted",
           jax_threshold: int | None = None,
           machines_per_tier: Mapping[str, int] | None = None,
           busy_until: Mapping[str, Sequence[float]] | None = None,
           frozen: Sequence[bool] | None = None) -> Schedule:
    """Size-dispatched Algorithm 2: the incremental Python tabu search for
    small instances, the fully jitted JAX neighbourhood search (one
    vmapped n x 3 neighbourhood evaluation per round inside lax.while_loop,
    no host syncs) for large ones. Both return an exact C1-C5 Schedule.

    jax_threshold: job count above which the JAX path is taken. Default
    (None): JAX_SEARCH_THRESHOLD when an accelerator backend is present,
    never on CPU. Since the delta-evaluation rewrite the jitted search
    wins on CPU too once compiled (n=100 and n=1000 both, DESIGN.md
    §3.3), but each new (instance size, fleet) shape pays a multi-second
    XLA compile — replanning loops see a different size at every event,
    so the Python path stays the CPU default. Pass an explicit threshold
    to force the JAX path where shapes repeat (benchmarks, serving, TPU
    deployments); fleet planning over many wards should use
    `search_batched`, which amortises one compile across the batch.

    machines_per_tier / busy_until (DESIGN.md §7) and frozen
    (DESIGN.md §9: immovable background jobs, initial required) are
    threaded through whichever backend runs, so both search the problem
    the schedule will actually be committed against.

    Compiled-shape fast path: a CPU call whose (n, fleet, objective)
    shape some earlier call already compiled (`_COMPILED_SHAPES`)
    dispatches to JAX even below the threshold — the compile is sunk, and
    once compiled the jitted search wins on CPU too (DESIGN.md §3.3).
    """
    n = len(jobs)
    mpt = dict(machines_per_tier or {})
    mpt_jax = (int(mpt.get(CC, 1)), int(mpt.get(ES, 1)))
    shape = (n, mpt_jax, objective)
    if jax_threshold is None:
        use_jax = (n > JAX_SEARCH_THRESHOLD and _accelerator_backend()) \
            or shape in _COMPILED_SHAPES
    else:
        use_jax = n > jax_threshold
    if not use_jax:
        return neighborhood_search(jobs, initial=initial,
                                   max_count=max_count, objective=objective,
                                   machines_per_tier=machines_per_tier,
                                   busy_until=busy_until, frozen=frozen)
    from repro.core import scheduler_jax   # lazy: keep jax off small paths
    if frozen is not None and any(frozen) and initial is None:
        raise ValueError("frozen jobs require an explicit initial "
                         "assignment carrying their pinned tiers")
    assign0 = initial or greedy_schedule(
        jobs, machines_per_tier=machines_per_tier, busy_until=busy_until)
    busy_jax = tuple(machine_free_times(busy_until, t, m)
                     for t, m in zip((CC, ES), mpt_jax))
    _, best_a = scheduler_jax.tabu_search_jax(
        jobs, initial=[MACHINES.index(t) for t in assign0],
        max_rounds=max(max_count, 1) * len(jobs), objective=objective,
        machines_per_tier=mpt_jax, busy_until=busy_jax,
        frozen=None if frozen is None else list(frozen))
    _COMPILED_SHAPES.add(shape)
    return simulate(jobs, [MACHINES[int(m)] for m in best_a],
                    machines_per_tier=machines_per_tier,
                    busy_until=busy_until)


def search_batched(problems: Sequence[Sequence[JobSpec]],
                   max_count: int = 50,
                   objective: str = "weighted",
                   machines_per_tier=None,
                   busy_until=None,
                   min_batch: int | None = None,
                   jax_threshold: int | None = None,
                   initial: Sequence[Sequence[str]] | None = None,
                   frozen: Sequence[Sequence[bool] | None] | None = None
                   ) -> List[Schedule]:
    """Plan B independent ward instances, one jitted device call
    (DESIGN.md §8) — the fleet-scale entry point used by
    `launch/serve.py --wards` and the batched clairvoyant baselines in
    `core/online.py`.

    problems: B job lists (sizes may differ — padded on the batched
    path with phantom jobs that contribute exactly 0 to every
    objective). machines_per_tier: one {tier: count} mapping for every
    ward or a per-ward sequence of mappings; busy_until: optional
    per-ward {tier: [free times]} sequence. min_batch: batches smaller
    than this loop the per-instance `search` instead (default
    BATCHED_SEARCH_MIN_WARDS — tiny fleets don't amortise a device
    dispatch); pass 1 to force the batched path, a large value to force
    the sequential loop. jax_threshold is forwarded to the sequential
    fallback's per-instance `search` calls, so small batches dispatch to
    the same backend their caller asked large ones to use (§3.3).

    initial / frozen (DESIGN.md §9): optional per-ward warm-start tier
    lists and immovable-background masks, forwarded to whichever backend
    runs (frozen jobs require initial, as everywhere else). The metro
    engine's multi-ward replans ride through here so one event's replans
    batch into one device call (DESIGN.md §10).

    Every returned Schedule is a final exact `simulate` of its ward's
    best assignment against that ward's own fleet, so reported numbers
    are the reference evaluator's bit-for-bit (§3.1 invariant)."""
    B = len(problems)
    single = isinstance(machines_per_tier, Mapping) or machines_per_tier \
        is None
    mpts = [machines_per_tier] * B if single else list(machines_per_tier)
    busys = [None] * B if busy_until is None else list(busy_until)
    inits = [None] * B if initial is None else list(initial)
    frozens = [None] * B if frozen is None else list(frozen)
    if len(mpts) != B or len(busys) != B or len(inits) != B \
            or len(frozens) != B:
        raise ValueError(f"{len(mpts)} fleets / {len(busys)} busy vectors "
                         f"/ {len(inits)} initials / {len(frozens)} frozen "
                         f"masks for {B} wards")
    threshold = BATCHED_SEARCH_MIN_WARDS if min_batch is None else min_batch
    if B < threshold:
        return [search(jobs, max_count=max_count, objective=objective,
                       jax_threshold=jax_threshold, initial=init,
                       frozen=fr, machines_per_tier=m, busy_until=b)
                for jobs, m, b, init, fr
                in zip(problems, mpts, busys, inits, frozens)]
    from repro.core import scheduler_jax   # lazy: keep jax off small paths
    if initial is None and frozen is not None \
            and any(fr is not None and any(fr) for fr in frozens):
        raise ValueError("frozen jobs require an explicit initial "
                         "assignment carrying their pinned tiers")
    if initial is not None:
        # the batched backend needs an initial for every ward or none —
        # fill the gaps with the greedy initial the solo path would use,
        # so mixed-initial calls behave the same on both dispatch paths
        inits = [init if init is not None else greedy_schedule(
            jobs, machines_per_tier=m, busy_until=b)
            for jobs, m, b, init in zip(problems, mpts, busys, inits)]
    pairs = [(int(dict(m or {}).get(CC, 1)), int(dict(m or {}).get(ES, 1)))
             for m in mpts]
    busy_pairs = [tuple(machine_free_times(b, t, mm)
                        for t, mm in zip((CC, ES), pair))
                  for b, pair in zip(busys, pairs)]
    n_max = max((len(jobs) for jobs in problems), default=0)
    _, assigns = scheduler_jax.tabu_search_batched(
        problems,
        None if initial is None else
        [[MACHINES.index(t) for t in init] for init in inits],
        max_rounds=max(max_count, 1) * max(n_max, 1),
        objective=objective, machines_per_tier=pairs,
        busy_until=busy_pairs,
        frozen=None if frozen is None else frozens)
    return [simulate(jobs, [MACHINES[int(i)] for i in a],
                     machines_per_tier=m, busy_until=b)
            for jobs, a, m, b in zip(problems, assigns, mpts, busys)]


# --------------------------------------------- contention-aware fleet search
@dataclass(frozen=True)
class FleetPlan:
    """Result of `search_fleet` (DESIGN.md §9).

    naive_reported is the objective B independent per-ward searches CLAIM
    (each ward scored against the full shared pool as if it were alone) —
    unachievable whenever wards overlap on the shared cloud. naive_fleet
    rescores those same plans on the real fleet; the ratio between the two
    is the contention gap this subsystem closes."""
    assignments: List[List[str]]     # final joint plan, per ward
    fleet: FleetSchedule             # fleet-true evaluation of the plan
    naive_fleet: FleetSchedule       # fleet-true eval of independent plans
    naive_assignments: List[List[str]]
    naive_reported: float            # what independent planning claimed
    sweeps: int                      # fixed-point sweeps run
    objective: str

    @property
    def contention_gap(self) -> float:
        """fleet-true / claimed objective of the independent plans (> 1
        means the per-ward numbers double-book the shared cloud)."""
        return self.naive_fleet.objective(self.objective) / max(
            self.naive_reported, 1e-9)

    @property
    def gap_closed(self) -> float:
        """Fraction of the contention gap recovered by the fixed-point
        search (0 = none, 1 = the final plan scores what the independent
        plans claimed)."""
        naive = self.naive_fleet.objective(self.objective)
        excess = naive - self.naive_reported
        if excess <= 0:
            return 1.0
        return (naive - self.fleet.objective(self.objective)) / excess


def _fleet_views(ward_jobs, mpts, busy_until, ward_busy_until, shared_tiers):
    """Per-ward (machines, busy) dicts for INDEPENDENT planning: every
    ward sees the full shared pool (and its initial occupancy) as its own
    — exactly the double-booking view `search_fleet` starts from."""
    views = []
    for b in range(len(ward_jobs)):
        busy: Dict[str, Sequence[float]] = {}
        for tier in (CC, ES):
            if tier in shared_tiers:
                vals = (busy_until or {}).get(tier, ())
            else:
                wb = ward_busy_until[b] if ward_busy_until else None
                vals = (wb or {}).get(tier, ())
            vals = list(vals)
            if vals:
                busy[tier] = vals
        views.append((mpts[b], busy or None))
    return views


def search_fleet(ward_jobs: Sequence[Sequence[JobSpec]],
                 machines_per_tier=None, *,
                 objective: str = "weighted",
                 max_count: int = 50,
                 max_sweeps: int = 8,
                 sweep_max_count: int = 2,
                 busy_until: Mapping[str, Sequence[float]] | None = None,
                 ward_busy_until=None,
                 shared_tiers: Tuple[str, ...] = (CC,),
                 min_batch: int | None = None,
                 jax_threshold: int | None = None,
                 sweep_backend: str = "auto",
                 pad_bucket: int = 64) -> FleetPlan:
    """Contention-aware multi-ward planning to a fixed point (DESIGN.md §9).

    Starts from B independent per-ward plans (today's `search_batched`
    mode — each ward optimises against the full shared cloud, silently
    double-booking it), rescores them with the fleet-true evaluator
    `simulate_fleet`, then runs Gauss–Seidel sweeps: each sweep replans
    every ward in one `scheduler_jax.tabu_search_batched` call in which
    ward b's instance carries the OTHER wards' currently-committed
    shared-tier jobs as frozen background occupancy (immovable, but fully
    present in the merged-queue evaluation — so ward b pays, and sees, the
    delay it inflicts on the rest of the fleet). A ward's proposal is then
    accepted only if it strictly improves the fleet-true objective, so the
    incumbent value is monotone decreasing over a finite assignment space
    and the iteration terminates (§9 termination argument).

    machines_per_tier: one {tier: count} mapping for all wards or a
    per-ward sequence (shared-tier counts must agree — one pool).
    busy_until: initial free times of the SHARED pools; ward_busy_until:
    optional per-ward occupancy of the per-ward pools. sweep_max_count:
    tabu budget per replanning sweep (small — sweeps only need local
    repairs on top of the incumbent). pad_bucket: background job slots
    are padded to multiples of this so the batched search's compiled
    shape stays stable while the background churns across sweeps.

    sweep_backend — the §3.3 dispatch question again, at sweep scale:
    "batched" replans all wards in one `tabu_search_batched` device call
    per sweep; "python" loops the incremental per-ward `search`. "auto"
    (default) picks batched only on an accelerator backend (and B >=
    min_batch): an augmented instance is dominated by FROZEN background
    jobs, whose all-n toggle stats the delta-evaluated kernel computes
    anyway (O(n_aug^2) per ward) while the Python path only ever tries
    the ~n_b movable jobs against two queues — measured 16x faster on a
    2-core CPU at B=32, n=100 (~1500 background). On TPU the batched
    call amortises one dispatch across the fleet, as in §8.

    Returns a FleetPlan carrying the final joint plan, both fleet-true
    evaluations, the claimed (double-booked) objective, and the sweep
    count.
    """
    B = len(ward_jobs)
    if B == 0:
        empty = simulate_fleet([], [], shared_tiers=shared_tiers)
        return FleetPlan([], empty, empty, [], 0.0, 0, objective)
    mpts = _fleet_mpts(machines_per_tier, B, shared_tiers)
    views = _fleet_views(ward_jobs, mpts, busy_until, ward_busy_until,
                         shared_tiers)

    def fleet_eval(assignments) -> FleetSchedule:
        return simulate_fleet(ward_jobs, assignments,
                              machines_per_tier=mpts,
                              busy_until=busy_until,
                              ward_busy_until=ward_busy_until,
                              shared_tiers=shared_tiers)

    # 1) independent (double-booked) plans — the naive baseline
    naive = search_batched(list(ward_jobs), max_count=max_count,
                           objective=objective,
                           machines_per_tier=[v[0] for v in views],
                           busy_until=[v[1] for v in views],
                           min_batch=min_batch, jax_threshold=jax_threshold)
    naive_assignments = [s.assignment() for s in naive]
    agg = max if objective == "last" else sum
    naive_reported = float(agg(s.objective(objective) for s in naive))
    naive_fleet = fleet_eval(naive_assignments)

    incumbent = [list(a) for a in naive_assignments]
    best_fleet = naive_fleet
    best = best_fleet.objective(objective)
    threshold = BATCHED_SEARCH_MIN_WARDS if min_batch is None else min_batch
    if sweep_backend not in ("auto", "batched", "python"):
        raise ValueError(f"unknown sweep_backend {sweep_backend!r}")
    batched_sweeps = sweep_backend == "batched" or (
        sweep_backend == "auto" and B >= threshold
        and _accelerator_backend())

    sweeps = 0
    pad_to = 0          # sticky across sweeps: one compile for the run
    for _ in range(max_sweeps):
        # background of ward b: every other ward's shared-tier jobs,
        # pinned at their committed tier (frozen, but queue-active)
        bg = [[(ward_jobs[c][i], incumbent[c][i])
               for c in range(B) if c != b
               for i in range(len(ward_jobs[c]))
               if incumbent[c][i] in shared_tiers]
              for b in range(B)]
        aug_jobs = [list(ward_jobs[b]) + [j for j, _ in bg[b]]
                    for b in range(B)]
        aug_init = [incumbent[b] + [t for _, t in bg[b]]
                    for b in range(B)]
        frozen = [[False] * len(ward_jobs[b]) + [True] * len(bg[b])
                  for b in range(B)]
        proposals: List[List[str]] = []
        if not batched_sweeps:
            for b in range(B):
                plan = search(aug_jobs[b], initial=aug_init[b],
                              max_count=sweep_max_count,
                              objective=objective, frozen=frozen[b],
                              jax_threshold=jax_threshold,
                              machines_per_tier=views[b][0],
                              busy_until=views[b][1])
                proposals.append(plan.assignment()[:len(ward_jobs[b])])
        else:
            from repro.core import scheduler_jax
            pairs = [(int(views[b][0].get(CC, 1)),
                      int(views[b][0].get(ES, 1))) for b in range(B)]
            busy_pairs = [tuple(machine_free_times(views[b][1], t, m)
                                for t, m in zip((CC, ES), pairs[b]))
                          for b in range(B)]
            # bucket the padded size and keep it STICKY across sweeps:
            # the background shrinks as wards move off the shared cloud,
            # and re-bucketing downward would retrace the jitted search
            # every sweep (XLA compile dwarfs the sweep itself)
            n_aug = max(len(jobs) for jobs in aug_jobs)
            pad_to = max(pad_to, -(-n_aug // pad_bucket) * pad_bucket)
            _, assigns = scheduler_jax.tabu_search_batched(
                aug_jobs,
                [[MACHINES.index(t) for t in init] for init in aug_init],
                max_rounds=max(sweep_max_count, 1) * pad_to,
                objective=objective, machines_per_tier=pairs,
                busy_until=busy_pairs, frozen=frozen, pad_to=pad_to)
            proposals = [[MACHINES[int(i)]
                          for i in assigns[b][:len(ward_jobs[b])]]
                         for b in range(B)]
        sweeps += 1
        # Gauss–Seidel acceptance: commit each ward's proposal only if it
        # strictly improves the FLEET-TRUE objective given everything
        # already committed this sweep — monotone, hence terminating
        improved = False
        for b in range(B):
            if proposals[b] == incumbent[b]:
                continue
            trial = list(incumbent)
            trial[b] = proposals[b]
            fs = fleet_eval(trial)
            v = fs.objective(objective)
            if v < best - 1e-9:
                incumbent, best_fleet, best = trial, fs, v
                improved = True
        if not improved:
            break

    return FleetPlan(assignments=[list(a) for a in incumbent],
                     fleet=best_fleet, naive_fleet=naive_fleet,
                     naive_assignments=naive_assignments,
                     naive_reported=naive_reported,
                     sweeps=sweeps, objective=objective)


def _accelerator_backend() -> bool:
    try:
        import jax
        return jax.default_backend() not in ("cpu",)
    except Exception:                                       # pragma: no cover
        return False


# ------------------------------------------------------------- exact optimum
def exact_optimum(jobs: Sequence[JobSpec],
                  objective: str = "weighted",
                  machines_per_tier: Mapping[str, int] | None = None,
                  busy_until: Mapping[str, Sequence[float]] | None = None
                  ) -> Schedule:
    """Brute-force over all 3^n assignments (n <= ~12). The paper offers no
    optimality baseline; we use this to report the heuristic's gap."""
    n = len(jobs)
    if n > 12:
        # ValueError, not assert: a 3^n enumeration bomb must be refused
        # under ``python -O`` too
        raise ValueError(f"exact_optimum is 3^n; n={n} > 12 — use "
                         f"scheduler_jax.exact_optimum_jax for larger n")
    best_s, best_v = None, float("inf")
    for combo in itertools.product(MACHINES, repeat=n):
        s = simulate(jobs, combo, machines_per_tier=machines_per_tier,
                     busy_until=busy_until)
        v = s.weighted_sum if objective == "weighted" else s.unweighted_sum
        if v < best_v:
            best_s, best_v = s, v
    return best_s


# -------------------------------------------------------------- comparison
def strategy_table(jobs: Sequence[JobSpec],
                   jax_threshold: int | None = None,
                   machines_per_tier: Mapping[str, int] | None = None
                   ) -> Dict[str, Schedule]:
    """The paper's Table VII comparison set + our extras. "ours" goes
    through the size-dispatched `search`, so fleet-scale tables use the
    jitted path. machines_per_tier (from TierSpec.machines) sizes the
    shared tiers for every strategy."""
    mpt = machines_per_tier
    return {
        "ours (algorithm 2)": search(jobs, jax_threshold=jax_threshold,
                                     machines_per_tier=mpt),
        "per-job optimal layer": per_job_optimal(jobs, machines_per_tier=mpt),
        "all cloud": all_on_tier(jobs, CC, machines_per_tier=mpt),
        "all edge": all_on_tier(jobs, ES, machines_per_tier=mpt),
        "all device": all_on_tier(jobs, ED, machines_per_tier=mpt),
    }
