"""Algorithm 2 — multi-job allocation heuristic (paper Section VI).

Pipeline:
  1. greedy initial solution: jobs in release order (tie: priority desc),
     each assigned to the machine minimising its completion time given the
     machine free-times so far ("the earliest released job gets the
     shortest response time");
  2. tabu-guarded neighbourhood search: repeatedly pick the
     earliest-completing non-tabu job, try moving it to every other
     machine, keep the move with the largest positive reduction of the
     weighted whole response time (paper lines 10-28);
  3. every candidate is scored with the incremental evaluator
     (simulator.ScheduleState) whose per-move cost is O(two machine
     queues); the returned Schedule is always a final exact re-simulation,
     so reported numbers always reflect C1-C5 semantics.

`search` dispatches between this Python path (small n) and the fully
jitted JAX neighbourhood search (scheduler_jax.tabu_search_jax) above
JAX_SEARCH_THRESHOLD jobs — see DESIGN.md §3.3 for the policy.

Also provides baseline strategies (Table VII comparison set), an exact
brute-force optimum for small n (the paper has none — we add it to measure
the heuristic's optimality gap), and `neighborhood_search_reference`, the
seed full-re-simulation implementation kept as a benchmark baseline and
parity oracle.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Dict, List, Mapping, Sequence, Tuple

from repro.core.simulator import (MACHINES, FleetSchedule, JobSpec,
                                  Reservation, Schedule, ScheduleState,
                                  _fleet_mpts, machine_free_times, simulate,
                                  simulate_fleet)
from repro.core.tiers import CC, ED, ES

# above this many jobs, `search` uses the jitted JAX neighbourhood search
JAX_SEARCH_THRESHOLD = 64

# batches at least this large dispatch to the single-call batched JAX
# search (DESIGN.md §8); smaller ones loop the per-instance `search`
BATCHED_SEARCH_MIN_WARDS = 4

# BUCKETED (rows, movable, fleet, objective) shapes the jitted solo
# search has already compiled IN THIS PROCESS. On CPU the delta-evaluated
# kernel beats the incremental Python path once compiled (DESIGN.md
# §3.3), but a fresh XLA trace costs seconds — so `search` only
# dispatches a CPU call to JAX when its shape is in here, i.e. when some
# earlier call (benchmark warm-up, explicit jax_threshold, TPU run)
# already paid the compile. Replanning loops with repeating shapes (the
# metro engine) then ride the compiled kernel for free.
#
# The key buckets both the padded row count (jobs + reservations) and
# the movable count up to multiples of 16 (DESIGN.md §12) — the same
# padding the kernel itself applies — so metro load, where the movable
# count drifts at every event, maps to a handful of compiled shapes
# instead of one per event. The cache is CAPPED: a miss at the cap
# clears it AND the underlying jit caches, so a pathological shape
# churn degrades to retracing instead of unbounded compiled-program
# growth. `compiled_shape_stats()` surfaces hit/miss/eviction counters
# (recorded by benchmarks/scheduler_scale.py) so retrace regressions
# under metro load are visible, not just slow.
#
# Note the trade this makes explicit: the two backends are both exact
# C1-C5 searches but follow different trajectories (paired moves, §8),
# so they can return DIFFERENT valid local optima — a cache hit changes
# which one a later same-shape call gets. `search` results are therefore
# deterministic per (inputs, dispatch state), not per inputs alone;
# callers that need call-order-independent output pin the backend with
# an explicit jax_threshold. The committed benchmarks run each section
# in a fixed order in a fresh process, so their numbers are stable.
_COMPILED_SHAPES: set = set()
_COMPILED_SHAPES_CAP = 64
_SHAPE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def _bucket16(x: int) -> int:
    """§12 bucketing contract: kernel sizes round up to multiples of 16
    (minimum 16), matching scheduler_jax's movable-slot padding."""
    return ((max(int(x), 1) + 15) // 16) * 16


def _batched_shape(B, rows, n_mov, pairs, objective):
    """Bucketed cache key for one BATCHED kernel dispatch. Tagged with a
    leading "batched" so it can never satisfy the solo fast-path lookup
    (a batched compile at B=32 does not warm the B=1 solo kernel)."""
    return ("batched", B, rows, min(rows, _bucket16(n_mov)),
            (max(c for c, _ in pairs), max(e for _, e in pairs)), objective)


def compiled_shape_stats() -> Dict[str, int]:
    """Dispatch cache counters: {size, hits, misses, evictions}, covering
    solo fast-path dispatches and batched kernel calls alike.

    A healthy metro run shows misses plateauing after warm-up while hits
    keep climbing; rising misses (or any eviction) under steady load
    means the bucketing no longer covers the traffic's shape churn."""
    return {"size": len(_COMPILED_SHAPES), **_SHAPE_STATS}


def _note_shape(shape) -> None:
    """Record one JAX dispatch of `shape` — a solo fast-path key or a
    `_batched_shape` key (hit or miss); on a miss
    at the cap, drop every compiled shape — ours and jit's — rather than
    let compiled programs accumulate without bound."""
    if shape in _COMPILED_SHAPES:
        _SHAPE_STATS["hits"] += 1
        return
    _SHAPE_STATS["misses"] += 1
    if len(_COMPILED_SHAPES) >= _COMPILED_SHAPES_CAP:
        _COMPILED_SHAPES.clear()
        _SHAPE_STATS["evictions"] += 1
        try:                                            # pragma: no cover
            import jax
            jax.clear_caches()
        except Exception:
            pass
    _COMPILED_SHAPES.add(shape)


# --------------------------------------------------------------- strategies
def all_on_tier(jobs: Sequence[JobSpec], tier: str,
                machines_per_tier: Mapping[str, int] | None = None
                ) -> Schedule:
    return simulate(jobs, [tier] * len(jobs),
                    machines_per_tier=machines_per_tier)


def per_job_optimal(jobs: Sequence[JobSpec],
                    machines_per_tier: Mapping[str, int] | None = None
                    ) -> Schedule:
    """Table VII row 2: each job on its own Algorithm-1-optimal tier,
    ignoring queueing."""
    assign = [min(MACHINES, key=lambda t: j.response_if_alone(t))
              for j in jobs]
    return simulate(jobs, assign, machines_per_tier=machines_per_tier)


# ------------------------------------------------------------------ greedy
def greedy_schedule(jobs: Sequence[JobSpec],
                    machines_per_tier: Mapping[str, int] | None = None,
                    busy_until: Mapping[str, Sequence[float]] | None = None
                    ) -> List[str]:
    """Initial feasible solution (Algorithm 2 step 1).

    Honors multi-server tiers (earliest-free machine per tier) and
    machines already busy at the start (``busy_until``, DESIGN.md §7) —
    the same greedy rule online scheduling commits on each arrival.
    """
    mpt = dict(machines_per_tier or {CC: 1, ES: 1})
    order = sorted(range(len(jobs)),
                   key=lambda i: (jobs[i].release, -jobs[i].weight, i))
    free = {t: machine_free_times(busy_until, t, mpt.get(t, 1))
            for t in (CC, ES)}
    for heap in free.values():
        heapq.heapify(heap)
    assign: List[str] = [""] * len(jobs)
    for i in order:
        job = jobs[i]
        best_t, best_end = None, float("inf")
        for tier in (ED, ES, CC):    # tie -> prefer lower tier
            arr = job.release + job.trans.get(tier, 0.0)
            start = arr if tier == ED else max(arr, free[tier][0])
            end = start + job.proc[tier]
            if end < best_end:
                best_t, best_end = tier, end
        assign[i] = best_t
        if best_t != ED:
            heapq.heapreplace(free[best_t], best_end)
    return assign


# ------------------------------------------------- Algorithm 2 (tabu search)
def neighborhood_search(jobs: Sequence[JobSpec],
                        initial: Sequence[str] | None = None,
                        max_count: int = 50,
                        objective: str = "weighted",
                        machines_per_tier: Mapping[str, int] | None = None,
                        busy_until: Mapping[str, Sequence[float]] | None
                        = None,
                        frozen: Sequence[bool] | None = None,
                        reserved: Mapping[str, Sequence[Reservation]] | None
                        = None) -> Schedule:
    """Paper Algorithm 2. objective: "weighted" (eq. 5) | "unweighted".

    Each candidate move is scored incrementally (only the two affected
    machine queues are re-simulated), and the incumbent objective is
    re-derived from the committed state after every accepted move — no
    running ``best -= v_max`` accumulator, so no float drift over long
    searches.

    machines_per_tier / busy_until describe the fleet the schedule will
    actually run on (multi-server tiers, machines pre-occupied by committed
    jobs) — the searched objective IS the commit objective (DESIGN.md §7).
    frozen: jobs the search must never reassign (they still occupy their
    queues and count toward the objective — DESIGN.md §9 background jobs);
    requires an explicit ``initial`` carrying their pinned tiers.
    reserved: {tier: [Reservation]} committed background occupancy merged
    into the shared queues (DESIGN.md §12) — queue-active and scored like
    frozen jobs, but never a move candidate, so a mostly-background
    instance searches only its own jobs. Requires an explicit ``initial``
    (the greedy initialiser ignores reservation occupancy).
    """
    if frozen is not None and any(frozen) and initial is None:
        raise ValueError("frozen jobs require an explicit initial "
                         "assignment carrying their pinned tiers")
    if reserved and any(reserved.values()) and initial is None:
        raise ValueError("reservations require an explicit initial "
                         "assignment (greedy init ignores their occupancy)")
    assign = list(initial or greedy_schedule(
        jobs, machines_per_tier=machines_per_tier, busy_until=busy_until))
    state = ScheduleState(jobs, assign, machines_per_tier=machines_per_tier,
                          busy_until=busy_until, reserved=reserved)
    best = state.score(objective)
    for _ in range(max_count):
        tabu_job = [bool(frozen[i]) if frozen is not None else False
                    for i in range(len(jobs))]
        improved_this_round = False
        for _inner in range(len(jobs)):
            # earliest-completing non-tabu job (paper line 15)
            cand = [i for i in range(len(jobs)) if not tabu_job[i]]
            if not cand:
                break
            k = min(cand, key=lambda i: state.end[i])
            tabu_job[k] = True
            # best move for job k across machines (paper lines 17-25)
            v_max, move = 0.0, None
            for tier in MACHINES:
                if tier == state.assign[k]:
                    continue
                v = best - state.try_move(k, tier, objective)
                if v > v_max:
                    v_max, move = v, tier
            if move is not None:
                state.apply_move(k, move)
                best = state.score(objective)
                improved_this_round = True
        if not improved_this_round:
            break
    return state.to_schedule()


def neighborhood_search_reference(jobs: Sequence[JobSpec],
                                  initial: Sequence[str] | None = None,
                                  max_count: int = 50,
                                  objective: str = "weighted") -> Schedule:
    """The seed implementation of Algorithm 2, kept verbatim as a benchmark
    baseline and parity oracle: every candidate move re-runs the full
    discrete-event simulation, and the incumbent objective is tracked by a
    running ``best -= v_max`` accumulator (which drifts on non-integer
    instances — fixed in `neighborhood_search`). O(rounds * n^2 * |tiers|)
    complete simulations; use only at small n."""
    assign = list(initial or greedy_schedule(jobs))

    def score(a: Sequence[str]) -> float:
        s = simulate(jobs, a)
        return s.weighted_sum if objective == "weighted" else s.unweighted_sum

    best = score(assign)
    for _ in range(max_count):
        tabu_job = [False] * len(jobs)
        improved_this_round = False
        for _inner in range(len(jobs)):
            sched = simulate(jobs, assign)
            ends = {id(e.job): e.end for e in sched.entries}
            cand = [i for i in range(len(jobs)) if not tabu_job[i]]
            if not cand:
                break
            k = min(cand, key=lambda i: ends[id(jobs[i])])
            tabu_job[k] = True
            v_max, move = 0.0, None
            for tier in MACHINES:
                if tier == assign[k]:
                    continue
                trial = list(assign)
                trial[k] = tier
                v = best - score(trial)
                if v > v_max:
                    v_max, move = v, tier
            if move is not None:
                assign[k] = move
                best -= v_max
                improved_this_round = True
        if not improved_this_round:
            break
    return simulate(jobs, assign)


# ------------------------------------------------------------- fast dispatch
def search(jobs: Sequence[JobSpec],
           initial: Sequence[str] | None = None,
           max_count: int = 50,
           objective: str = "weighted",
           jax_threshold: int | None = None,
           machines_per_tier: Mapping[str, int] | None = None,
           busy_until: Mapping[str, Sequence[float]] | None = None,
           frozen: Sequence[bool] | None = None,
           reserved: Mapping[str, Sequence[Reservation]] | None = None
           ) -> Schedule:
    """Size-dispatched Algorithm 2: the incremental Python tabu search for
    small instances, the fully jitted JAX neighbourhood search (one
    vmapped n x 3 neighbourhood evaluation per round inside lax.while_loop,
    no host syncs) for large ones. Both return an exact C1-C5 Schedule.

    jax_threshold: job count above which the JAX path is taken. Default
    (None): JAX_SEARCH_THRESHOLD when an accelerator backend is present,
    never on CPU. Since the delta-evaluation rewrite the jitted search
    wins on CPU too once compiled (n=100 and n=1000 both, DESIGN.md
    §3.3), but each new (instance size, fleet) shape pays a multi-second
    XLA compile — replanning loops see a different size at every event,
    so the Python path stays the CPU default. Pass an explicit threshold
    to force the JAX path where shapes repeat (benchmarks, serving, TPU
    deployments); fleet planning over many wards should use
    `search_batched`, which amortises one compile across the batch.

    machines_per_tier / busy_until (DESIGN.md §7), frozen (DESIGN.md §9:
    immovable background jobs, initial required) and reserved
    (DESIGN.md §12: committed interval occupancy, initial required) are
    threaded through whichever backend runs, so both search the problem
    the schedule will actually be committed against.

    Compiled-shape fast path: a CPU call whose BUCKETED (rows, movable,
    fleet, objective) shape some earlier call already compiled
    (`_COMPILED_SHAPES`) dispatches to JAX even below the threshold —
    the compile is sunk, and once compiled the jitted search wins on CPU
    too (DESIGN.md §3.3). The JAX call pads its instance to the bucketed
    row count (§12), so every call whose sizes land in one bucket hits
    ONE compiled kernel — under metro load the movable count drifts at
    every event, and without the bucketing each drift would be a fresh
    multi-second trace.
    """
    n = len(jobs)
    mpt = dict(machines_per_tier or {})
    mpt_jax = (int(mpt.get(CC, 1)), int(mpt.get(ES, 1)))
    n_res = sum(len(v) for v in (reserved or {}).values())
    n_mov = n - (sum(map(bool, frozen)) if frozen is not None else 0)
    rows = _bucket16(n + n_res)
    shape = (rows, min(rows, _bucket16(n_mov)), mpt_jax, objective)
    if jax_threshold is None:
        use_jax = (n > JAX_SEARCH_THRESHOLD and _accelerator_backend()) \
            or shape in _COMPILED_SHAPES
    else:
        use_jax = n > jax_threshold
    if not use_jax:
        return neighborhood_search(jobs, initial=initial,
                                   max_count=max_count, objective=objective,
                                   machines_per_tier=machines_per_tier,
                                   busy_until=busy_until, frozen=frozen,
                                   reserved=reserved)
    from repro.core import scheduler_jax   # lazy: keep jax off small paths
    if frozen is not None and any(frozen) and initial is None:
        raise ValueError("frozen jobs require an explicit initial "
                         "assignment carrying their pinned tiers")
    if n_res and initial is None:
        raise ValueError("reservations require an explicit initial "
                         "assignment (greedy init ignores their occupancy)")
    assign0 = initial or greedy_schedule(
        jobs, machines_per_tier=machines_per_tier, busy_until=busy_until)
    busy_jax = tuple(machine_free_times(busy_until, t, m)
                     for t, m in zip((CC, ES), mpt_jax))
    _, assigns = scheduler_jax.tabu_search_batched(
        [jobs], [[MACHINES.index(t) for t in assign0]],
        max_rounds=max(max_count, 1), objective=objective,
        machines_per_tier=[mpt_jax], busy_until=[busy_jax],
        frozen=None if frozen is None else [list(frozen)],
        reserved=None if reserved is None else [reserved],
        pad_to=rows)
    _note_shape(shape)
    return simulate(jobs, [MACHINES[int(m)] for m in assigns[0]],
                    machines_per_tier=machines_per_tier,
                    busy_until=busy_until, reserved=reserved)


def search_batched(problems: Sequence[Sequence[JobSpec]],
                   max_count: int = 50,
                   objective: str = "weighted",
                   machines_per_tier=None,
                   busy_until=None,
                   min_batch: int | None = None,
                   jax_threshold: int | None = None,
                   initial: Sequence[Sequence[str]] | None = None,
                   frozen: Sequence[Sequence[bool] | None] | None = None,
                   reserved=None) -> List[Schedule]:
    """Plan B independent ward instances, one jitted device call
    (DESIGN.md §8) — the fleet-scale entry point used by
    `launch/serve.py --wards` and the batched clairvoyant baselines in
    `core/online.py`.

    problems: B job lists (sizes may differ — padded on the batched
    path with phantom jobs that contribute exactly 0 to every
    objective). machines_per_tier: one {tier: count} mapping for every
    ward or a per-ward sequence of mappings; busy_until: optional
    per-ward {tier: [free times]} sequence. min_batch: batches smaller
    than this loop the per-instance `search` instead (default
    BATCHED_SEARCH_MIN_WARDS — tiny fleets don't amortise a device
    dispatch); pass 1 to force the batched path, a large value to force
    the sequential loop. jax_threshold is forwarded to the sequential
    fallback's per-instance `search` calls, so small batches dispatch to
    the same backend their caller asked large ones to use (§3.3).

    initial / frozen (DESIGN.md §9): optional per-ward warm-start tier
    lists and immovable-background masks, forwarded to whichever backend
    runs (frozen jobs require initial, as everywhere else). The metro
    engine's multi-ward replans ride through here so one event's replans
    batch into one device call (DESIGN.md §10).

    reserved (DESIGN.md §12): optional per-ward {tier: [Reservation]}
    maps of committed interval occupancy, forwarded to whichever backend
    runs; a ward with reservations needs an explicit initial. Returned
    objectives include reservation contributions.

    Every returned Schedule is a final exact `simulate` of its ward's
    best assignment against that ward's own fleet, so reported numbers
    are the reference evaluator's bit-for-bit (§3.1 invariant)."""
    B = len(problems)
    single = isinstance(machines_per_tier, Mapping) or machines_per_tier \
        is None
    mpts = [machines_per_tier] * B if single else list(machines_per_tier)
    busys = [None] * B if busy_until is None else list(busy_until)
    inits = [None] * B if initial is None else list(initial)
    frozens = [None] * B if frozen is None else list(frozen)
    reserveds = [None] * B if reserved is None else list(reserved)
    if len(mpts) != B or len(busys) != B or len(inits) != B \
            or len(frozens) != B or len(reserveds) != B:
        raise ValueError(f"{len(mpts)} fleets / {len(busys)} busy vectors "
                         f"/ {len(inits)} initials / {len(frozens)} frozen "
                         f"masks / {len(reserveds)} reservation maps "
                         f"for {B} wards")
    bad = [i for i, (rv, init) in enumerate(zip(reserveds, inits))
           if rv and any(rv.values()) and init is None]
    if bad:
        raise ValueError(f"reservations require an explicit initial "
                         f"assignment (greedy init ignores their "
                         f"occupancy); missing for wards {bad}")
    threshold = BATCHED_SEARCH_MIN_WARDS if min_batch is None else min_batch
    if B < threshold:
        return [search(jobs, max_count=max_count, objective=objective,
                       jax_threshold=jax_threshold, initial=init,
                       frozen=fr, reserved=rv, machines_per_tier=m,
                       busy_until=b)
                for jobs, m, b, init, fr, rv
                in zip(problems, mpts, busys, inits, frozens, reserveds)]
    from repro.core import scheduler_jax   # lazy: keep jax off small paths
    if initial is None and frozen is not None \
            and any(fr is not None and any(fr) for fr in frozens):
        raise ValueError("frozen jobs require an explicit initial "
                         "assignment carrying their pinned tiers")
    if initial is not None:
        # the batched backend needs an initial for every ward or none —
        # fill the gaps with the greedy initial the solo path would use,
        # so mixed-initial calls behave the same on both dispatch paths
        inits = [init if init is not None else greedy_schedule(
            jobs, machines_per_tier=m, busy_until=b)
            for jobs, m, b, init in zip(problems, mpts, busys, inits)]
    pairs = [(int(dict(m or {}).get(CC, 1)), int(dict(m or {}).get(ES, 1)))
             for m in mpts]
    busy_pairs = [tuple(machine_free_times(b, t, mm)
                        for t, mm in zip((CC, ES), pair))
                  for b, pair in zip(busys, pairs)]
    # bucket the padded row count (§12) so metro multi-ward replans with
    # drifting sizes land on a handful of compiled shapes, and record the
    # dispatch so `compiled_shape_stats` sees batched traffic too
    raw_rows = max((len(jobs) + sum(len(v) for v in (rv or {}).values())
                    for jobs, rv in zip(problems, reserveds)), default=0)
    rows = _bucket16(raw_rows) if raw_rows else None
    _, assigns = scheduler_jax.tabu_search_batched(
        problems,
        None if initial is None else
        [[MACHINES.index(t) for t in init] for init in inits],
        max_rounds=max(max_count, 1),
        objective=objective, machines_per_tier=pairs,
        busy_until=busy_pairs,
        frozen=None if frozen is None else frozens,
        reserved=None if reserved is None else reserveds,
        pad_to=rows)
    if raw_rows:
        n_mov = max(len(jobs) - (sum(map(bool, fr)) if fr is not None
                                 else 0)
                    for jobs, fr in zip(problems, frozens))
        _note_shape(_batched_shape(B, rows, n_mov, pairs, objective))
    return [simulate(jobs, [MACHINES[int(i)] for i in a],
                     machines_per_tier=m, busy_until=b, reserved=rv)
            for jobs, a, m, b, rv
            in zip(problems, assigns, mpts, busys, reserveds)]


# --------------------------------------------- contention-aware fleet search
@dataclass(frozen=True)
class FleetPlan:
    """Result of `search_fleet` (DESIGN.md §9).

    naive_reported is the objective B independent per-ward searches CLAIM
    (each ward scored against the full shared pool as if it were alone) —
    unachievable whenever wards overlap on the shared cloud. naive_fleet
    rescores those same plans on the real fleet; the ratio between the two
    is the contention gap this subsystem closes."""
    assignments: List[List[str]]     # final joint plan, per ward
    fleet: FleetSchedule             # fleet-true evaluation of the plan
    naive_fleet: FleetSchedule       # fleet-true eval of independent plans
    naive_assignments: List[List[str]]
    naive_reported: float            # what independent planning claimed
    sweeps: int                      # fixed-point sweeps run
    objective: str

    @property
    def contention_gap(self) -> float:
        """fleet-true / claimed objective of the independent plans (> 1
        means the per-ward numbers double-book the shared cloud)."""
        return self.naive_fleet.objective(self.objective) / max(
            self.naive_reported, 1e-9)

    @property
    def gap_closed(self) -> float:
        """Fraction of the contention gap recovered by the fixed-point
        search (0 = none, 1 = the final plan scores what the independent
        plans claimed)."""
        naive = self.naive_fleet.objective(self.objective)
        excess = naive - self.naive_reported
        if excess <= 0:
            return 1.0
        return (naive - self.fleet.objective(self.objective)) / excess


class _FleetEval:
    """Fleet-true trial evaluator for the §9 acceptance loop — the same
    C5 arithmetic as `simulate_fleet`, specialised to a FIXED fleet
    (jobs, pools, busy vectors) with only the assignment varying.

    `simulate_fleet` re-sorts every pool's merged queue and rebuilds
    ScheduledJob objects on each call; with the interval kernel making
    sweeps cheap, the acceptance loop's per-trial rescoring became the
    §9 bottleneck. This evaluator pre-sorts each pool's full cross-ward
    queue ONCE (filtering a sorted queue by the trial's assignment
    preserves queue order), then replays the exact `_fifo_pool` heap
    arithmetic per trial — same floats in the same accumulation order,
    so values are bit-identical to
    ``simulate_fleet(...).objective(objective)`` (pinned by
    tests/test_intervals.py), and the monotone acceptance decisions are
    exactly the ones the full evaluator would have made."""

    def __init__(self, ward_jobs, mpts, busy_until, ward_busy_until,
                 shared_tiers):
        B = len(ward_jobs)
        busys = [None] * B if ward_busy_until is None \
            else list(ward_busy_until)
        self._rel = [[j.release for j in jobs] for jobs in ward_jobs]
        self._w = [[j.weight for j in jobs] for jobs in ward_jobs]
        # the private tier never queues: precomputed ends, overwritten
        # per trial wherever the assignment routes a job to a pool
        self._ed = [[j.release + j.trans.get(ED, 0.0) + j.proc[ED]
                     for j in jobs] for jobs in ward_jobs]
        self._pools = []        # (tier, sorted records, initial frees)

        def pool(tier, wards_, free0):
            recs = sorted(
                (ward_jobs[b][i].release + ward_jobs[b][i].trans[tier],
                 ward_jobs[b][i].release, b, i,
                 ward_jobs[b][i].proc[tier])
                for b in wards_ for i in range(len(ward_jobs[b])))
            self._pools.append((tier, recs, free0))

        for tier in (CC, ES):
            if tier in shared_tiers:
                if B:
                    pool(tier, range(B),
                         machine_free_times(busy_until, tier,
                                            mpts[0].get(tier, 1)))
            else:
                for b in range(B):
                    pool(tier, (b,),
                         machine_free_times(busys[b], tier,
                                            mpts[b].get(tier, 1)))

    def __call__(self, assignments, objective: str) -> float:
        ends = [list(e) for e in self._ed]
        for tier, recs, free0 in self._pools:
            free = list(free0)
            heapq.heapify(free)
            for arr, _rel, b, i, proc in recs:
                if assignments[b][i] != tier:
                    continue
                avail = heapq.heappop(free)
                start = arr if arr > avail else avail
                end = start + proc
                heapq.heappush(free, end)
                ends[b][i] = end
        if objective == "last":
            return max((max(e, default=0.0) for e in ends), default=0.0)
        tot = 0.0
        if objective == "weighted":
            for rel, w, end in zip(self._rel, self._w, ends):
                s = 0.0
                for r, ww, e in zip(rel, w, end):
                    s += ww * (e - r)
                tot += s
        else:
            for rel, end in zip(self._rel, ends):
                s = 0.0
                for r, e in zip(rel, end):
                    s += e - r
                tot += s
        return tot


def _fleet_reservations(ward_jobs, incumbent, shared_tiers):
    """Per-ward reservation maps for one §9 sweep: ward b sees every
    OTHER ward's currently-committed shared-tier jobs as interval
    reservations (DESIGN.md §12) — same occupancy, same objective
    contribution, same queue ties as the frozen-phantom construction
    they replace, but O(1) carry width in the kernel instead of O(n)
    extra move candidates. Scan order (c, i) restricted per tier keeps
    the within-tier queue tie order identical to the phantom append
    order."""
    B = len(ward_jobs)
    out = []
    for b in range(B):
        m: Dict[str, List[Reservation]] = {}
        for c in range(B):
            if c == b:
                continue
            jobs_c, inc_c = ward_jobs[c], incumbent[c]
            for i, t in enumerate(inc_c):
                if t in shared_tiers:
                    j = jobs_c[i]
                    m.setdefault(t, []).append(Reservation(
                        arrival=j.release + j.trans.get(t, 0.0),
                        proc=j.proc[t], release=j.release,
                        weight=j.weight))
        out.append(m)
    return out


def _fleet_views(ward_jobs, mpts, busy_until, ward_busy_until, shared_tiers):
    """Per-ward (machines, busy) dicts for INDEPENDENT planning: every
    ward sees the full shared pool (and its initial occupancy) as its own
    — exactly the double-booking view `search_fleet` starts from."""
    views = []
    for b in range(len(ward_jobs)):
        busy: Dict[str, Sequence[float]] = {}
        for tier in (CC, ES):
            if tier in shared_tiers:
                vals = (busy_until or {}).get(tier, ())
            else:
                wb = ward_busy_until[b] if ward_busy_until else None
                vals = (wb or {}).get(tier, ())
            vals = list(vals)
            if vals:
                busy[tier] = vals
        views.append((mpts[b], busy or None))
    return views


def search_fleet(ward_jobs: Sequence[Sequence[JobSpec]],
                 machines_per_tier=None, *,
                 objective: str = "weighted",
                 max_count: int = 50,
                 max_sweeps: int = 8,
                 sweep_max_count: int = 2,
                 busy_until: Mapping[str, Sequence[float]] | None = None,
                 ward_busy_until=None,
                 shared_tiers: Tuple[str, ...] = (CC,),
                 min_batch: int | None = None,
                 jax_threshold: int | None = None,
                 sweep_backend: str = "auto",
                 pad_bucket: int = 64,
                 background: str = "interval") -> FleetPlan:
    """Contention-aware multi-ward planning to a fixed point (DESIGN.md §9).

    Starts from B independent per-ward plans (today's `search_batched`
    mode — each ward optimises against the full shared cloud, silently
    double-booking it), rescores them with the fleet-true evaluator
    `simulate_fleet`, then runs Gauss–Seidel sweeps: each sweep replans
    every ward against the OTHER wards' currently-committed shared-tier
    jobs as interval reservations (DESIGN.md §12 — queue-active
    background occupancy the search prices but can never reassign, so
    ward b pays, and sees, the delay it inflicts on the rest of the
    fleet). A ward's proposal is then accepted only if it strictly
    improves the fleet-true objective, so the incumbent value is
    monotone decreasing over a finite assignment space and the
    iteration terminates (§9 termination argument); trial values come
    from the bit-identical `_FleetEval` replay, with one final
    `simulate_fleet` on the accepted plan (§3.1 invariant).

    machines_per_tier: one {tier: count} mapping for all wards or a
    per-ward sequence (shared-tier counts must agree — one pool).
    busy_until: initial free times of the SHARED pools; ward_busy_until:
    optional per-ward occupancy of the per-ward pools. sweep_max_count:
    tabu budget per replanning sweep (small — sweeps only need local
    repairs on top of the incumbent). pad_bucket: instance row slots
    (jobs + reservations) are padded to multiples of this so the batched
    search's compiled shape stays stable while the background churns
    across sweeps.

    sweep_backend — the §3.3 dispatch question again, at sweep scale:
    "batched" replans all wards in one `tabu_search_batched` device call
    per sweep; "python" loops the incremental per-ward `search`. "auto"
    (default) picks batched whenever B >= min_batch — on CPU too, since
    the §12 movable-only carry made a mostly-background ward cost
    O(rows x movable) per round instead of the O(n_aug^2) that used to
    hand CPU sweeps to the Python path (DESIGN.md §12).

    background: "interval" (default) models other wards' committed jobs
    as reservations; "phantom" is the legacy frozen-job construction,
    kept as the parity oracle for the interval representation
    (tests/test_intervals.py) — same objectives, same trajectories,
    O(n_aug) extra move-candidate rows per sweep.

    Returns a FleetPlan carrying the final joint plan, both fleet-true
    evaluations, the claimed (double-booked) objective, and the sweep
    count.
    """
    B = len(ward_jobs)
    if B == 0:
        empty = simulate_fleet([], [], shared_tiers=shared_tiers)
        return FleetPlan([], empty, empty, [], 0.0, 0, objective)
    mpts = _fleet_mpts(machines_per_tier, B, shared_tiers)
    views = _fleet_views(ward_jobs, mpts, busy_until, ward_busy_until,
                         shared_tiers)

    def fleet_eval(assignments) -> FleetSchedule:
        return simulate_fleet(ward_jobs, assignments,
                              machines_per_tier=mpts,
                              busy_until=busy_until,
                              ward_busy_until=ward_busy_until,
                              shared_tiers=shared_tiers)

    # 1) independent (double-booked) plans — the naive baseline
    naive = search_batched(list(ward_jobs), max_count=max_count,
                           objective=objective,
                           machines_per_tier=[v[0] for v in views],
                           busy_until=[v[1] for v in views],
                           min_batch=min_batch, jax_threshold=jax_threshold)
    naive_assignments = [s.assignment() for s in naive]
    agg = max if objective == "last" else sum
    naive_reported = float(agg(s.objective(objective) for s in naive))
    naive_fleet = fleet_eval(naive_assignments)

    incumbent = [list(a) for a in naive_assignments]
    best_fleet = naive_fleet
    best = best_fleet.objective(objective)
    threshold = BATCHED_SEARCH_MIN_WARDS if min_batch is None else min_batch
    if sweep_backend not in ("auto", "batched", "python"):
        raise ValueError(f"unknown sweep_backend {sweep_backend!r}")
    if background not in ("interval", "phantom"):
        raise ValueError(f"unknown background {background!r}")
    batched_sweeps = sweep_backend == "batched" or (
        sweep_backend == "auto" and B >= threshold)
    if batched_sweeps:
        pairs = [(int(views[b][0].get(CC, 1)),
                  int(views[b][0].get(ES, 1))) for b in range(B)]
        busy_pairs = [tuple(machine_free_times(views[b][1], t, m)
                            for t, m in zip((CC, ES), pairs[b]))
                      for b in range(B)]
    trial_eval = _FleetEval(ward_jobs, mpts, busy_until, ward_busy_until,
                            shared_tiers)

    sweeps = 0
    changed = False
    pad_to = 0          # sticky across sweeps: one compile for the run
    for _ in range(max_sweeps):
        proposals: List[List[str]] = []
        if background == "interval":
            # background of ward b: every other ward's shared-tier jobs,
            # committed as interval reservations (§12)
            resvs = _fleet_reservations(ward_jobs, incumbent, shared_tiers)
            if not batched_sweeps:
                for b in range(B):
                    plan = search(list(ward_jobs[b]), initial=incumbent[b],
                                  max_count=sweep_max_count,
                                  objective=objective,
                                  reserved=resvs[b] or None,
                                  jax_threshold=jax_threshold,
                                  machines_per_tier=views[b][0],
                                  busy_until=views[b][1])
                    proposals.append(plan.assignment())
            else:
                from repro.core import scheduler_jax
                # bucket the padded ROW count (jobs + reservations) and
                # keep it STICKY across sweeps: the background shrinks
                # as wards move off the shared cloud, and re-bucketing
                # downward would retrace the jitted search every sweep
                # (XLA compile dwarfs the sweep itself)
                rows = max(len(ward_jobs[b])
                           + sum(len(v) for v in resvs[b].values())
                           for b in range(B))
                pad_to = max(pad_to, -(-rows // pad_bucket) * pad_bucket)
                _, assigns = scheduler_jax.tabu_search_batched(
                    [list(jobs) for jobs in ward_jobs],
                    [[MACHINES.index(t) for t in incumbent[b]]
                     for b in range(B)],
                    max_rounds=max(sweep_max_count, 1),
                    objective=objective, machines_per_tier=pairs,
                    busy_until=busy_pairs, reserved=resvs, pad_to=pad_to)
                _note_shape(_batched_shape(
                    B, pad_to, max(map(len, ward_jobs)), pairs, objective))
                proposals = [[MACHINES[int(i)]
                              for i in assigns[b][:len(ward_jobs[b])]]
                             for b in range(B)]
        else:
            # legacy frozen-phantom background — the §12 parity oracle:
            # other wards' shared-tier jobs appended as immovable rows
            bg = [[(ward_jobs[c][i], incumbent[c][i])
                   for c in range(B) if c != b
                   for i in range(len(ward_jobs[c]))
                   if incumbent[c][i] in shared_tiers]
                  for b in range(B)]
            aug_jobs = [list(ward_jobs[b]) + [j for j, _ in bg[b]]
                        for b in range(B)]
            aug_init = [incumbent[b] + [t for _, t in bg[b]]
                        for b in range(B)]
            frozen = [[False] * len(ward_jobs[b]) + [True] * len(bg[b])
                      for b in range(B)]
            if not batched_sweeps:
                for b in range(B):
                    plan = search(aug_jobs[b], initial=aug_init[b],
                                  max_count=sweep_max_count,
                                  objective=objective, frozen=frozen[b],
                                  jax_threshold=jax_threshold,
                                  machines_per_tier=views[b][0],
                                  busy_until=views[b][1])
                    proposals.append(plan.assignment()[:len(ward_jobs[b])])
            else:
                from repro.core import scheduler_jax
                n_aug = max(len(jobs) for jobs in aug_jobs)
                pad_to = max(pad_to, -(-n_aug // pad_bucket) * pad_bucket)
                _, assigns = scheduler_jax.tabu_search_batched(
                    aug_jobs,
                    [[MACHINES.index(t) for t in init]
                     for init in aug_init],
                    max_rounds=max(sweep_max_count, 1),
                    objective=objective, machines_per_tier=pairs,
                    busy_until=busy_pairs, frozen=frozen, pad_to=pad_to)
                _note_shape(_batched_shape(
                    B, pad_to, max(map(len, ward_jobs)), pairs, objective))
                proposals = [[MACHINES[int(i)]
                              for i in assigns[b][:len(ward_jobs[b])]]
                             for b in range(B)]
        sweeps += 1
        # Gauss–Seidel acceptance: commit each ward's proposal only if it
        # strictly improves the FLEET-TRUE objective given everything
        # already committed this sweep — monotone, hence terminating.
        # `trial_eval` replays `simulate_fleet`'s arithmetic bit-for-bit
        # at a fraction of its cost; the accepted plan is rescored by the
        # reference evaluator once, after the loop.
        improved = False
        for b in range(B):
            if proposals[b] == incumbent[b]:
                continue
            trial = list(incumbent)
            trial[b] = proposals[b]
            v = trial_eval(trial, objective)
            if v < best - 1e-9:
                incumbent, best = trial, v
                improved = changed = True
        if not improved:
            break
    if changed:
        best_fleet = fleet_eval(incumbent)

    return FleetPlan(assignments=[list(a) for a in incumbent],
                     fleet=best_fleet, naive_fleet=naive_fleet,
                     naive_assignments=naive_assignments,
                     naive_reported=naive_reported,
                     sweeps=sweeps, objective=objective)


def _accelerator_backend() -> bool:
    try:
        import jax
        return jax.default_backend() not in ("cpu",)
    except Exception:                                       # pragma: no cover
        return False


# ------------------------------------------------------------- exact optimum
def exact_optimum(jobs: Sequence[JobSpec],
                  objective: str = "weighted",
                  machines_per_tier: Mapping[str, int] | None = None,
                  busy_until: Mapping[str, Sequence[float]] | None = None
                  ) -> Schedule:
    """Brute-force over all 3^n assignments (n <= ~12). The paper offers no
    optimality baseline; we use this to report the heuristic's gap."""
    n = len(jobs)
    if n > 12:
        # ValueError, not assert: a 3^n enumeration bomb must be refused
        # under ``python -O`` too
        raise ValueError(f"exact_optimum is 3^n; n={n} > 12 — use "
                         f"scheduler_jax.exact_optimum_jax for larger n")
    best_s, best_v = None, float("inf")
    for combo in itertools.product(MACHINES, repeat=n):
        s = simulate(jobs, combo, machines_per_tier=machines_per_tier,
                     busy_until=busy_until)
        v = s.weighted_sum if objective == "weighted" else s.unweighted_sum
        if v < best_v:
            best_s, best_v = s, v
    return best_s


# -------------------------------------------------------------- comparison
def strategy_table(jobs: Sequence[JobSpec],
                   jax_threshold: int | None = None,
                   machines_per_tier: Mapping[str, int] | None = None
                   ) -> Dict[str, Schedule]:
    """The paper's Table VII comparison set + our extras. "ours" goes
    through the size-dispatched `search`, so fleet-scale tables use the
    jitted path. machines_per_tier (from TierSpec.machines) sizes the
    shared tiers for every strategy."""
    mpt = machines_per_tier
    return {
        "ours (algorithm 2)": search(jobs, jax_threshold=jax_threshold,
                                     machines_per_tier=mpt),
        "per-job optimal layer": per_job_optimal(jobs, machines_per_tier=mpt),
        "all cloud": all_on_tier(jobs, CC, machines_per_tier=mpt),
        "all edge": all_on_tier(jobs, ES, machines_per_tier=mpt),
        "all device": all_on_tier(jobs, ED, machines_per_tier=mpt),
    }
