"""Algorithm 2 — multi-job allocation heuristic (paper Section VI).

Pipeline:
  1. greedy initial solution: jobs in release order (tie: priority desc),
     each assigned to the machine minimising its completion time given the
     machine free-times so far ("the earliest released job gets the
     shortest response time");
  2. tabu-guarded neighbourhood search: repeatedly pick the
     earliest-completing non-tabu job, try moving it to every other
     machine, keep the move with the largest positive reduction of the
     weighted whole response time (paper lines 10-28);
  3. every candidate is evaluated with the exact discrete-event simulator
     (core.simulator), so reported numbers always reflect C1-C5 semantics.

Also provides baseline strategies (Table VII comparison set) and an exact
brute-force optimum for small n (the paper has none — we add it to measure
the heuristic's optimality gap).
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Sequence

from repro.core.simulator import (MACHINES, JobSpec, Schedule, simulate)
from repro.core.tiers import CC, ED, ES


# --------------------------------------------------------------- strategies
def all_on_tier(jobs: Sequence[JobSpec], tier: str) -> Schedule:
    return simulate(jobs, [tier] * len(jobs))


def per_job_optimal(jobs: Sequence[JobSpec]) -> Schedule:
    """Table VII row 2: each job on its own Algorithm-1-optimal tier,
    ignoring queueing."""
    assign = [min(MACHINES, key=lambda t: j.response_if_alone(t))
              for j in jobs]
    return simulate(jobs, assign)


# ------------------------------------------------------------------ greedy
def greedy_schedule(jobs: Sequence[JobSpec]) -> List[str]:
    """Initial feasible solution (Algorithm 2 step 1)."""
    order = sorted(range(len(jobs)),
                   key=lambda i: (jobs[i].release, -jobs[i].weight, i))
    free: Dict[str, float] = {CC: 0.0, ES: 0.0}
    assign: List[str] = [""] * len(jobs)
    for i in order:
        job = jobs[i]
        best_t, best_end = None, float("inf")
        for tier in (ED, ES, CC):    # tie -> prefer lower tier
            arr = job.release + job.trans.get(tier, 0.0)
            start = arr if tier == ED else max(arr, free[tier])
            end = start + job.proc[tier]
            if end < best_end:
                best_t, best_end = tier, end
        assign[i] = best_t
        if best_t != ED:
            free[best_t] = best_end
    return assign


# ------------------------------------------------- Algorithm 2 (tabu search)
def neighborhood_search(jobs: Sequence[JobSpec],
                        initial: Sequence[str] | None = None,
                        max_count: int = 50,
                        objective: str = "weighted") -> Schedule:
    """Paper Algorithm 2. objective: "weighted" (eq. 5) | "unweighted"."""
    assign = list(initial or greedy_schedule(jobs))

    def score(a: Sequence[str]) -> float:
        s = simulate(jobs, a)
        return s.weighted_sum if objective == "weighted" else s.unweighted_sum

    best = score(assign)
    for _ in range(max_count):
        tabu_job = [False] * len(jobs)
        improved_this_round = False
        for _inner in range(len(jobs)):
            # earliest-completing non-tabu job (paper line 15)
            sched = simulate(jobs, assign)
            ends = {id(e.job): e.end for e in sched.entries}
            cand = [i for i in range(len(jobs)) if not tabu_job[i]]
            if not cand:
                break
            k = min(cand, key=lambda i: ends[id(jobs[i])])
            tabu_job[k] = True
            # best move for job k across machines (paper lines 17-25)
            v_max, move = 0.0, None
            for tier in MACHINES:
                if tier == assign[k]:
                    continue
                trial = list(assign)
                trial[k] = tier
                v = best - score(trial)
                if v > v_max:
                    v_max, move = v, tier
            if move is not None:
                assign[k] = move
                best -= v_max
                improved_this_round = True
        if not improved_this_round:
            break
    return simulate(jobs, assign)


# ------------------------------------------------------------- exact optimum
def exact_optimum(jobs: Sequence[JobSpec],
                  objective: str = "weighted") -> Schedule:
    """Brute-force over all 3^n assignments (n <= ~12). The paper offers no
    optimality baseline; we use this to report the heuristic's gap."""
    n = len(jobs)
    assert n <= 12, "use scheduler_jax.exact_optimum_jax for larger n"
    best_s, best_v = None, float("inf")
    for combo in itertools.product(MACHINES, repeat=n):
        s = simulate(jobs, combo)
        v = s.weighted_sum if objective == "weighted" else s.unweighted_sum
        if v < best_v:
            best_s, best_v = s, v
    return best_s


# -------------------------------------------------------------- comparison
def strategy_table(jobs: Sequence[JobSpec]) -> Dict[str, Schedule]:
    """The paper's Table VII comparison set + our extras."""
    return {
        "ours (algorithm 2)": neighborhood_search(jobs),
        "per-job optimal layer": per_job_optimal(jobs),
        "all cloud": all_on_tier(jobs, CC),
        "all edge": all_on_tier(jobs, ES),
        "all device": all_on_tier(jobs, ED),
    }
