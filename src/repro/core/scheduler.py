"""Algorithm 2 — multi-job allocation heuristic (paper Section VI).

Pipeline:
  1. greedy initial solution: jobs in release order (tie: priority desc),
     each assigned to the machine minimising its completion time given the
     machine free-times so far ("the earliest released job gets the
     shortest response time");
  2. tabu-guarded neighbourhood search: repeatedly pick the
     earliest-completing non-tabu job, try moving it to every other
     machine, keep the move with the largest positive reduction of the
     weighted whole response time (paper lines 10-28);
  3. every candidate is scored with the incremental evaluator
     (simulator.ScheduleState) whose per-move cost is O(two machine
     queues); the returned Schedule is always a final exact re-simulation,
     so reported numbers always reflect C1-C5 semantics.

`search` dispatches between this Python path (small n) and the fully
jitted JAX neighbourhood search (scheduler_jax.tabu_search_jax) above
JAX_SEARCH_THRESHOLD jobs — see DESIGN.md §3.3 for the policy.

Also provides baseline strategies (Table VII comparison set), an exact
brute-force optimum for small n (the paper has none — we add it to measure
the heuristic's optimality gap), and `neighborhood_search_reference`, the
seed full-re-simulation implementation kept as a benchmark baseline and
parity oracle.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Mapping, Sequence

from repro.core.simulator import (MACHINES, JobSpec, Schedule, ScheduleState,
                                  machine_free_times, simulate)
from repro.core.tiers import CC, ED, ES

# above this many jobs, `search` uses the jitted JAX neighbourhood search
JAX_SEARCH_THRESHOLD = 64

# batches at least this large dispatch to the single-call batched JAX
# search (DESIGN.md §8); smaller ones loop the per-instance `search`
BATCHED_SEARCH_MIN_WARDS = 4


# --------------------------------------------------------------- strategies
def all_on_tier(jobs: Sequence[JobSpec], tier: str,
                machines_per_tier: Mapping[str, int] | None = None
                ) -> Schedule:
    return simulate(jobs, [tier] * len(jobs),
                    machines_per_tier=machines_per_tier)


def per_job_optimal(jobs: Sequence[JobSpec],
                    machines_per_tier: Mapping[str, int] | None = None
                    ) -> Schedule:
    """Table VII row 2: each job on its own Algorithm-1-optimal tier,
    ignoring queueing."""
    assign = [min(MACHINES, key=lambda t: j.response_if_alone(t))
              for j in jobs]
    return simulate(jobs, assign, machines_per_tier=machines_per_tier)


# ------------------------------------------------------------------ greedy
def greedy_schedule(jobs: Sequence[JobSpec],
                    machines_per_tier: Mapping[str, int] | None = None,
                    busy_until: Mapping[str, Sequence[float]] | None = None
                    ) -> List[str]:
    """Initial feasible solution (Algorithm 2 step 1).

    Honors multi-server tiers (earliest-free machine per tier) and
    machines already busy at the start (``busy_until``, DESIGN.md §7) —
    the same greedy rule online scheduling commits on each arrival.
    """
    mpt = dict(machines_per_tier or {CC: 1, ES: 1})
    order = sorted(range(len(jobs)),
                   key=lambda i: (jobs[i].release, -jobs[i].weight, i))
    free = {t: machine_free_times(busy_until, t, mpt.get(t, 1))
            for t in (CC, ES)}
    for heap in free.values():
        heapq.heapify(heap)
    assign: List[str] = [""] * len(jobs)
    for i in order:
        job = jobs[i]
        best_t, best_end = None, float("inf")
        for tier in (ED, ES, CC):    # tie -> prefer lower tier
            arr = job.release + job.trans.get(tier, 0.0)
            start = arr if tier == ED else max(arr, free[tier][0])
            end = start + job.proc[tier]
            if end < best_end:
                best_t, best_end = tier, end
        assign[i] = best_t
        if best_t != ED:
            heapq.heapreplace(free[best_t], best_end)
    return assign


# ------------------------------------------------- Algorithm 2 (tabu search)
def neighborhood_search(jobs: Sequence[JobSpec],
                        initial: Sequence[str] | None = None,
                        max_count: int = 50,
                        objective: str = "weighted",
                        machines_per_tier: Mapping[str, int] | None = None,
                        busy_until: Mapping[str, Sequence[float]] | None = None
                        ) -> Schedule:
    """Paper Algorithm 2. objective: "weighted" (eq. 5) | "unweighted".

    Each candidate move is scored incrementally (only the two affected
    machine queues are re-simulated), and the incumbent objective is
    re-derived from the committed state after every accepted move — no
    running ``best -= v_max`` accumulator, so no float drift over long
    searches.

    machines_per_tier / busy_until describe the fleet the schedule will
    actually run on (multi-server tiers, machines pre-occupied by committed
    jobs) — the searched objective IS the commit objective (DESIGN.md §7).
    """
    assign = list(initial or greedy_schedule(
        jobs, machines_per_tier=machines_per_tier, busy_until=busy_until))
    state = ScheduleState(jobs, assign, machines_per_tier=machines_per_tier,
                          busy_until=busy_until)
    best = state.score(objective)
    for _ in range(max_count):
        tabu_job = [False] * len(jobs)
        improved_this_round = False
        for _inner in range(len(jobs)):
            # earliest-completing non-tabu job (paper line 15)
            cand = [i for i in range(len(jobs)) if not tabu_job[i]]
            if not cand:
                break
            k = min(cand, key=lambda i: state.end[i])
            tabu_job[k] = True
            # best move for job k across machines (paper lines 17-25)
            v_max, move = 0.0, None
            for tier in MACHINES:
                if tier == state.assign[k]:
                    continue
                v = best - state.try_move(k, tier, objective)
                if v > v_max:
                    v_max, move = v, tier
            if move is not None:
                state.apply_move(k, move)
                best = state.score(objective)
                improved_this_round = True
        if not improved_this_round:
            break
    return state.to_schedule()


def neighborhood_search_reference(jobs: Sequence[JobSpec],
                                  initial: Sequence[str] | None = None,
                                  max_count: int = 50,
                                  objective: str = "weighted") -> Schedule:
    """The seed implementation of Algorithm 2, kept verbatim as a benchmark
    baseline and parity oracle: every candidate move re-runs the full
    discrete-event simulation, and the incumbent objective is tracked by a
    running ``best -= v_max`` accumulator (which drifts on non-integer
    instances — fixed in `neighborhood_search`). O(rounds * n^2 * |tiers|)
    complete simulations; use only at small n."""
    assign = list(initial or greedy_schedule(jobs))

    def score(a: Sequence[str]) -> float:
        s = simulate(jobs, a)
        return s.weighted_sum if objective == "weighted" else s.unweighted_sum

    best = score(assign)
    for _ in range(max_count):
        tabu_job = [False] * len(jobs)
        improved_this_round = False
        for _inner in range(len(jobs)):
            sched = simulate(jobs, assign)
            ends = {id(e.job): e.end for e in sched.entries}
            cand = [i for i in range(len(jobs)) if not tabu_job[i]]
            if not cand:
                break
            k = min(cand, key=lambda i: ends[id(jobs[i])])
            tabu_job[k] = True
            v_max, move = 0.0, None
            for tier in MACHINES:
                if tier == assign[k]:
                    continue
                trial = list(assign)
                trial[k] = tier
                v = best - score(trial)
                if v > v_max:
                    v_max, move = v, tier
            if move is not None:
                assign[k] = move
                best -= v_max
                improved_this_round = True
        if not improved_this_round:
            break
    return simulate(jobs, assign)


# ------------------------------------------------------------- fast dispatch
def search(jobs: Sequence[JobSpec],
           initial: Sequence[str] | None = None,
           max_count: int = 50,
           objective: str = "weighted",
           jax_threshold: int | None = None,
           machines_per_tier: Mapping[str, int] | None = None,
           busy_until: Mapping[str, Sequence[float]] | None = None
           ) -> Schedule:
    """Size-dispatched Algorithm 2: the incremental Python tabu search for
    small instances, the fully jitted JAX neighbourhood search (one
    vmapped n x 3 neighbourhood evaluation per round inside lax.while_loop,
    no host syncs) for large ones. Both return an exact C1-C5 Schedule.

    jax_threshold: job count above which the JAX path is taken. Default
    (None): JAX_SEARCH_THRESHOLD when an accelerator backend is present,
    never on CPU. Since the delta-evaluation rewrite the jitted search
    wins on CPU too once compiled (n=100 and n=1000 both, DESIGN.md
    §3.3), but each new (instance size, fleet) shape pays a multi-second
    XLA compile — replanning loops see a different size at every event,
    so the Python path stays the CPU default. Pass an explicit threshold
    to force the JAX path where shapes repeat (benchmarks, serving, TPU
    deployments); fleet planning over many wards should use
    `search_batched`, which amortises one compile across the batch.

    machines_per_tier / busy_until (DESIGN.md §7) are threaded through
    whichever backend runs, so both search the problem the schedule will
    actually be committed against.
    """
    n = len(jobs)
    if jax_threshold is None:
        use_jax = n > JAX_SEARCH_THRESHOLD and _accelerator_backend()
    else:
        use_jax = n > jax_threshold
    if not use_jax:
        return neighborhood_search(jobs, initial=initial,
                                   max_count=max_count, objective=objective,
                                   machines_per_tier=machines_per_tier,
                                   busy_until=busy_until)
    from repro.core import scheduler_jax   # lazy: keep jax off small paths
    assign0 = initial or greedy_schedule(
        jobs, machines_per_tier=machines_per_tier, busy_until=busy_until)
    mpt = dict(machines_per_tier or {})
    mpt_jax = (int(mpt.get(CC, 1)), int(mpt.get(ES, 1)))
    busy_jax = tuple(machine_free_times(busy_until, t, m)
                     for t, m in zip((CC, ES), mpt_jax))
    _, best_a = scheduler_jax.tabu_search_jax(
        jobs, initial=[MACHINES.index(t) for t in assign0],
        max_rounds=max(max_count, 1) * len(jobs), objective=objective,
        machines_per_tier=mpt_jax, busy_until=busy_jax)
    return simulate(jobs, [MACHINES[int(m)] for m in best_a],
                    machines_per_tier=machines_per_tier,
                    busy_until=busy_until)


def search_batched(problems: Sequence[Sequence[JobSpec]],
                   max_count: int = 50,
                   objective: str = "weighted",
                   machines_per_tier=None,
                   busy_until=None,
                   min_batch: int | None = None) -> List[Schedule]:
    """Plan B independent ward instances, one jitted device call
    (DESIGN.md §8) — the fleet-scale entry point used by
    `launch/serve.py --wards` and the batched clairvoyant baselines in
    `core/online.py`.

    problems: B job lists (sizes may differ — padded on the batched
    path with phantom jobs that contribute exactly 0 to every
    objective). machines_per_tier: one {tier: count} mapping for every
    ward or a per-ward sequence of mappings; busy_until: optional
    per-ward {tier: [free times]} sequence. min_batch: batches smaller
    than this loop the per-instance `search` instead (default
    BATCHED_SEARCH_MIN_WARDS — tiny fleets don't amortise a device
    dispatch); pass 1 to force the batched path, a large value to force
    the sequential loop.

    Every returned Schedule is a final exact `simulate` of its ward's
    best assignment against that ward's own fleet, so reported numbers
    are the reference evaluator's bit-for-bit (§3.1 invariant)."""
    B = len(problems)
    single = isinstance(machines_per_tier, Mapping) or machines_per_tier \
        is None
    mpts = [machines_per_tier] * B if single else list(machines_per_tier)
    busys = [None] * B if busy_until is None else list(busy_until)
    if len(mpts) != B or len(busys) != B:
        raise ValueError(f"{len(mpts)} fleets / {len(busys)} busy vectors "
                         f"for {B} wards")
    threshold = BATCHED_SEARCH_MIN_WARDS if min_batch is None else min_batch
    if B < threshold:
        return [search(jobs, max_count=max_count, objective=objective,
                       machines_per_tier=m, busy_until=b)
                for jobs, m, b in zip(problems, mpts, busys)]
    from repro.core import scheduler_jax   # lazy: keep jax off small paths
    pairs = [(int(dict(m or {}).get(CC, 1)), int(dict(m or {}).get(ES, 1)))
             for m in mpts]
    busy_pairs = [tuple(machine_free_times(b, t, mm)
                        for t, mm in zip((CC, ES), pair))
                  for b, pair in zip(busys, pairs)]
    n_max = max((len(jobs) for jobs in problems), default=0)
    _, assigns = scheduler_jax.tabu_search_batched(
        problems, max_rounds=max(max_count, 1) * max(n_max, 1),
        objective=objective, machines_per_tier=pairs,
        busy_until=busy_pairs)
    return [simulate(jobs, [MACHINES[int(i)] for i in a],
                     machines_per_tier=m, busy_until=b)
            for jobs, a, m, b in zip(problems, assigns, mpts, busys)]


def _accelerator_backend() -> bool:
    try:
        import jax
        return jax.default_backend() not in ("cpu",)
    except Exception:                                       # pragma: no cover
        return False


# ------------------------------------------------------------- exact optimum
def exact_optimum(jobs: Sequence[JobSpec],
                  objective: str = "weighted",
                  machines_per_tier: Mapping[str, int] | None = None,
                  busy_until: Mapping[str, Sequence[float]] | None = None
                  ) -> Schedule:
    """Brute-force over all 3^n assignments (n <= ~12). The paper offers no
    optimality baseline; we use this to report the heuristic's gap."""
    n = len(jobs)
    assert n <= 12, "use scheduler_jax.exact_optimum_jax for larger n"
    best_s, best_v = None, float("inf")
    for combo in itertools.product(MACHINES, repeat=n):
        s = simulate(jobs, combo, machines_per_tier=machines_per_tier,
                     busy_until=busy_until)
        v = s.weighted_sum if objective == "weighted" else s.unweighted_sum
        if v < best_v:
            best_s, best_v = s, v
    return best_s


# -------------------------------------------------------------- comparison
def strategy_table(jobs: Sequence[JobSpec],
                   jax_threshold: int | None = None,
                   machines_per_tier: Mapping[str, int] | None = None
                   ) -> Dict[str, Schedule]:
    """The paper's Table VII comparison set + our extras. "ours" goes
    through the size-dispatched `search`, so fleet-scale tables use the
    jitted path. machines_per_tier (from TierSpec.machines) sizes the
    shared tiers for every strategy."""
    mpt = machines_per_tier
    return {
        "ours (algorithm 2)": search(jobs, jax_threshold=jax_threshold,
                                     machines_per_tier=mpt),
        "per-job optimal layer": per_job_optimal(jobs, machines_per_tier=mpt),
        "all cloud": all_on_tier(jobs, CC, machines_per_tier=mpt),
        "all edge": all_on_tier(jobs, ES, machines_per_tier=mpt),
        "all device": all_on_tier(jobs, ED, machines_per_tier=mpt),
    }
