"""Bridges between cost models / paper tables and scheduler JobSpecs."""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

from repro.core.cost_model import CostModel, Job
from repro.core.simulator import JobSpec
from repro.core.tiers import CC, ED, ES


def jobs_to_specs(cost_model: CostModel, jobs: Sequence[Job],
                  normalize: float | None = None) -> List[JobSpec]:
    """Turn Jobs + a CostModel into scheduler rows.

    normalize: if set, divide all times by this quantum and round up to
    integers (paper constraint C3)."""
    specs = []
    for job in jobs:
        proc, trans = {}, {}
        for tier, (d, i) in cost_model.times(job).items():
            if normalize:
                d = math.ceil(d / normalize)
                i = max(1, math.ceil(i / normalize))
            proc[tier], trans[tier] = i, d
        specs.append(JobSpec(name=job.name or job.workload.name,
                             release=job.release, weight=job.priority,
                             proc=proc, trans=trans))
    return specs


def table6_jobs() -> List[JobSpec]:
    """The paper's Table VI experimental job set, verbatim.

    Columns: release, weight, cloud (proc, trans), edge (proc, trans),
    device proc."""
    rows = [
        ("J1", 1, 2, 6, 56, 9, 11, 14),
        ("J2", 1, 2, 3, 32, 3, 6, 12),
        ("J3", 3, 1, 4, 12, 6, 2, 49),
        ("J4", 5, 1, 7, 23, 11, 5, 69),
        ("J5", 10, 2, 4, 27, 5, 5, 11),
        ("J6", 20, 2, 5, 70, 5, 14, 22),
        ("J7", 21, 2, 5, 70, 5, 14, 22),
        ("J8", 21, 1, 4, 12, 6, 2, 49),
        ("J9", 22, 1, 4, 12, 6, 2, 49),
        ("J10", 25, 1, 7, 23, 11, 5, 69),
    ]
    return [JobSpec(name=n, release=r, weight=w,
                    proc={CC: pc, ES: pe, ED: pd},
                    trans={CC: tc, ES: te, ED: 0.0})
            for (n, r, w, pc, tc, pe, te, pd) in rows]
