"""Bridges between cost models / paper tables and scheduler JobSpecs,
plus arrival-scenario generators for the online scheduler (DESIGN.md §7)."""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

import numpy as np

from repro.core.cost_model import CostModel, Job
from repro.core.simulator import MACHINES, JobSpec
from repro.core.tiers import CC, ED, ES


def jobs_to_specs(cost_model: CostModel, jobs: Sequence[Job],
                  normalize: float | None = None) -> List[JobSpec]:
    """Turn Jobs + a CostModel into scheduler rows.

    normalize: if set, divide all times by this quantum and round up to
    integers (paper constraint C3)."""
    specs = []
    for job in jobs:
        proc, trans = {}, {}
        for tier, (d, i) in cost_model.times(job).items():
            if normalize:
                d = math.ceil(d / normalize)
                i = max(1, math.ceil(i / normalize))
            proc[tier], trans[tier] = i, d
        specs.append(JobSpec(name=job.name or job.workload.name,
                             release=job.release, weight=job.priority,
                             proc=proc, trans=trans,
                             workload=job.workload.name))
    return specs


def table6_jobs() -> List[JobSpec]:
    """The paper's Table VI experimental job set, verbatim.

    Columns: release, weight, cloud (proc, trans), edge (proc, trans),
    device proc."""
    rows = [
        ("J1", 1, 2, 6, 56, 9, 11, 14),
        ("J2", 1, 2, 3, 32, 3, 6, 12),
        ("J3", 3, 1, 4, 12, 6, 2, 49),
        ("J4", 5, 1, 7, 23, 11, 5, 69),
        ("J5", 10, 2, 4, 27, 5, 5, 11),
        ("J6", 20, 2, 5, 70, 5, 14, 22),
        ("J7", 21, 2, 5, 70, 5, 14, 22),
        ("J8", 21, 1, 4, 12, 6, 2, 49),
        ("J9", 22, 1, 4, 12, 6, 2, 49),
        ("J10", 25, 1, 7, 23, 11, 5, 69),
    ]
    return [JobSpec(name=n, release=r, weight=w,
                    proc={CC: pc, ES: pe, ED: pd},
                    trans={CC: tc, ES: te, ED: 0.0})
            for (n, r, w, pc, tc, pe, te, pd) in rows]


# ---------------------------------------------- online arrival scenarios
# Cost ranges follow the paper's Table VI magnitudes (proc 1-30 units,
# cloud-heavy transmission); only the ARRIVAL PROCESS differs per scenario.
def _spec_at(rng: np.random.Generator, i: int, release: float) -> JobSpec:
    return JobSpec(
        name=f"J{i}", release=float(release),
        weight=float(rng.integers(1, 4)),
        proc={t: float(rng.integers(1, 30)) for t in MACHINES},
        trans={CC: float(rng.integers(0, 60)),
               ES: float(rng.integers(0, 15)), ED: 0.0})


def poisson_jobs(rng: np.random.Generator, n: int = 20,
                 rate: float = 0.2) -> List[JobSpec]:
    """Steady-state ward: memoryless arrivals at `rate` jobs per time unit
    (exponential inter-arrival times) — the baseline online scenario."""
    releases = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [_spec_at(rng, i, r) for i, r in enumerate(releases)]


def surge_jobs(rng: np.random.Generator, n: int = 20,
               quiet_rate: float = 0.05, surge_frac: float = 0.6,
               surge_width: float = 10.0) -> List[JobSpec]:
    """ER surge: a quiet Poisson background, then a mass-casualty burst —
    `surge_frac` of the jobs land inside one `surge_width`-wide window.
    Bursty arrivals are where naive replanning degrades hardest."""
    n_surge = int(round(n * surge_frac))
    background = np.cumsum(rng.exponential(1.0 / quiet_rate,
                                           size=n - n_surge))
    t0 = float(rng.uniform(0, max(background[-1], 1.0))) \
        if len(background) else 0.0
    burst = t0 + rng.uniform(0, surge_width, size=n_surge)
    releases = np.sort(np.concatenate([background, burst]))
    return [_spec_at(rng, i, r) for i, r in enumerate(releases)]


def quiet_jobs(rng: np.random.Generator, n: int = 12,
               rate: float = 0.02) -> List[JobSpec]:
    """Nightly quiet: sparse arrivals with long gaps — machines usually
    drain between events, so online should track the clairvoyant optimum
    closely (competitive ratio near 1)."""
    releases = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [_spec_at(rng, i, r) for i, r in enumerate(releases)]


ONLINE_SCENARIOS = {
    "poisson": poisson_jobs,
    "surge": surge_jobs,
    "quiet": quiet_jobs,
}


def metro_costs(rng: np.random.Generator, scale: float = 1.0
                ) -> tuple[Dict[str, float], Dict[str, float]]:
    """One (proc, trans) cost row in the paper's Table VI metro regime
    (cloud fast but far, edge moderate, device slow): proc_cloud 2-8,
    trans_cloud 10-40, proc_edge 4-14, trans_edge 1-8, proc_device 20-70.

    ``scale`` shrinks/grows the whole row (metro traces size the three
    episode stages with it — the life-death threat model is tiny, the
    phenotype classifier heavy). Draw order is part of the contract:
    `metro_jobs` consumers (the §9 contention benchmark) depend on
    bit-identical streams for a given rng state."""
    proc = {CC: scale * float(rng.integers(2, 9)),
            ES: scale * float(rng.integers(4, 15)),
            ED: scale * float(rng.integers(20, 71))}
    trans = {CC: scale * float(rng.integers(10, 41)),
             ES: scale * float(rng.integers(1, 9)), ED: 0.0}
    return proc, trans


def metro_jobs(rng: np.random.Generator, n: int = 100,
               horizon: float = 50.0) -> List[JobSpec]:
    """Cloud-attractive ward workload in the `metro_costs` regime.

    With these magnitudes the shared metropolitan cloud carries real load
    from every ward, which is exactly the regime where per-ward-independent
    planning double-books it — the contention benchmark's generator
    (DESIGN.md §9)."""
    out = []
    for i in range(n):
        release = float(rng.uniform(0, horizon))
        weight = float(rng.integers(1, 4))
        proc, trans = metro_costs(rng)
        out.append(JobSpec(name=f"J{i}", release=release, weight=weight,
                           proc=proc, trans=trans))
    return out


def patient_jobs(rng: np.random.Generator, patients: int,
                 horizon: float) -> List:
    """Random ICU patient jobs: each patient's end device releases one of
    the paper's three LSTM applications in [0, horizon) at a Table IV data
    size. THE scenario source for the serving driver and benchmarks
    (launch/serve.py binds `make_jobs` to this) — returns cost-model
    `Job`s, not JobSpecs; pair with a CostModel via `jobs_to_specs`."""
    # local imports: keep core.problems importable without the model zoo
    from repro.configs.icu_lstm import DATA_SIZES, ICU_WORKLOADS
    from repro.core.cost_model import Workload
    from repro.data import icu

    jobs = []
    for pid in range(patients):
        wl_cfg = ICU_WORKLOADS[rng.integers(len(ICU_WORKLOADS))]
        size = int(DATA_SIZES[rng.integers(len(DATA_SIZES))])
        wl = Workload(name=wl_cfg.name, comp=wl_cfg.paper_flops,
                      unit_bytes=icu.record_bytes(wl_cfg),
                      priority=wl_cfg.priority)
        jobs.append(Job(workload=wl, size=size,
                        release=float(rng.uniform(0, horizon)),
                        name=f"patient{pid}-{wl_cfg.name.split('-')[0]}"))
    return jobs


def ward_batch(rng: np.random.Generator, wards: int,
               n_lo: int = 8, n_hi: int = 24,
               scenario: str = "poisson") -> List[List[JobSpec]]:
    """B independent ward instances for fleet-scale (batched) planning.

    Ward sizes are drawn uniformly from [n_lo, n_hi] — deliberately
    mixed, so consumers exercise the batched search's phantom-job padding
    (DESIGN.md §8). Each ward's arrivals come from the named
    ONLINE_SCENARIOS generator."""
    gen = ONLINE_SCENARIOS[scenario]
    return [gen(rng, n=int(rng.integers(n_lo, n_hi + 1)))
            for _ in range(wards)]
