"""Lower bounds for the multi-job problem (paper eq. 6 + tighter extras)."""
from __future__ import annotations

from typing import Mapping, Sequence

from repro.core.simulator import MACHINES, JobSpec
from repro.core.tiers import CC, ED, ES


def paper_lower_bound(jobs: Sequence[JobSpec],
                      weighted: bool = True) -> float:
    """Eq. (6): every job takes its stand-alone minimum response time."""
    total = 0.0
    for j in jobs:
        best = min(j.response_if_alone(t) for t in MACHINES)
        total += (j.weight if weighted else 1.0) * best
    return total


def jobwise_last_bound(jobs: Sequence[JobSpec]) -> float:
    """Per-job last-completion bound: no schedule can finish before the
    latest of the jobs' own best-case completions (release + stand-alone
    minimum response)."""
    return max(j.release + min(j.response_if_alone(t) for t in MACHINES)
               for j in jobs)


def _forced_load_feasible(jobs: Sequence[JobSpec], tau: float,
                          machines: Mapping[str, int]) -> bool:
    """Can every job individually finish by `tau`, and does every shared
    tier have room for the jobs FORCED onto it?

    A job is forced onto shared tier T at level `tau` when no other tier
    could finish it by `tau` even running it alone (an optimistic test, so
    the forced set is a subset of the truly forced jobs — the predicate is
    a relaxation and the resulting bound stays valid). Forced jobs must
    all run on T's machines: total work after the earliest forced arrival
    on m machines needs earliest_arrival + work/m <= tau, and each forced
    job needs its own arrival + processing <= tau.
    """
    for j in jobs:
        if min(j.response_if_alone(t) for t in MACHINES) + j.release > tau:
            return False
    for tier in (CC, ES):
        m = machines.get(tier, 1)
        forced_arr, forced_work = [], 0.0
        for j in jobs:
            alone = {t: j.release + j.response_if_alone(t) for t in MACHINES}
            if all(alone[t] > tau for t in MACHINES if t != tier):
                arr = j.release + j.trans[tier]
                if arr + j.proc[tier] > tau:
                    return False
                forced_arr.append(arr)
                forced_work += j.proc[tier]
        if forced_arr and min(forced_arr) + forced_work / m > tau:
            return False
    return True


def load_lower_bound(jobs: Sequence[JobSpec],
                     machines_per_tier: Mapping[str, int] | None = None,
                     tol: float = 1e-6) -> float:
    """Machine-load last-completion bound: a horizon `tau` that no
    schedule can beat because some shared tier cannot absorb the
    processing it is forced to run — sum of forced processing after the
    earliest forced arrival, divided over the tier's machines — where a
    job avoids a machine entirely whenever any other tier could finish it
    alone by `tau`.

    Validity: feasibility at `tau` is a necessary condition for ANY
    assignment to finish by `tau` (the avoid-test ignores queueing, so it
    only under-forces), hence infeasibility at `tau` proves
    last_end > tau for every schedule. The predicate is NOT monotone in
    `tau` (raising the horizon can unforce a cheap early job while the
    expensive late ones stay forced), so bisection converges to *a*
    feasible/infeasible crossing, not necessarily the largest infeasible
    horizon; the returned value is the bisection's infeasible end (or the
    per-job bound when that is already feasible), so it is always a valid
    bound and always >= `jobwise_last_bound`.
    """
    machines = dict(machines_per_tier or {CC: 1, ES: 1})
    lo = jobwise_last_bound(jobs)
    if _forced_load_feasible(jobs, lo, machines):
        return lo
    # infeasible at the per-job bound: grow to a feasible upper horizon
    hi = max(lo, max(j.release for j in jobs) +
             sum(min(j.proc[t] + j.trans[t] for t in MACHINES)
                 for j in jobs))
    while not _forced_load_feasible(jobs, hi, machines):   # pragma: no cover
        hi *= 2.0
    for _ in range(80):
        if hi - lo <= tol:
            break
        mid = 0.5 * (lo + hi)
        if _forced_load_feasible(jobs, mid, machines):
            hi = mid
        else:
            lo = mid
    return lo
