"""Lower bounds for the multi-job problem (paper eq. 6 + tighter extras)."""
from __future__ import annotations

from typing import Sequence

from repro.core.simulator import MACHINES, JobSpec
from repro.core.tiers import CC, ES


def paper_lower_bound(jobs: Sequence[JobSpec],
                      weighted: bool = True) -> float:
    """Eq. (6): every job takes its stand-alone minimum response time."""
    total = 0.0
    for j in jobs:
        best = min(j.response_if_alone(t) for t in MACHINES)
        total += (j.weight if weighted else 1.0) * best
    return total


def load_lower_bound(jobs: Sequence[JobSpec]) -> float:
    """Tighter last-completion bound: a shared machine cannot finish its
    assigned work before the sum of processing times after the earliest
    arrival — minimised over which jobs could avoid that machine entirely.
    Conservative version: max over jobs of their best-case completion."""
    return max(j.release + min(j.response_if_alone(t) for t in MACHINES)
               for j in jobs)
