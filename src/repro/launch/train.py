"""Training launcher.

On real hardware this runs the full configs on the production mesh; on this
CPU container it runs reduced variants end-to-end (the full configs are
exercised by launch/dryrun.py). Examples:

  python -m repro.launch.train --arch qwen2-1.5b --reduced --steps 100
  python -m repro.launch.train --arch mixtral-8x7b --reduced --steps 50 \
      --mesh 1x1 --checkpoint-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import checkpointer
from repro.configs import ARCH_NAMES, get_config
from repro.data.pipeline import MarkovTokenDataset, audio_stub, vision_stub
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.sharding import policy
from repro.training import optimizer, train_loop


def make_batches(cfg, batch, seq, seed=0):
    ds = MarkovTokenDataset(vocab_size=cfg.vocab_size, seq_len=seq,
                            batch_size=batch, seed=seed)
    for b in ds.batches():
        if cfg.family == "vlm":
            b["vision_embeds"] = vision_stub(batch, cfg, seed)
        if cfg.is_encdec:
            b["frames"] = audio_stub(batch, cfg, seed)
        yield b


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family variant (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="host",
                    help="host | prod | prod-multipod")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(d_model=256, vocab=512)
    mesh = {"host": make_host_mesh,
            "prod": make_production_mesh,
            "prod-multipod": lambda: make_production_mesh(multi_pod=True),
            }[args.mesh]()

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = optimizer.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                    warmup_steps=min(20, args.steps // 5))
    opt_state = optimizer.init(params)
    step_fn = train_loop.make_train_step(model, opt_cfg,
                                         microbatches=args.microbatches)

    batches = make_batches(cfg, args.batch, args.seq)
    t0 = time.perf_counter()
    with mesh, policy.activation_policy(mesh):
        for i, batch in zip(range(args.steps), batches):
            params, opt_state, m = step_fn(params, opt_state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e} "
                      f"({(time.perf_counter()-t0)/(i+1):.2f}s/step)")
            if args.checkpoint_dir and args.checkpoint_every and \
                    (i + 1) % args.checkpoint_every == 0:
                checkpointer.save(args.checkpoint_dir, i + 1,
                                  {"params": params})
    if args.checkpoint_dir:
        fn = checkpointer.save(args.checkpoint_dir, args.steps,
                               {"params": params})
        print("saved", fn)


if __name__ == "__main__":
    main()
