import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower and compile every (arch x shape) on the
production mesh, with zero real allocation (ShapeDtypeStruct stand-ins).

For each combination this produces the roofline inputs (EXPERIMENTS.md
§Dry-run / §Roofline):
  * compiled.memory_analysis()  -> per-device bytes (proves it fits)
  * compiled.cost_analysis()    -> HLO FLOPs / bytes accessed
  * collective bytes            -> parsed from the post-SPMD HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute operand sizes)

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all --out experiments/dryrun
  python -m repro.launch.dryrun --all --multi-pod        # 2x16x16 pass
"""
import argparse
import dataclasses
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.sharding import policy
from repro.training import optimizer, train_loop
from repro.utils import flops as flops_util

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def variant_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """long_500k on pure full-attention archs runs the explicit
    sliding-window variant (DESIGN.md §4). Native-SWA / recurrent / hybrid
    archs run unmodified."""
    if shape.name == "long_500k" and cfg.has_quadratic_prefill:
        return dataclasses.replace(cfg, long_context_window=4096)
    return cfg


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.cross_attn_states, cfg.vision_dim), dt)
        if cfg.is_encdec:
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), dt)
        return batch
    # decode: one new token + a seq_len-deep cache
    return {"token": jax.ShapeDtypeStruct((b,), jnp.int32)}


def build_case(cfg: ModelConfig, shape: ShapeConfig, mesh,
               microbatches: int = 1):
    """Returns (fn, arg_specs tuple, in_shardings tuple)."""
    model = build_model(cfg, remat=(shape.kind == "train"))
    p_specs = model.param_specs()
    p_sh = policy.to_shardings(policy.param_specs(p_specs, mesh), mesh)
    batch = input_specs(cfg, shape)
    b_sh = policy.to_shardings(policy.batch_specs(batch, mesh), mesh)

    if shape.kind == "train":
        opt_cfg = optimizer.AdamWConfig()
        o_specs = jax.eval_shape(optimizer.init, p_specs)
        o_sh = policy.to_shardings(policy.param_specs(o_specs, mesh), mesh)
        fn = train_loop.make_train_step(model, opt_cfg, jit=False,
                                        microbatches=microbatches)
        return fn, (p_specs, o_specs, batch), (p_sh, o_sh, b_sh)

    if shape.kind == "prefill":
        def fn(params, b):
            return model.prefill(params, b, max_len=shape.seq_len)
        return fn, (p_specs, batch), (p_sh, b_sh)

    # decode: serve_step = one token against a seq_len cache
    cache_specs = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    c_sh = policy.to_shardings(policy.cache_specs(cache_specs, mesh), mesh)
    tok = batch["token"]
    t_sh = policy.to_shardings(policy.batch_specs(tok, mesh), mesh)

    def fn(params, token, cache):
        return model.decode_step(params, token, cache)

    return fn, (p_specs, tok, cache_specs), (p_sh, t_sh, c_sh)


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in the partitioned HLO."""
    per_op = {op: 0 for op in COLLECTIVE_OPS}
    count = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("%") or stripped.startswith("ROOT"):
            body = stripped.split("=", 1)
            if len(body) != 2:
                continue
            rhs = body[1]
            m = re.search(r"\b(all-gather|all-reduce|reduce-scatter|"
                          r"all-to-all|collective-permute)(-start|-done)?\(",
                          rhs)
            if not m or m.group(2) == "-done":
                continue
            op = m.group(1)
            shape_part = rhs[:m.start()]
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(shape_part):
                if dt not in _DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES[dt]
            per_op[op] += nbytes
            count[op] += 1
    total = sum(per_op.values())
    return {"bytes_by_op": per_op, "count_by_op": count,
            "total_bytes": total}


def memory_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_bytes"] = (out.get("argument_size_in_bytes", 0)
                              + out.get("output_size_in_bytes", 0)
                              + out.get("temp_size_in_bytes", 0)
                              - out.get("alias_size_in_bytes", 0))
    return out


# train_4k gradient-accumulation factors: chosen so the per-device
# activation high-water fits HBM (recorded per-case in the dry-run JSON)
TRAIN_MICROBATCHES = {
    "xlstm-350m": 8, "gemma2-27b": 8, "llama-3.2-vision-11b": 4,
    "zamba2-2.7b": 8, "mixtral-8x7b": 4, "mixtral-8x22b": 8,
    "seamless-m4t-large-v2": 2, "qwen2-1.5b": 2, "mistral-large-123b": 8,
    "gemma-2b": 2,
}


def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, hlo_dir: str | None = None,
             microbatches: int | None = None, moe_ep: bool = False,
             kv_int8: bool = False):
    cfg = variant_for_shape(get_config(arch), INPUT_SHAPES[shape_name])
    shape = INPUT_SHAPES[shape_name]
    if kv_int8:
        if shape.kind != "decode":
            raise ValueError(f"int8 KV is a decode-cache layout, got "
                             f"{shape.kind!r}")
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if moe_ep:
        if not cfg.num_experts or shape.kind == "train":
            raise ValueError("EP MoE is an inference layout "
                             "(dp-replicated expert storage); needs "
                             "num_experts > 0 and a non-train shape")
        model_axis = 16
        if model_axis % cfg.num_experts:
            raise ValueError(f"model axis {model_axis} not a multiple "
                             f"of num_experts {cfg.num_experts}")
        cfg = dataclasses.replace(
            cfg, moe_ep_shards=model_axis // cfg.num_experts)
    if microbatches is None:
        microbatches = TRAIN_MICROBATCHES.get(arch, 1) \
            if shape.kind == "train" else 1
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, specs, shardings = build_case(cfg, shape, mesh, microbatches)

    # residual-stream sharding: sequence-sharded (Megatron SP) for
    # attention-family archs — shrinks remat saves |model|-fold (88-layer
    # mistral-large needs it); replicated for recurrent families, whose
    # chunked state scans need the full sequence locally (seq-sharding
    # forced 11.3 GB/step of L-regathers on xlstm — §Perf iteration 2.5)
    residual = "replicated" if cfg.family in ("ssm", "hybrid") else "seq"
    t0 = time.perf_counter()
    with mesh, policy.activation_policy(mesh, residual=residual):
        lowered = jax.jit(fn, in_shardings=shardings).lower(*specs)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()

    mem = memory_dict(compiled)
    try:
        cost = compiled.cost_analysis() or {}
    except Exception:
        cost = {}
    if isinstance(cost, (list, tuple)):    # jax >= 0.4.30: list of per-
        cost = cost[0] if cost else {}     # computation dicts
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'2x16x16' if multi_pod else '16x16'}"
        with open(os.path.join(hlo_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(np.prod(list(mesh.shape.values()))),
        "step_kind": shape.kind,
        "lower_seconds": round(t1 - t0, 2),
        "compile_seconds": round(t2 - t1, 2),
        "hlo_flops": float(cost.get("flops", -1.0)),
        "hlo_bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "memory": mem,
        "collectives": coll,
        "param_count": flops_util.param_count(cfg),
        "active_param_count": flops_util.active_param_count(cfg),
        "param_bytes": flops_util.param_bytes(cfg),
        "analytic_step_flops": flops_util.step_flops(cfg, shape),
        "model_flops_6nd": flops_util.model_flops_6nd(cfg, shape),
        "long_context_variant": cfg.long_context_window is not None,
        "microbatches": microbatches,
        "moe_ep": bool(cfg.moe_ep_shards),
        "kv_cache_dtype": cfg.kv_cache_dtype,
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} on {record['mesh']}: "
              f"lower {record['lower_seconds']}s "
              f"compile {record['compile_seconds']}s "
              f"HLO_GFLOPs {record['hlo_flops']/1e9:.1f} "
              f"collective_MB {coll['total_bytes']/1e6:.1f}")
        if mem:
            print(f"  memory_analysis: {json.dumps(mem)}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-ep", action="store_true",
                    help="expert-parallel MoE layout (inference shapes)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()

    cases = ([(args.arch, args.shape)] if not args.all else
             [(a, s) for a in ARCH_NAMES for s in INPUT_SHAPES])
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cases:
        tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
        try:
            rec = run_case(arch, shape, multi_pod=args.multi_pod,
                           hlo_dir=args.hlo_dir, moe_ep=args.moe_ep)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((tag, repr(e)))
            print(f"[dryrun] FAIL {tag}: {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print(f"[dryrun] all {len(cases)} cases compiled OK")


if __name__ == "__main__":
    main()
