"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run (launch/dryrun.py) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import
so these meshes can be built on the CPU-only container.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip pod ("data","model"); 2x16x16 = 512-chip 2-pod
    ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
