"""Hierarchical serving driver — the paper's technique as a first-class
feature.

Multi-patient ICU inference requests (the paper's three LSTM applications,
with priorities and release times) are placed on cloud/edge/device tiers by
core.scheduler (Algorithm 2) and then EXECUTED: the LSTM inferences really
run (Pallas lstm_cell path on TPU, oracle on CPU), while tier compute-speed
ratios and network transfer times come from the calibrated cost model. The
driver reports per-job response times under our allocation vs the paper's
four baseline strategies.

  python -m repro.launch.serve --patients 10 --horizon 30 --seed 0
  python -m repro.launch.serve --tiers tpu          # TPU-fleet tier specs
  python -m repro.launch.serve --wards 16           # multi-hospital fleet:
                                                    # one batched device call
                                                    # plans every ward
  python -m repro.launch.serve --metro              # streaming metro load:
                                                    # hours of episodes vs
                                                    # failures, policy table
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import zlib

import jax
import numpy as np

from repro.configs.icu_lstm import ICU_WORKLOADS
from repro.core import scheduler
from repro.core.cost_model import CalibratedCostModel
from repro.core.lower_bound import paper_lower_bound
from repro.core.problems import jobs_to_specs, patient_jobs
from repro.core.tiers import CC, ED, ES, paper_tiers, tpu_tiers
from repro.data import icu
from repro.models.lstm import ICULSTM
from repro.serving.engine import ClassifierEngine


def calibrate(tiers, engines, unit_records: int = 16):
    """The paper's Algorithm 1 steps 2-8: measure a small dataset once,
    derive per-(workload, tier) unit costs. Processing time is measured on
    THIS host and scaled by the tier FLOPS ratio; transmission uses the
    tier network function and the real record sizes."""
    host_flops = tiers[ED].flops
    unit_proc, unit_trans = {}, {}
    for wl_cfg, engine in engines.items():
        x, _ = icu.generate(wl_cfg, unit_records, seed=1)
        engine.infer(jax.numpy.asarray(x))                 # warm up / compile
        _, seconds = engine.infer(jax.numpy.asarray(x))
        per_unit = seconds / unit_records
        rec_bytes = icu.record_bytes(wl_cfg)
        for tid, tier in tiers.items():
            unit_proc[(wl_cfg.name, tid)] = per_unit * host_flops / tier.flops
            unit_trans[(wl_cfg.name, tid)] = 0.0 if tier.private else (
                tier.net_latency + rec_bytes / tier.net_bw)
    return CalibratedCostModel(tiers, unit_proc, unit_trans)


# Each patient's end device releases one random ICU job in [0, horizon).
# The generator lives in core.problems so serve and benchmarks draw from
# ONE scenario library; the old name stays bound for callers/tests.
make_jobs = patient_jobs


def _setup_fleet(tiers_kind, cloud_machines, edge_machines):
    """Shared single-ward / --wards setup: tier specs (with machine-count
    overrides), real models + engines (the compute that actually runs;
    keys are stable across processes — crc32, not PYTHONHASHSEED-salted
    hash() — so --seed really reproduces a run), and the calibrated cost
    model. -> (tiers, machines_per_tier, engines, cost_model)."""
    tiers = paper_tiers() if tiers_kind == "paper" else tpu_tiers()
    for tid, count in ((CC, cloud_machines), (ES, edge_machines)):
        if count is not None:
            tiers[tid] = dataclasses.replace(tiers[tid], machines=count)
    machines_per_tier = {tid: t.machines for tid, t in tiers.items()
                         if not t.private}
    engines = {}
    for wl_cfg in ICU_WORKLOADS:
        model = ICULSTM(wl_cfg)
        key = jax.random.PRNGKey(zlib.crc32(wl_cfg.name.encode()))
        engines[wl_cfg] = ClassifierEngine(model, model.init(key))
    return tiers, machines_per_tier, engines, calibrate(tiers, engines)


def _validate_quantum(quantum) -> None:
    """An explicit quantum must be a positive time unit. (``quantum or
    min(...)`` silently replaced an explicit 0.0 with the derived default —
    a ``None`` check keeps falsy-but-explicit values visible and rejected.)
    """
    if not quantum > 0:
        raise ValueError(f"quantum must be > 0, got {quantum!r}")


def run(patients=10, horizon=30.0, seed=0, tiers_kind="paper",
        execute=True, quantum=None, verbose=True, jax_threshold=None,
        cloud_machines=None, edge_machines=None):
    """jax_threshold: fleets larger than this replan on the jitted JAX
    search (scheduler.search dispatch; default auto — accelerator only).
    cloud_machines / edge_machines: override the shared-server count of a
    tier (TierSpec.machines is honored by every strategy)."""
    rng = np.random.default_rng(seed)
    tiers, machines_per_tier, engines, cost_model = _setup_fleet(
        tiers_kind, cloud_machines, edge_machines)
    jobs = make_jobs(rng, patients, horizon)
    if quantum is None:
        quantum = min(
            min(cost_model.times(j)[t][1] for t in tiers) for j in jobs)
    _validate_quantum(quantum)
    specs = jobs_to_specs(cost_model, jobs, normalize=quantum)

    table = scheduler.strategy_table(specs, jax_threshold=jax_threshold,
                                     machines_per_tier=machines_per_tier)
    lb = paper_lower_bound(specs)
    results = {}
    if verbose:
        print(f"{'strategy':26s} {'weighted':>9s} {'unweighted':>10s} "
              f"{'last':>6s}  (time unit = {quantum*1e3:.3f} ms)")
    for name, sched in table.items():
        results[name] = sched
        if verbose:
            print(f"{name:26s} {sched.weighted_sum:9.0f} "
                  f"{sched.unweighted_sum:10.0f} {sched.last_end:6.0f}")
    if verbose:
        print(f"{'lower bound (eq.6)':26s} {lb:9.0f}")

    if execute:
        ours = results["ours (algorithm 2)"]
        if verbose:
            print("\nexecuting our schedule (real LSTM inference per job):")
        for entry in sorted(ours.entries, key=lambda e: e.start):
            # the spec carries its workload name (no display-string parsing)
            wl_cfg = next(w for w in ICU_WORKLOADS
                          if w.name == entry.job.workload)
            x, _ = icu.generate(wl_cfg, 8, seed=int(entry.start) + 1)
            _, seconds = engines[wl_cfg].infer(jax.numpy.asarray(x))
            if verbose:
                print(f"  {entry.job.name:32s} -> {entry.machine:6s} "
                      f"[start {entry.start:4.0f}, end {entry.end:4.0f}] "
                      f"real_infer {seconds*1e3:6.1f} ms")
    return results, lb


def run_wards(wards=4, patients=10, horizon=30.0, seed=0,
              tiers_kind="paper", quantum=None, verbose=True,
              cloud_machines=None, edge_machines=None, min_batch=None,
              contention=False, max_sweeps=8):
    """Multi-hospital fleet mode: plan `wards` ward instances in ONE
    batched device call (scheduler.search_batched, DESIGN.md §8).

    The metropolitan cloud spec is shared — every ward sees the same
    cloud machine count — while each ward owns its edge servers and its
    patients' end devices. Calibration runs once (the cost model
    describes the shared hardware), and one quantum (the fleet-wide
    minimum) keeps every ward's time unit comparable.

    contention=False (default): planning is per-ward independent — a ward
    optimises against the full cloud fleet, so B wards silently
    double-book the shared cloud servers and the per-ward numbers are
    only achievable one ward at a time.

    contention=True (DESIGN.md §9): additionally rescore the independent
    plans with the fleet-true evaluator (`simulate_fleet` — one merged
    shared-cloud FIFO queue) and run `scheduler.search_fleet`'s
    contention-aware fixed-point sweeps; reports the naive claimed
    scores, the fleet-true scores, the contention gap, and the gap
    recovered.

    Returns (list of per-ward Schedules, wall seconds of the planning
    call) — in contention mode, the per-ward schedules of the fleet-true
    plan (entries carry merged-queue times) and a third element, the
    FleetPlan."""
    rng = np.random.default_rng(seed)
    tiers, machines_per_tier, _, cost_model = _setup_fleet(
        tiers_kind, cloud_machines, edge_machines)

    ward_jobs = [make_jobs(rng, patients, horizon) for _ in range(wards)]
    if quantum is None:
        quantum = min(
            min(cost_model.times(j)[t][1] for t in tiers)
            for jobs in ward_jobs for j in jobs)
    _validate_quantum(quantum)
    ward_specs = [jobs_to_specs(cost_model, jobs, normalize=quantum)
                  for jobs in ward_jobs]

    import time
    if contention:
        # warm the naive batched search's compile cache at the real shape
        # (max_sweeps=0 plans nothing beyond the naive stage), so the
        # reported time is planning throughput, not XLA tracing — same
        # policy as the independent-mode branch below
        scheduler.search_fleet(
            ward_specs, machines_per_tier=machines_per_tier,
            min_batch=min_batch, max_count=1, max_sweeps=0)
        t0 = time.perf_counter()
        plan = scheduler.search_fleet(
            ward_specs, machines_per_tier=machines_per_tier,
            min_batch=min_batch, max_sweeps=max_sweeps)
        seconds = time.perf_counter() - t0
        if verbose:
            print(f"{'ward':>4s} {'jobs':>5s} {'naive':>9s} "
                  f"{'fleet-true':>10s}  (time unit = {quantum*1e3:.3f} ms)")
            for i, (naive_s, fleet_s) in enumerate(
                    zip(plan.naive_fleet.wards, plan.fleet.wards)):
                print(f"{i:4d} {len(fleet_s.entries):5d} "
                      f"{naive_s.weighted_sum:9.0f} "
                      f"{fleet_s.weighted_sum:10.0f}")
            print(f"independent plans claim   {plan.naive_reported:9.0f}")
            print(f"  ...but really score     "
                  f"{plan.naive_fleet.weighted_sum:9.0f} on the shared "
                  f"fleet (contention gap {plan.contention_gap:.3f}x)")
            print(f"fleet-true after {plan.sweeps} sweeps: "
                  f"{plan.fleet.weighted_sum:9.0f} "
                  f"({plan.gap_closed:.0%} of the gap recovered) "
                  f"in {seconds*1e3:.1f} ms")
        return plan.fleet.wards, seconds, plan

    # compile once at the real (B, n_max, fleet) shape so the reported
    # rate is the steady-state replanning throughput, not XLA tracing;
    # the sequential fallback path compiles nothing, so skip the warm-up
    threshold = (scheduler.BATCHED_SEARCH_MIN_WARDS if min_batch is None
                 else min_batch)
    if wards >= threshold:
        scheduler.search_batched(ward_specs, max_count=1,
                                 machines_per_tier=machines_per_tier,
                                 min_batch=min_batch)
    t0 = time.perf_counter()
    schedules = scheduler.search_batched(
        ward_specs, machines_per_tier=machines_per_tier,
        min_batch=min_batch)
    seconds = time.perf_counter() - t0
    if verbose:
        print(f"{'ward':>4s} {'jobs':>5s} {'weighted':>9s} "
              f"{'unweighted':>10s} {'last':>6s}  "
              f"(time unit = {quantum*1e3:.3f} ms)")
        for i, s in enumerate(schedules):
            print(f"{i:4d} {len(s.entries):5d} {s.weighted_sum:9.0f} "
                  f"{s.unweighted_sum:10.0f} {s.last_end:6.0f}")
        total = sum(s.weighted_sum for s in schedules)
        print(f"fleet total weighted {total:.0f}; planned {wards} wards "
              f"in {seconds*1e3:.1f} ms ({wards/seconds:.1f} wards/s)")
    return schedules, seconds


def _trace_path(base: str, policy: str, multi: bool) -> str:
    """Per-policy trace file name: the given path verbatim for a single
    policy, `name.<policy>.ext` when several policies share one run."""
    if not multi:
        return base
    root, dot, ext = base.rpartition(".")
    return f"{root}.{policy}.{ext}" if dot else f"{base}.{policy}"


def run_metro(wards=None, hours=None, seed=0, cloud_machines=2,
              edge_machines=2, policies=("greedy", "tabu", "fleet"),
              verbose=True, jax_threshold=None, scenario="default",
              check_determinism=False, hedge=False, hedge_factor=1.5,
              retry_backoff=0.0, max_attempts=None, sanitize=False,
              trace=None, trace_format="jsonl", postmortem=False,
              postmortem_out=None, metrics_out=None):
    """Metro traffic mode (DESIGN.md §10-§11): streaming patient-episode
    traffic over a ward fleet sharing one metropolitan cloud, replayed
    under each policy on identical traces, failures (drain or crash),
    fail-slow slowdown windows, degraded-network windows and
    elastic-capacity events. `scenario` names a chaos pack from
    `metro.traces.SCENARIO_PACKS`; `wards` and `hours` default to the
    pack's canonical shape. Prints the policy comparison (p50/p99
    response, SLA miss-rate overall / life-critical / shed, per-tier
    utilisation with the crash-retry and wasted-work counts broken out
    per tier, engine events/s) and returns {policy: summary dict}.

    hedge=True wraps every policy in the deadline-aware HedgingPolicy
    and arms the engine's straggler watchdog at `hedge_factor` x the
    committed proc time (DESIGN.md §13); the table gains hedge/win/
    hedge-waste columns. retry_backoff / max_attempts bound crash
    retries (exponential backoff, shed-with-record past the cap).

    sanitize=True arms the engine's runtime invariant sanitizer
    (DESIGN.md §14) on every run: FIFO dispatch order, slot
    double-booking, C2 immutability, event-time monotonicity, hedge
    uniqueness, terminal accounting and capacity bounds are validated
    per event, and the run fails on the first violation. The sanitizer
    is read-only, so sanitized event logs hash bit-identically.

    check_determinism=True replays every policy twice on a fresh engine
    and raises unless the event logs hash identically — the seeded-chaos
    determinism contract (DESIGN.md §11). The search backend is pinned
    to the Python path when no jax_threshold is given, because the
    compiled-shape cache is call-order-dependent across runs in one
    process (see metro.engine's determinism note). The verification
    rerun is UNTRACED, so with `trace` set the hash comparison doubles
    as a live traced-vs-untraced CRC-parity check (DESIGN.md §15).

    trace=PATH arms the flight recorder (DESIGN.md §15) and writes each
    policy's span stream there — `trace_format` "jsonl" (one span per
    line) or "chrome" (trace-event JSON, opens in Perfetto); several
    policies write `name.<policy>.ext` each. postmortem=True prints the
    deadline-miss blame table (exact per-job response decomposition into
    retry-waste / wait / transmit / service / slowdown) plus the engine
    self-profile; postmortem_out=PATH exports the same as JSON.
    metrics_out=PATH dumps the full per-policy summary dicts (every
    MetroMetrics.summary() column, incl. per-tier retry/waste/hedge
    breakdowns, p99.9s and the windowed recent_* snapshot) as JSON.

    One trace time unit reads as one minute; episodes are the paper's
    three-app cascade with per-class response deadlines
    (metro.traces.EPISODE_STAGES). Unlike the finite single-shot modes
    above, nothing here is scored once — schedules are committed event
    by event against the chaos timeline, which is the regime the
    ROADMAP's sustained-load north star asks for."""
    from repro.metro import HedgingPolicy, make_policy, simulate_metro, traces

    if check_determinism and jax_threshold is None:
        jax_threshold = 10 ** 9          # always the Python search path
    horizon = None if hours is None else hours * 60.0
    sc = traces.make_scenario(scenario, seed, wards=wards, horizon=horizon)
    wards = len(sc.traces)
    mpt = {CC: cloud_machines, ES: edge_machines}
    # fleet's joint fixed point gets small per-event budgets: each event
    # only needs local repair on top of the previous one (DESIGN.md §10).
    # jax_threshold pins the search backend of the replanning policies
    # (greedy/shed never search) — pass it for call-order-independent
    # runs (see metro.engine's determinism note).
    kwargs = {"fleet": dict(max_count=2, max_sweeps=1,
                            jax_threshold=jax_threshold),
              "tabu": dict(jax_threshold=jax_threshold)}

    want_trace = trace is not None or postmortem or \
        postmortem_out is not None
    want_profile = postmortem or postmortem_out is not None

    def one_run(name, traced=False):
        # a fresh policy per run: policies may carry stream state (the
        # shedding wrapper's running max weight, the hedging wrapper's)
        pol = make_policy(name, **kwargs.get(name, {}))
        eng_kw = {}
        if hedge:
            pol = HedgingPolicy(inner=pol)
            eng_kw["hedge_factor"] = hedge_factor
        return simulate_metro(
            sc.traces, pol, machines_per_tier=mpt, failures=sc.failures,
            scale_events=sc.scales, network_events=sc.network,
            slowdowns=sc.slowdowns, retry_backoff=retry_backoff,
            max_attempts=max_attempts, sanitize=sanitize,
            trace=traced, profile=traced and want_profile, **eng_kw)

    if verbose:
        kills = sum(f.kill_running for f in sc.failures)
        print(f"metro[{sc.name}]: {wards} wards, {sc.jobs} episode-stage "
              f"jobs, {len(sc.failures)} failures ({kills} crash), "
              f"{len(sc.slowdowns)} slowdown windows, "
              f"{len(sc.scales)} scale events, {len(sc.network)} network "
              f"windows, fleet {cloud_machines}c/{edge_machines}e per ward"
              + (f", hedging at {hedge_factor:g}x" if hedge else ""))
        hedge_cols = (f" {'hedge':>5s} {'win':>4s} {'hwaste':>6s}"
                      if hedge else "")
        print(f"{'policy':8s} {'p50':>6s} {'p95':>6s} {'p99':>6s} "
              f"{'p99.9':>6s} {'miss%':>6s} {'crit%':>6s} {'shed%':>6s} "
              f"{'cloud':>6s} {'rtry':>4s} {'waste':>6s} "
              f"{'edge':>6s} {'rtry':>4s} {'waste':>6s}"
              f"{hedge_cols} {'events/s':>9s}")
    out = {}
    traced_runs = {}
    for name in policies:
        res = one_run(name, traced=want_trace)
        log_hash = zlib.crc32(repr(res.event_log).encode())
        if check_determinism:
            rerun_hash = zlib.crc32(repr(one_run(name).event_log).encode())
            if rerun_hash != log_hash:
                raise AssertionError(
                    f"metro[{sc.name}]/{name}: event log not "
                    f"deterministic across reruns ({log_hash:#x} vs "
                    f"{rerun_hash:#x})")
        s = res.summary()
        s["event_log_hash"] = log_hash
        # global cumulative §3.3 shape-cache counters at this point of
        # the process — evictions staying 0 is a gate invariant, so it
        # belongs where users look, not only in the benchmark
        s["compiled_shapes"] = scheduler.compiled_shape_stats()
        out[name] = s
        if res.trace is not None:
            traced_runs[name] = res
        if verbose:
            util = s["utilization"]
            rbt, wbt = s["retries_by_tier"], s["wasted_by_tier"]
            hedge_cells = (f" {s['hedges']:5d} {s['hedge_wins']:4d} "
                           f"{s['hedge_waste']:6.1f}" if hedge else "")
            print(f"{name:8s} {s['p50']:6.1f} {s['p95']:6.1f} "
                  f"{s['p99']:6.1f} {s['p999']:6.1f} "
                  f"{s['miss_rate']:6.2%} "
                  f"{s['critical_miss_rate']:6.2%} {s['shed_rate']:6.2%} "
                  f"{util.get('cloud', 0.0):6.1%} "
                  f"{rbt.get('cloud', 0):4d} {wbt.get('cloud', 0.0):6.1f} "
                  f"{util.get('edge', 0.0):6.1%} "
                  f"{rbt.get('edge', 0):4d} {wbt.get('edge', 0.0):6.1f}"
                  f"{hedge_cells} "
                  f"{s['events_per_s']:9.0f}")
    if verbose and check_determinism:
        print(f"determinism: {len(out)} policies x 2 runs, event logs "
              f"bit-identical")
    if verbose and "greedy" in out and "tabu" in out:
        # same semantics as benchmarks.scheduler_scale.bench_metro: the
        # ratio is vacuous when greedy itself misses nothing, and a
        # perfect tabu run is floored at half a missed job
        g, t = out["greedy"]["miss_rate"], out["tabu"]["miss_rate"]
        if g == 0:
            print("tabu-replan miss-rate improvement vs greedy: vacuous "
                  "(greedy missed no deadlines)")
        else:
            jobs_done = max(out["greedy"]["completions"], 1)
            print(f"tabu-replan miss-rate improvement vs greedy: "
                  f"{g / max(t, 0.5 / jobs_done):.2f}x")
    if verbose:
        cs = scheduler.compiled_shape_stats()
        print(f"compiled shapes: size={cs['size']} hits={cs['hits']} "
              f"misses={cs['misses']} evictions={cs['evictions']}")
    if trace is not None:
        multi = len(traced_runs) > 1
        for name, res in traced_runs.items():
            path = _trace_path(trace, name, multi)
            n = res.trace.write(path, trace_format)
            if verbose:
                unit = "events" if trace_format == "chrome" else "spans"
                print(f"trace[{name}]: {n} {unit} ({trace_format}) "
                      f"-> {path}")
    if postmortem and verbose:
        for name, res in traced_runs.items():
            print(res.trace.format_postmortem(
                name, res.profile,
                out[name].get("compiled_shapes")))
    if postmortem_out is not None:
        report = {name: res.trace.postmortem_json(
            name, res.profile, out[name].get("compiled_shapes"))
            for name, res in traced_runs.items()}
        with open(postmortem_out, "w") as f:
            json.dump(report, f, indent=2)
        if verbose:
            print(f"postmortem JSON -> {postmortem_out}")
    if metrics_out is not None:
        with open(metrics_out, "w") as f:
            json.dump(out, f, indent=2)
        if verbose:
            print(f"metrics JSON -> {metrics_out}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--patients", type=int, default=10)
    ap.add_argument("--horizon", type=float, default=30.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tiers", choices=("paper", "tpu"), default="paper")
    ap.add_argument("--no-execute", action="store_true")
    ap.add_argument("--jax-threshold", type=int, default=None,
                    help="force the jitted JAX search above this many jobs "
                         "(default: auto — accelerator backends only)")
    ap.add_argument("--cloud-machines", type=int, default=None,
                    help="shared cloud servers (default: TierSpec.machines)")
    ap.add_argument("--edge-machines", type=int, default=None,
                    help="shared edge servers (default: TierSpec.machines)")
    ap.add_argument("--wards", type=int, default=0,
                    help="multi-hospital mode: plan this many wards in one "
                         "batched device call (shared cloud, per-ward "
                         "edge/device fleets); 0 = single-ward mode")
    ap.add_argument("--contention", action="store_true",
                    help="with --wards: score plans on the REAL shared "
                         "cloud (merged FIFO queue) and run the "
                         "contention-aware fixed-point search; reports "
                         "naive vs fleet-true scores and the gap "
                         "(DESIGN.md §9)")
    ap.add_argument("--metro", action="store_true",
                    help="streaming metro traffic mode: hours of "
                         "patient-episode load over a shared-cloud ward "
                         "fleet with failures and elastic capacity, "
                         "compared across replanning policies "
                         "(DESIGN.md §10)")
    ap.add_argument("--metro-hours", type=float, default=None,
                    help="simulated hours of metro traffic (default: the "
                         "scenario pack's canonical horizon)")
    ap.add_argument("--scenario", default="default",
                    help="chaos scenario pack for --metro "
                         "(metro.traces.SCENARIO_PACKS: default, "
                         "edge_brownout, mass_casualty_crash, "
                         "degraded_network, diurnal_day, fail_slow_tail)")
    ap.add_argument("--hedge", action="store_true",
                    help="with --metro: wrap every policy in the "
                         "deadline-aware hedging wrapper and arm the "
                         "straggler watchdog (DESIGN.md §13)")
    ap.add_argument("--hedge-factor", type=float, default=1.5,
                    help="watchdog threshold: hedge once elapsed runtime "
                         "exceeds this multiple of the committed proc "
                         "time (default 1.5)")
    ap.add_argument("--retry-backoff", type=float, default=0.0,
                    help="base delay for exponential crash-retry backoff "
                         "(0 = immediate re-dispatch, the legacy path)")
    ap.add_argument("--max-attempts", type=int, default=None,
                    help="cap on attempts per job; past it the job is "
                         "shed-with-record (default: unbounded)")
    ap.add_argument("--metro-policies", default="greedy,tabu,fleet",
                    help="comma-separated policy list for --metro "
                         "(greedy, tabu, fleet, shed)")
    ap.add_argument("--check-determinism", action="store_true",
                    help="with --metro: run every policy twice and fail "
                         "unless the event logs are bit-identical "
                         "(DESIGN.md §11)")
    ap.add_argument("--sanitize", action="store_true",
                    help="with --metro: run the engine with the runtime "
                         "invariant sanitizer armed (FIFO dispatch, no "
                         "slot double-booking, C2 immutability, ... — "
                         "DESIGN.md §14); fails on the first violation")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="with --metro: arm the flight recorder and "
                         "write per-job span streams here (per-policy "
                         "suffix when several policies run — "
                         "DESIGN.md §15)")
    ap.add_argument("--trace-format", choices=("jsonl", "chrome"),
                    default="jsonl",
                    help="trace file format: jsonl spans, or Chrome "
                         "trace-event JSON for Perfetto/chrome://tracing")
    ap.add_argument("--postmortem", action="store_true",
                    help="with --metro: print the deadline-miss blame "
                         "table (exact response-time decomposition per "
                         "class x tier) and the engine self-profile")
    ap.add_argument("--postmortem-out", default=None, metavar="PATH",
                    help="write the postmortem attribution report "
                         "(per-job terms, blame table, profile) as JSON")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="with --metro: dump the full per-policy "
                         "MetroMetrics.summary() dicts as JSON")
    args = ap.parse_args()
    if args.contention and args.wards <= 0:
        ap.error("--contention requires --wards N (N > 0)")
    if args.metro:
        run_metro(wards=args.wards or None, hours=args.metro_hours,
                  seed=args.seed,
                  cloud_machines=args.cloud_machines or 2,
                  edge_machines=args.edge_machines or 2,
                  policies=tuple(
                      p for p in args.metro_policies.split(",") if p),
                  jax_threshold=args.jax_threshold,
                  scenario=args.scenario,
                  check_determinism=args.check_determinism,
                  hedge=args.hedge, hedge_factor=args.hedge_factor,
                  retry_backoff=args.retry_backoff,
                  max_attempts=args.max_attempts,
                  sanitize=args.sanitize,
                  trace=args.trace, trace_format=args.trace_format,
                  postmortem=args.postmortem,
                  postmortem_out=args.postmortem_out,
                  metrics_out=args.metrics_out)
    elif args.wards > 0:
        run_wards(wards=args.wards, patients=args.patients,
                  horizon=args.horizon, seed=args.seed,
                  tiers_kind=args.tiers,
                  cloud_machines=args.cloud_machines,
                  edge_machines=args.edge_machines,
                  contention=args.contention)
    else:
        run(patients=args.patients, horizon=args.horizon, seed=args.seed,
            tiers_kind=args.tiers, execute=not args.no_execute,
            jax_threshold=args.jax_threshold,
            cloud_machines=args.cloud_machines,
            edge_machines=args.edge_machines)


if __name__ == "__main__":
    main()
