"""The paper's allocator on the TPU fleet and the 10 assigned LLM archs.

Where the ICU LSTMs are tiny (the device tier always wins under physical
constants), LLM inference exposes the paper's real trade-off surface:
prefill jobs are compute-bound (cloud pod wins despite DCN transfer),
decode jobs are latency/memory-bound (edge/device wins), and the
roofline cost model (beyond-paper) re-ranks tiers vs the FLOPS-only one.

    PYTHONPATH=src python examples/llm_fleet_allocation.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.core.allocator import allocate_single
from repro.core.cost_model import (AnalyticCostModel, Job,
                                   RooflineCostModel, Workload)
from repro.core.tiers import tpu_tiers
from repro.utils import flops as F


def job_for(arch, shape_name, kind):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    seq = shape.seq_len
    comp = F.forward_flops(cfg, 1, seq, kind)
    bytes_in = seq * 4 if kind != "decode" else 64     # prompt vs one token
    # HBM bytes per request for the roofline model (decode: weights+KV read)
    hbm = F.param_bytes(cfg) * (1 if kind == "decode" else 0.1)
    return Job(Workload(f"{arch}:{shape_name}", comp=comp,
                        unit_bytes=bytes_in, hbm_bytes=hbm), size=1.0,
               name=f"{arch}:{shape_name}")


def main():
    tiers = tpu_tiers(cloud_chips=512, edge_chips=16, device_chips=1)
    paper_cm = AnalyticCostModel(tiers)
    roof_cm = RooflineCostModel(tiers)

    print(f"{'job':44s} {'paper->':>8s} {'T_ms':>9s} {'roofline->':>11s} "
          f"{'T_ms':>9s}")
    disagreements = 0
    for arch in ARCH_NAMES:
        for shape_name, kind in (("prefill_32k", "prefill"),
                                 ("decode_32k", "decode")):
            job = job_for(arch, shape_name, kind)
            a1 = allocate_single(paper_cm, job)
            a2 = allocate_single(roof_cm, job)
            disagreements += a1.tier != a2.tier
            print(f"{job.name:44s} {a1.tier:>8s} {a1.response*1e3:9.3f} "
                  f"{a2.tier:>11s} {a2.response*1e3:9.3f}")
    print(f"\nFLOPS-only vs roofline cost model disagreements: "
          f"{disagreements}/20 — the memory term re-ranks decode jobs "
          f"(EXPERIMENTS.md §Beyond-paper)")


if __name__ == "__main__":
    main()
