"""Long-context decode across architecture families (the long_500k story
at CPU-runnable scale).

Compares decode state growth: recurrent archs (xlstm) carry O(1) state,
SWA archs (mixtral) carry O(window), full-attention archs carry O(context)
— the reason long_500k is restricted to sub-quadratic archs (DESIGN.md §4).

    PYTHONPATH=src python examples/long_context_decode.py
"""
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.data.pipeline import make_batch
from repro.models import build_model


def cache_bytes(cache):
    return sum(np.prod(l.shape) * l.dtype.itemsize
               for l in jax.tree.leaves(cache))


def main():
    ctx = 512   # stand-in for 500k at CPU scale; scaling is the point
    for arch, note in (("xlstm-350m", "recurrent: O(1) state"),
                       ("zamba2-2.7b", "hybrid: O(1) mamba + shared KV"),
                       ("mixtral-8x7b", "SWA: O(window) ring buffer"),
                       ("qwen2-1.5b", "full attention: O(context) KV")):
        cfg = get_config(arch).reduced(
            layers=2 if len(get_config(arch).group_pattern) <= 2 else None,
            d_model=128, vocab=256)
        if arch == "mixtral-8x7b":
            import dataclasses
            cfg = dataclasses.replace(cfg, attn_window=64)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sizes = []
        for c in (ctx // 4, ctx // 2, ctx):
            batch = make_batch(cfg, 1, c, seed=1)
            _, cache = model.prefill(params, batch, max_len=c + 8)
            sizes.append(cache_bytes(cache))
        t0 = time.perf_counter()
        tok = batch["tokens"][:, -1]
        for _ in range(4):
            logits, cache = model.decode_step(params, tok, cache)
            tok = jax.numpy.argmax(logits, -1).astype(jax.numpy.int32)
        dt = (time.perf_counter() - t0) / 4
        growth = sizes[-1] / sizes[0]
        print(f"{arch:16s} cache@{ctx//4}/{ctx//2}/{ctx} tokens = "
              f"{sizes[0]//1024}/{sizes[1]//1024}/{sizes[2]//1024} KiB "
              f"(x{growth:.1f})  decode {dt*1e3:.0f} ms/tok  <- {note}")


if __name__ == "__main__":
    main()
