"""END-TO-END DRIVER — the paper's scenario, fully executed.

Multi-patient ICU room: each patient's end device releases inference jobs
(short-of-breath alerts w=2, life-death prediction w=2, phenotype
classification w=1) over real synthetic MIMIC-like time series. The
pipeline is the paper's, end to end:

  1. train the three LSTM models (offline phase, 'on the cloud');
  2. calibrate the cost model on a small dataset (Algorithm 1, steps 2-8);
  3. allocate + schedule the job stream with Algorithm 2;
  4. execute the schedule — every inference really runs;
  5. compare against the paper's four baseline strategies.

    PYTHONPATH=src python examples/serve_hierarchical.py --patients 12
"""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs.icu_lstm import ICU_WORKLOADS
from repro.data import icu
from repro.launch import serve
from repro.models.lstm import ICULSTM
from repro.training import train_loop


def train_offline(steps=60):
    """The paper's offline phase: train each ICU model (here on CPU; in the
    paper, on the cloud server) and report accuracy on held-out data."""
    print("=== offline phase: training the three ICU models ===")
    for wl in ICU_WORKLOADS:
        model = ICULSTM(wl)
        params = model.init(jax.random.PRNGKey(0))
        x, y = icu.generate(wl, 256, seed=0)

        def batches():
            rng = np.random.default_rng(0)
            while True:
                idx = rng.integers(0, 256, 32)
                yield {"features": jnp.asarray(x[idx]),
                       "labels": jnp.asarray(y[idx])}

        params, _, hist = train_loop.train(model, params, batches(),
                                           steps=steps, log_every=steps,
                                           log_fn=lambda *_: None)
        xt, yt = icu.generate(wl, 128, seed=9)
        logits = model.forward(params, jnp.asarray(xt))
        if wl.num_classes == 25:
            acc = float(jnp.mean((logits > 0) == jnp.asarray(yt)))
        else:
            acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yt)))
        print(f"  {wl.name:36s} loss {hist[0][1]:.3f}->{hist[-1][1]:.3f} "
              f"acc {acc:.2%}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--patients", type=int, default=12)
    ap.add_argument("--horizon", type=float, default=40.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tiers", choices=("paper", "tpu"), default="paper")
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()

    if not args.skip_train:
        train_offline()

    print("\n=== online phase: allocation + scheduling + execution ===")
    serve.run(patients=args.patients, horizon=args.horizon, seed=args.seed,
              tiers_kind=args.tiers, execute=True)


if __name__ == "__main__":
    main()
