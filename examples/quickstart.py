"""Quickstart: train a small LM on synthetic bigram data, then serve it.

    PYTHONPATH=src python examples/quickstart.py [--steps 200] [--arch qwen2-1.5b]

Runs a reduced variant on CPU; on TPU hardware drop --reduced-style sizes
and use launch/train.py with the production mesh.
"""
import argparse
import sys

import jax

sys.path.insert(0, "src")

from repro.configs import ARCH_NAMES, get_config
from repro.data.pipeline import MarkovTokenDataset, make_batch
from repro.models import build_model
from repro.serving.engine import ServingEngine
from repro.training import optimizer, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(layers=2, d_model=128, vocab=128)
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.num_layers} "
          f"d_model={cfg.d_model}")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    ds = MarkovTokenDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            batch_size=args.batch)
    print(f"true process entropy: {ds.entropy_floor:.3f} nats")
    opt_cfg = optimizer.AdamWConfig(total_steps=args.steps, warmup_steps=20)
    params, _, hist = train_loop.train(model, params, ds.batches(),
                                       steps=args.steps, opt_cfg=opt_cfg,
                                       log_every=20)
    print(f"loss: {hist[0][1]:.3f} -> {hist[-1][1]:.3f} "
          f"(floor {ds.entropy_floor:.3f})")

    engine = ServingEngine(model, params)
    res = engine.generate(make_batch(cfg, 2, 16, seed=1), steps=16)
    print(f"served batch: prefill {res.prefill_seconds*1e3:.1f} ms, "
          f"16 decode steps {res.decode_seconds*1e3:.1f} ms")
    print("sample continuation:", res.tokens[0, -16:].tolist())


if __name__ == "__main__":
    main()
