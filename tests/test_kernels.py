"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes.

Pallas kernels run in interpret mode on CPU (TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lstm_cell import lstm_cell
from repro.kernels.mlstm_chunk import mlstm_chunk
from repro.kernels.ssm_scan import ssm_scan


ATTN_CASES = [
    # b, hq, hkv, lq, lk, d, causal, window, softcap
    (2, 4, 2, 256, 256, 64, True, None, None),
    (1, 8, 1, 128, 128, 128, True, None, 50.0),     # MQA + softcap (gemma)
    (2, 4, 4, 256, 256, 64, True, 128, None),       # sliding window
    (1, 4, 2, 128, 512, 64, True, None, None),      # chunked prefill tail
    (1, 2, 2, 1, 256, 64, True, None, None),        # single-token decode
    (2, 2, 2, 128, 128, 32, False, None, None),     # bidirectional (encoder)
    (1, 4, 4, 256, 256, 64, True, 64, 30.0),        # window + softcap
]


@pytest.mark.parametrize("case", ATTN_CASES, ids=str)
@pytest.mark.parametrize("dtype", [
    jnp.float32,
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow)])
def test_flash_attention_matches_oracle(case, dtype):
    b, hq, hkv, lq, lk, d, causal, window, softcap = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, hq, lq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, lk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, lk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, interpret=True)
    want = ref.attention_reference(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    assert out.dtype == q.dtype


def test_attention_blockwise_matches_reference():
    for window in (None, 96):
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (2, 4, 1024, 32))
        k = jax.random.normal(ks[1], (2, 2, 1024, 32))
        v = jax.random.normal(ks[2], (2, 2, 1024, 32))
        out = ref.attention_blockwise(q, k, v, causal=True, window=window,
                                      block_q=256)
        want = ref.attention_reference(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", [
    (4, 76, 16), (8, 17, 8),
    pytest.param((128, 64, 128), marks=pytest.mark.slow),
    pytest.param((32, 130, 256), marks=pytest.mark.slow)], ids=str)
def test_lstm_cell_matches_oracle(shape):
    b, i_dim, h_dim = shape
    ks = jax.random.split(jax.random.PRNGKey(b), 6)
    x = jax.random.normal(ks[0], (b, i_dim))
    h = jax.random.normal(ks[1], (b, h_dim))
    c = jax.random.normal(ks[2], (b, h_dim))
    wx = jax.random.normal(ks[3], (i_dim, 4, h_dim)) * 0.1
    wh = jax.random.normal(ks[4], (h_dim, 4, h_dim)) * 0.1
    bias = jax.random.normal(ks[5], (4, h_dim)) * 0.1
    h2, c2 = lstm_cell(x, h, c, wx, wh, bias, block_b=64, block_h=64,
                       interpret=True)
    hr, cr = ref.lstm_cell_reference(
        x, h, c, wx.reshape(i_dim, 4 * h_dim), wh.reshape(h_dim, 4 * h_dim),
        bias.reshape(4 * h_dim))
    np.testing.assert_allclose(h2, hr, atol=1e-5)
    np.testing.assert_allclose(c2, cr, atol=1e-5)


SSM_CASES = [
    (2, 64, 2, 8, 16, 64, 2),
    pytest.param((2, 128, 4, 16, 16, 32, 2), marks=pytest.mark.slow),
    pytest.param((1, 256, 8, 32, 64, 64, 4), marks=pytest.mark.slow)]


@pytest.mark.parametrize("case", SSM_CASES, ids=str)
def test_ssm_scan_matches_oracle(case):
    b, l, h, p, n, chunk, bh = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 6)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, n))
    cm = jax.random.normal(ks[4], (b, l, n))
    d = jax.random.normal(ks[5], (h,))
    y, hf = ssm_scan(x, dt, a, bm, cm, d, chunk=chunk, block_h=bh,
                     interpret=True)
    yr, hr = ref.ssm_scan_reference(x, dt, a, bm, cm, d)
    np.testing.assert_allclose(y, yr, atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(hf, hr, atol=3e-4, rtol=3e-4)


MLSTM_CASES = [
    (1, 64, 2, 64, 16, 1),
    pytest.param((2, 128, 4, 32, 32, 2), marks=pytest.mark.slow),
    pytest.param((2, 256, 4, 16, 64, 4), marks=pytest.mark.slow)]


@pytest.mark.parametrize("case", MLSTM_CASES, ids=str)
def test_mlstm_chunk_matches_oracle(case):
    b, l, h, d, chunk, bh = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 5)
    q = jax.random.normal(ks[0], (b, l, h, d))
    k = jax.random.normal(ks[1], (b, l, h, d))
    v = jax.random.normal(ks[2], (b, l, h, d))
    ig = jax.random.normal(ks[3], (b, l, h))
    fg = jax.random.normal(ks[4], (b, l, h)) + 2.0
    y, (c, n, m) = mlstm_chunk(q, k, v, ig, fg, chunk=chunk, block_h=bh,
                               interpret=True)
    yr, (cr, nr, mr) = ref.mlstm_chunk_reference(q, k, v, ig, fg)
    np.testing.assert_allclose(y, yr, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(c, cr, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(m, mr, atol=1e-5)


def test_mlstm_chunk_jnp_matches_sequential():
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q, k, v = (jax.random.normal(ks[i], (2, 256, 4, 32)) for i in range(3))
    ig = jax.random.normal(ks[3], (2, 256, 4))
    fg = jax.random.normal(ks[4], (2, 256, 4)) + 2.0
    y1, s1 = ref.mlstm_chunk_jnp(q, k, v, ig, fg, chunk=64)
    y2, s2 = ref.mlstm_chunk_reference(q, k, v, ig, fg)
    np.testing.assert_allclose(y1, y2, atol=5e-4, rtol=5e-3)
    for a, b_ in zip(s1, s2):
        np.testing.assert_allclose(a, b_, atol=5e-4, rtol=5e-3)


def test_ssm_scan_state_handoff_equals_split_scan():
    """Scanning [0:L] equals scanning [0:L/2] then feeding the state into
    the sequential reference for [L/2:L] — the prefill->decode invariant."""
    b, l, h, p, n = 1, 128, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, n))
    cm = jax.random.normal(ks[4], (b, l, n))
    d = jax.random.normal(ks[5], (h,))
    y_full, h_full = ref.ssm_scan_reference(x, dt, a, bm, cm, d)
    half = l // 2
    _, h_half = ref.ssm_scan_reference(x[:, :half], dt[:, :half], a,
                                       bm[:, :half], cm[:, :half], d)
    y2, h2 = ref.ssm_scan_reference(x[:, half:], dt[:, half:], a,
                                    bm[:, half:], cm[:, half:], d, h0=h_half)
    np.testing.assert_allclose(y2, y_full[:, half:], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h2, h_full, atol=1e-4, rtol=1e-4)
