"""Cross-ward shared-cloud contention (DESIGN.md §9): the fleet-true
evaluator `simulate_fleet`, frozen background jobs in both search
backends, the fixed-point `scheduler.search_fleet`, the ward-aware online
hook, the `--wards` CLI path, and the ``python -O`` guard survival of the
ValueError conversions."""
import itertools
import os
import subprocess
import sys

import numpy as np
import pytest

from prop import sweep
from repro.core import online, scheduler, scheduler_jax
from repro.core.problems import metro_jobs
from repro.core.simulator import (MACHINES, JobSpec, ScheduleState,
                                  simulate, simulate_fleet)
from repro.core.tiers import CC, ED, ES


def _random_jobs(rng, n):
    return [JobSpec(name=f"J{i}", release=float(rng.integers(0, 30)),
                    weight=float(rng.integers(1, 4)),
                    proc={t: float(rng.integers(1, 30)) for t in MACHINES},
                    trans={CC: float(rng.integers(0, 60)),
                           ES: float(rng.integers(0, 15)), ED: 0.0})
            for i in range(n)]


def _random_plan(rng, wards):
    return [[MACHINES[int(rng.integers(3))] for _ in jobs]
            for jobs in wards]


# ------------------------------------------------------ fleet-true evaluator
class TestSimulateFleet:
    def test_fully_shared_fleet_equals_merged_instance(self):
        """With every shared tier pooled, the fleet evaluator IS the
        wards-concatenated single instance — same merged FIFO queues,
        same (arrival, release, ward, index) order, bit-identical sums."""
        def check(rng):
            B = int(rng.integers(1, 5))
            wards = [_random_jobs(rng, int(rng.integers(1, 10)))
                     for _ in range(B)]
            plan = _random_plan(rng, wards)
            mpt = {CC: int(rng.integers(1, 4)), ES: int(rng.integers(1, 4))}
            busy = ({CC: [float(rng.integers(0, 20))]}
                    if rng.integers(2) else None)
            fs = simulate_fleet(wards, plan, machines_per_tier=mpt,
                                busy_until=busy, shared_tiers=(CC, ES))
            merged = simulate([j for ws in wards for j in ws],
                              [a for ps in plan for a in ps],
                              machines_per_tier=mpt, busy_until=busy)
            assert fs.weighted_sum == merged.weighted_sum
            assert fs.unweighted_sum == merged.unweighted_sum
            assert fs.last_end == merged.last_end
        sweep(check, n_cases=15, seed=0)

    def test_single_ward_equals_simulate(self):
        """B = 1: shared-cloud pooling degenerates to plain simulate."""
        def check(rng):
            jobs = _random_jobs(rng, int(rng.integers(1, 12)))
            assign = [MACHINES[int(rng.integers(3))] for _ in jobs]
            mpt = {CC: 2, ES: 3}
            fs = simulate_fleet([jobs], [assign], machines_per_tier=mpt)
            ref = simulate(jobs, assign, machines_per_tier=mpt)
            assert fs.weighted_sum == ref.weighted_sum
            assert fs.wards[0].last_end == ref.last_end
        sweep(check, n_cases=10, seed=50)

    def test_per_ward_edge_pools_are_private(self):
        """Two wards all-edge: each queues only on its OWN edge pool, so
        per-ward results equal B independent simulations — while the same
        plan all-cloud shares one pool and must be slower than any single
        ward alone whenever queues overlap."""
        rng = np.random.default_rng(3)
        wards = [_random_jobs(rng, 6), _random_jobs(rng, 6)]
        edge_plan = [[ES] * 6, [ES] * 6]
        fs = simulate_fleet(wards, edge_plan,
                            machines_per_tier={CC: 1, ES: 1})
        for jobs, s in zip(wards, fs.wards):
            ref = simulate(jobs, [ES] * 6)
            assert s.weighted_sum == ref.weighted_sum
        cloud_plan = [[CC] * 6, [CC] * 6]
        fc = simulate_fleet(wards, cloud_plan,
                            machines_per_tier={CC: 1, ES: 1})
        solo = [simulate(jobs, [CC] * 6) for jobs in wards]
        assert fc.weighted_sum >= max(s.weighted_sum for s in solo)

    def test_contention_shows_double_booking(self):
        """The PR's headline: B independent per-ward evaluations claim
        objectives the shared cloud cannot deliver — the fleet-true score
        of the same plans is strictly worse."""
        rng = np.random.default_rng(7)
        wards = [metro_jobs(rng, n=10) for _ in range(4)]
        plan = [[CC] * 10 for _ in range(4)]
        claimed = sum(
            simulate(jobs, p, machines_per_tier={CC: 2, ES: 1}).weighted_sum
            for jobs, p in zip(wards, plan))
        fleet = simulate_fleet(wards, plan,
                               machines_per_tier={CC: 2, ES: 1})
        assert fleet.weighted_sum > claimed

    def test_input_validation(self):
        jobs = _random_jobs(np.random.default_rng(0), 3)
        with pytest.raises(ValueError):
            simulate_fleet([jobs], [])                  # ward count
        with pytest.raises(ValueError):
            simulate_fleet([jobs], [[CC, ES]])          # length mismatch
        with pytest.raises(ValueError):
            simulate_fleet([jobs], [[CC] * 3], shared_tiers=(ED,))
        with pytest.raises(ValueError):                 # pool size dispute
            simulate_fleet([jobs, jobs], [[CC] * 3] * 2,
                           machines_per_tier=[{CC: 1}, {CC: 2}])

    def test_exact_joint_optimum_two_wards(self):
        """2 wards x 3 jobs on a fully shared fleet: brute-forcing joint
        assignments through simulate_fleet reaches exactly the
        exact_optimum of the merged instance — and search_fleet lands
        between that optimum and the naive fleet-true score."""
        rng = np.random.default_rng(11)
        wards = [metro_jobs(rng, n=3), metro_jobs(rng, n=3)]
        mpt = {CC: 1, ES: 1}
        best = float("inf")
        for combo in itertools.product(MACHINES, repeat=6):
            fs = simulate_fleet(wards, [combo[:3], combo[3:]],
                                machines_per_tier=mpt,
                                shared_tiers=(CC, ES))
            best = min(best, fs.weighted_sum)
        merged_opt = scheduler.exact_optimum(
            [j for ws in wards for j in ws], machines_per_tier=mpt)
        assert best == merged_opt.weighted_sum
        plan = scheduler.search_fleet(wards, machines_per_tier=mpt,
                                      shared_tiers=(CC, ES),
                                      sweep_backend="python")
        assert plan.fleet.weighted_sum >= best - 1e-9
        assert plan.fleet.weighted_sum <= \
            plan.naive_fleet.weighted_sum + 1e-9


# ------------------------------------------------------- frozen background
class TestFrozenJobs:
    def test_frozen_never_move_and_score_exactly(self):
        """Both backends: frozen jobs stay pinned, and the reported value
        is the exact simulator's on the full (frozen-included) instance."""
        def check(rng):
            jobs = _random_jobs(rng, 9)
            frozen = [bool(rng.integers(2)) for _ in jobs]
            init = [int(rng.integers(3)) if f else 2
                    for f, _ in zip(frozen, jobs)]
            v, a = scheduler_jax.tabu_search_jax(
                jobs, initial=init, frozen=frozen)
            for i, f in enumerate(frozen):
                if f:
                    assert int(a[i]) == init[i]
            exact = simulate(jobs, [MACHINES[int(i)] for i in a])
            assert abs(v - exact.weighted_sum) < 1e-3
            # python path: same pinning contract
            sched = scheduler.neighborhood_search(
                jobs, initial=[MACHINES[i] for i in init], frozen=frozen)
            for i, f in enumerate(frozen):
                if f:
                    assert sched.assignment()[i] == MACHINES[init[i]]
        sweep(check, n_cases=8, seed=100)

    def test_frozen_requires_initial(self):
        jobs = _random_jobs(np.random.default_rng(1), 4)
        with pytest.raises(ValueError):
            scheduler_jax.tabu_search_batched([jobs], frozen=[[True] * 4])
        with pytest.raises(ValueError):
            scheduler.neighborhood_search(jobs, frozen=[True] * 4)
        with pytest.raises(ValueError):
            scheduler.search(jobs, frozen=[True] * 4, jax_threshold=1)

    def test_frozen_background_occupies_the_queue(self):
        """A frozen cloud job ahead in the FIFO queue must delay the
        movable job's cloud option — the search sees the contention."""
        mk = lambda name, rel: JobSpec(
            name=name, release=rel, weight=1.0,
            proc={CC: 10.0, ES: 50.0, ED: 50.0},
            trans={CC: 0.0, ES: 0.0, ED: 0.0})
        jobs = [mk("movable", 1.0), mk("bg", 0.0)]
        sched = scheduler.neighborhood_search(
            jobs, initial=[CC, CC], frozen=[False, True])
        entry = sched.entries[0]
        # bg holds the single cloud machine 0-10, so cloud would finish at
        # 20 (response 19); the search must route the movable job away
        assert entry.machine != CC or entry.start >= 10.0

    def test_pad_to_is_inert(self):
        jobs = _random_jobs(np.random.default_rng(5), 7)
        v1, a1 = scheduler_jax.tabu_search_batched([jobs])
        v2, a2 = scheduler_jax.tabu_search_batched([jobs], pad_to=32)
        assert v1[0] == v2[0] and list(a1[0]) == list(a2[0])


# ------------------------------------------------- fixed-point fleet search
class TestSearchFleet:
    MPT = {CC: 2, ES: 1}

    def _wards(self, seed, B=4, n=8):
        rng = np.random.default_rng(seed)
        return [metro_jobs(rng, n=n) for _ in range(B)]

    @pytest.mark.parametrize("backend", ["python", "batched"])
    def test_monotone_and_gap(self, backend):
        """The fixed-point search never worsens the fleet-true objective,
        and on a cloud-attractive fleet it strictly improves it."""
        wards = self._wards(21, B=4, n=8)
        plan = scheduler.search_fleet(
            wards, machines_per_tier=self.MPT, sweep_backend=backend,
            pad_bucket=16)
        assert plan.fleet.weighted_sum <= \
            plan.naive_fleet.weighted_sum + 1e-9
        # naive fleet-true can never beat what the wards claimed
        assert plan.naive_fleet.weighted_sum >= plan.naive_reported - 1e-6
        assert plan.sweeps >= 1
        # the returned evaluation matches a fresh fleet-true rescore
        fresh = simulate_fleet(wards, plan.assignments,
                               machines_per_tier=self.MPT)
        assert fresh.weighted_sum == plan.fleet.weighted_sum

    def test_contention_gap_closes_on_overcommitted_fleet(self):
        """B wards of cloud-heavy jobs on a small shared pool: the naive
        plans must overcommit (gap > 1) and the sweeps must recover a
        strictly better fleet-true plan."""
        wards = self._wards(33, B=5, n=10)
        plan = scheduler.search_fleet(wards, machines_per_tier=self.MPT,
                                      sweep_backend="python")
        assert plan.contention_gap > 1.0
        assert plan.fleet.weighted_sum < plan.naive_fleet.weighted_sum
        assert 0.0 < plan.gap_closed <= 1.0

    def test_independent_mode_untouched(self):
        """search_fleet's naive stage IS search_batched — per-ward
        assignments identical to calling it directly (the PR-3 batched
        path stays bit-identical)."""
        wards = self._wards(8, B=4, n=8)
        plan = scheduler.search_fleet(wards, machines_per_tier=self.MPT,
                                      max_sweeps=0)
        direct = scheduler.search_batched(
            wards, machines_per_tier=self.MPT)
        assert plan.naive_assignments == [s.assignment() for s in direct]
        assert plan.sweeps == 0

    def test_empty_fleet(self):
        plan = scheduler.search_fleet([], machines_per_tier=self.MPT)
        assert plan.assignments == [] and plan.sweeps == 0


# -------------------------------------------------- ward-aware online hook
class TestOnlineFleet:
    def test_single_ward_is_plain_tabu_online(self):
        """B = 1 has an empty background at every event, so the hook IS
        online_schedule(replan='tabu') — identical commits."""
        def check(rng):
            jobs = metro_jobs(rng, n=8)
            mpt = {CC: 2, ES: 1}
            solo = online.online_schedule(jobs, replan="tabu",
                                          machines_per_tier=mpt)
            fleet = online.online_schedule_fleet(
                [jobs], machines_per_tier=mpt)[0]
            assert solo.weighted_sum == fleet.weighted_sum
            assert solo.last_end == fleet.last_end
        sweep(check, n_cases=6, seed=200)

    def test_no_cloud_double_booking(self):
        """At no instant do more cloud jobs run than the shared pool has
        machines — the property the per-ward-independent online mode
        cannot guarantee."""
        rng = np.random.default_rng(9)
        wards = [metro_jobs(rng, n=8) for _ in range(4)]
        mpt = {CC: 2, ES: 1}
        scheds = online.online_schedule_fleet(wards,
                                              machines_per_tier=mpt)
        assert len(scheds) == 4
        cloud = [(e.start, e.end) for s in scheds for e in s.entries
                 if e.machine == CC]
        for t in sorted({t for se in cloud for t in se}):
            running = sum(1 for s, e in cloud if s <= t < e)
            assert running <= mpt[CC], (t, running)
        for jobs, s in zip(wards, scheds):
            assert len(s.entries) == len(jobs)
            assert all(e.start >= e.job.release for e in s.entries)


# --------------------------------------------------------- CLI / serve path
@pytest.mark.slow
class TestRunWards:
    def test_run_wards_smoke(self):
        from repro.launch import serve
        schedules, seconds = serve.run_wards(
            wards=2, patients=3, horizon=10.0, seed=1, verbose=False)
        assert len(schedules) == 2
        for s in schedules:
            assert len(s.entries) == 3
            assert all(e.machine in (CC, ES, ED) for e in s.entries)
        assert seconds > 0

    def test_run_wards_contention_smoke(self):
        from repro.launch import serve
        schedules, seconds, plan = serve.run_wards(
            wards=2, patients=3, horizon=10.0, seed=1, verbose=False,
            contention=True)
        assert len(schedules) == 2
        assert plan.fleet.weighted_sum <= \
            plan.naive_fleet.weighted_sum + 1e-9
        assert plan.contention_gap >= 1.0 - 1e-9

    def test_explicit_zero_quantum_rejected(self):
        from repro.launch import serve
        with pytest.raises(ValueError):
            serve.run_wards(wards=2, patients=2, horizon=5.0,
                            quantum=0.0, verbose=False)
        with pytest.raises(ValueError):
            serve.run(patients=2, horizon=5.0, quantum=0.0,
                      verbose=False, execute=False)


# ------------------------------------------------------- python -O survival
@pytest.mark.slow
def test_guards_survive_python_O():
    """The length/size guards converted from assert must still raise
    under ``python -O`` (which strips asserts) — core scheduling plus
    every module the R001 reprolint sweep converted (kernels, models,
    configs, sharding, launch; DESIGN.md §14)."""
    code = """
import dataclasses
import sys
sys.path.insert(0, sys.argv[1])
import jax.numpy as jnp
from repro.configs import get_config
from repro.core import scheduler
from repro.core.simulator import JobSpec, ScheduleState, simulate
from repro.core.tiers import CC, ED, ES
from repro.kernels import (flash_attention, lstm_cell, mlstm_chunk, ref,
                           ssm_scan)
from repro.launch import dryrun
from repro.models.encdec import EncDecModel
from repro.sharding import ep_moe, policy
assert not __debug__, "run me with -O"
job = JobSpec(name="J", release=0.0, weight=1.0,
              proc={CC: 1.0, ES: 1.0, ED: 1.0},
              trans={CC: 0.0, ES: 0.0, ED: 0.0})
cfg = get_config("qwen2-1.5b")
z = jnp.zeros
for fn in (lambda: simulate([job], []),
           lambda: ScheduleState([job], []),
           lambda: simulate([job], ["moon"]),
           lambda: scheduler.exact_optimum([job] * 13),
           # converted R001 guards (group pattern / enc-dec / wx shape)
           lambda: dataclasses.replace(cfg, num_groups=cfg.num_layers + 1),
           lambda: EncDecModel(cfg),
           lambda: lstm_cell.lstm_cell(z((4, 8)), z((4, 8)), z((4, 8)),
                                       z((8, 3, 8)), z((8, 4, 8)),
                                       z((4, 8)))):
    try:
        fn()
    except ValueError:
        pass
    else:
        raise SystemExit(f"guard vanished under -O: {fn}")
print("guards ok")
"""
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    out = subprocess.run([sys.executable, "-O", "-c", code, src],
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "guards ok" in out.stdout


# --------------------------------------------- contention regression gate
class TestContentionGate:
    """check_regression.py contention logic (no bench run)."""

    def _compare(self):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        os.pardir, "benchmarks"))
        try:
            from check_regression import compare
        finally:
            sys.path.pop(0)
        return compare

    def _reports(self):
        base = {"contention": {
            "contention_gap": 1.5, "gap_closed": 0.9,
            "improvement_vs_naive": 1.4, "wards_per_s": 2.0,
            "naive_fleet_true": 3000.0, "fleet_true": 2100.0}}
        import copy
        return base, copy.deepcopy(base)

    def test_identical_passes(self):
        compare = self._compare()
        committed, fresh = self._reports()
        assert compare(committed, fresh) == []

    def test_throughput_regression_fails(self):
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["contention"]["wards_per_s"] = 0.5          # -75%
        assert any("wards_per_s" in p for p in compare(committed, fresh))

    def test_gap_closed_regression_fails(self):
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["contention"]["gap_closed"] = 0.3           # -66%
        assert any("gap_closed" in p for p in compare(committed, fresh))

    def test_vanished_gap_fails(self):
        """If the benchmark fleet stops double-booking, the bench no
        longer measures contention — hard failure, not a perf floor."""
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["contention"]["contention_gap"] = 1.0
        assert any("contention_gap" in p for p in compare(committed, fresh))

    def test_no_strict_improvement_fails(self):
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["contention"]["fleet_true"] = 3000.0        # == naive
        assert any("strictly beat" in p for p in compare(committed, fresh))

    def test_missing_section_is_not_gated(self):
        """Old reports without a contention section still pass (the gate
        tightens with the baseline, never blocks on new sections)."""
        compare = self._compare()
        committed, _ = self._reports()
        assert compare(committed, {"contention": {}}) == []


# ------------------------------------ interval contention regression gate
class TestContentionIntervalGate:
    """check_regression.py §12 interval-path logic (no bench run)."""

    def _compare(self):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        os.pardir, "benchmarks"))
        try:
            from check_regression import compare
        finally:
            sys.path.pop(0)
        return compare

    def _reports(self):
        base = {"contention_interval": {
            "gap_closed": 0.95, "improvement_vs_naive": 10.0,
            "wards_per_s": 5.0, "fraction_of_batched": 0.03,
            "parity_with_phantom": True,
            "compiled_shapes": {"size": 2, "hits": 10, "misses": 2,
                                "evictions": 0}}}
        import copy
        return base, copy.deepcopy(base)

    def test_identical_passes(self):
        compare = self._compare()
        committed, fresh = self._reports()
        assert compare(committed, fresh) == []

    def test_throughput_regression_fails(self):
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["contention_interval"]["wards_per_s"] = 1.0  # -80%
        assert any("contention_interval/wards_per_s" in p
                   for p in compare(committed, fresh))

    def test_batched_ratio_regression_fails(self):
        """fraction_of_batched is the committed "fleet sweeps at §8
        batched speeds" claim — falling far behind the independent
        batched floor fails even if absolute wards/s still passes."""
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["contention_interval"]["fraction_of_batched"] = 0.001
        assert any("fraction_of_batched" in p
                   for p in compare(committed, fresh))

    def test_parity_break_fails(self):
        """parity_with_phantom is a hard invariant: tolerance never
        excuses the interval background diverging from the oracle."""
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["contention_interval"]["parity_with_phantom"] = False
        assert any("parity_with_phantom" in p
                   for p in compare(committed, fresh, tolerance=0.99))

    def test_eviction_fails(self):
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["contention_interval"]["compiled_shapes"]["evictions"] = 3
        assert any("evictions" in p for p in compare(committed, fresh))

    def test_missing_section_is_not_gated(self):
        compare = self._compare()
        committed, _ = self._reports()
        assert compare(committed, {}) == []
