"""Optimizer math, microbatch-equivalence, end-to-end learnability."""
import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import MarkovTokenDataset
from repro.models import build_model
from repro.training import optimizer, train_loop


def test_adamw_first_step_matches_manual():
    cfg = optimizer.AdamWConfig(lr=0.1, warmup_steps=1, weight_decay=0.0,
                                grad_clip=1e9)
    params = {"w": jnp.ones((2, 2))}
    grads = {"w": jnp.full((2, 2), 0.5)}
    state = optimizer.init(params)
    new, state2, stats = optimizer.update(cfg, grads, state, params)
    # bias-corrected mhat = g, vhat = g^2 -> delta = g/(|g|+eps) = 1
    lr0 = optimizer.schedule(cfg, jnp.zeros((), jnp.int32))
    np.testing.assert_allclose(new["w"], 1.0 - float(lr0), rtol=1e-5)
    assert int(state2.step) == 1


def test_grad_clip_bounds_update():
    cfg = optimizer.AdamWConfig(lr=1.0, warmup_steps=1, grad_clip=1.0,
                                weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, stats = optimizer.update(cfg, grads, optimizer.init(params), params)
    assert float(stats["grad_norm"]) == 200.0


@pytest.mark.slow
def test_microbatch_grads_equal_full_batch():
    """Grad accumulation must produce the same update as one big batch."""
    cfg = get_config("qwen2-1.5b").reduced(layers=2, d_model=64, vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, 64)}
    opt_cfg = optimizer.AdamWConfig(total_steps=10)
    s1 = train_loop.make_train_step(model, opt_cfg, jit=False,
                                    microbatches=1)
    s2 = train_loop.make_train_step(model, opt_cfg, jit=False,
                                    microbatches=2)
    o = optimizer.init(params)
    p1, _, m1 = s1(params, o, batch)
    p2, _, m2 = s2(params, o, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)


@pytest.mark.slow
def test_loss_learns_markov_structure():
    cfg = get_config("gemma-2b").reduced(layers=2, d_model=128, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ds = MarkovTokenDataset(vocab_size=128, seq_len=32, batch_size=8)
    params, _, hist = train_loop.train(model, params, ds.batches(),
                                       steps=50, log_every=50,
                                       log_fn=lambda *_: None)
    first, last = hist[0][1], hist[-1][1]
    assert last < first - 0.4, (first, last)
    assert last > ds.entropy_floor - 0.5   # can't beat the true entropy
