"""FLOP/param accounting vs published model sizes + paper formulas."""
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.icu_lstm import ICU_WORKLOADS
from repro.utils import flops

# published parameter counts (model cards); ours include the vocab padding
PUBLISHED = {
    "gemma2-27b": 27.2e9,
    "mixtral-8x7b": 46.7e9,
    "mixtral-8x22b": 141e9,
    "mistral-large-123b": 123e9,
    "qwen2-1.5b": 1.54e9,
    "gemma-2b": 2.5e9,
}


@pytest.mark.parametrize("arch,want", sorted(PUBLISHED.items()))
def test_param_count_matches_model_card(arch, want):
    got = flops.param_count(get_config(arch))
    assert abs(got - want) / want < 0.05, (arch, got, want)


def test_mixtral_active_params():
    cfg = get_config("mixtral-8x7b")
    active = flops.active_param_count(cfg)
    assert abs(active - 12.9e9) / 12.9e9 < 0.05


def test_train_flops_approx_6nd():
    """Dense train FLOPs should be within ~2x of 6*N*D (attention extra)."""
    cfg = get_config("qwen2-1.5b")
    shape = INPUT_SHAPES["train_4k"]
    got = flops.step_flops(cfg, shape)
    nd6 = flops.model_flops_6nd(cfg, shape)
    assert 0.8 < got / nd6 < 2.0, (got, nd6)


def test_decode_flops_scale_with_context():
    cfg = get_config("mistral-large-123b")
    f1 = flops.forward_flops(cfg, 1, 4096, "decode")
    f2 = flops.forward_flops(cfg, 1, 32768, "decode")
    assert f2 > f1                       # KV read term grows
    assert f2 < f1 * 2                   # but matmuls dominate at 123B


def test_recurrent_decode_flops_context_independent():
    cfg = get_config("xlstm-350m")
    f1 = flops.forward_flops(cfg, 1, 4096, "decode")
    f2 = flops.forward_flops(cfg, 1, 524288, "decode")
    assert f1 == f2


def test_paper_lstm_flops_formula():
    """Section III.C: FLOPs = (2I-1)O per FC layer, summed over gates."""
    got = flops.lstm_flops(input_dim=76, hidden=16)
    assert got == (2 * 76 - 1) * 64 + (2 * 16 - 1) * 64
    # paper Table IV magnitudes are plausible under this formula
    for wl in ICU_WORKLOADS:
        est = flops.lstm_flops(wl.input_dim, wl.hidden)
        assert 0.05 < est / wl.paper_flops < 20.0
