"""End-to-end behaviour tests for the hierarchical serving system."""
import pytest

import jax
import numpy as np

from repro.core.tiers import CC, ED, ES

pytestmark = pytest.mark.slow


def test_serve_driver_end_to_end():
    """Multi-patient ICU serving: calibrate -> allocate -> schedule ->
    execute. Our allocation must meet every baseline and the lower bound."""
    from repro.launch import serve
    results, lb = serve.run(patients=6, horizon=20.0, seed=3,
                            execute=True, verbose=False)
    ours = results["ours (algorithm 2)"]
    assert ours.weighted_sum >= lb - 1e-9
    for name, sched in results.items():
        assert ours.weighted_sum <= sched.weighted_sum + 1e-9, name
    # every job scheduled exactly once, on a real tier
    assert len(ours.entries) == 6
    assert all(e.machine in (CC, ES, ED) for e in ours.entries)


def test_tpu_tier_allocation_prefers_cloud_for_heavy_jobs():
    """On the TPU fleet, a 123B-prefill-sized job belongs on the pod; a
    tiny classifier belongs on the device chip (Algorithm 1 end-to-end
    with flops-derived workloads)."""
    from repro.core import allocator
    from repro.core.cost_model import AnalyticCostModel, Job, Workload
    from repro.core.tiers import tpu_tiers
    from repro.configs import get_config
    from repro.utils import flops

    tiers = tpu_tiers()
    cm = AnalyticCostModel(tiers)
    heavy_cfg = get_config("mistral-large-123b")
    comp = flops.forward_flops(heavy_cfg, 1, 32768, "prefill")
    heavy = Job(Workload("mistral-prefill-32k", comp=comp,
                         unit_bytes=32768 * 4), size=1.0)
    assert allocator.allocate_single(cm, heavy).tier == CC

    light = Job(Workload("icu-lstm", comp=1e6, unit_bytes=1e4), size=1.0)
    assert allocator.allocate_single(cm, light).tier == ED


def test_quickstart_pattern_trains_and_serves():
    """The README quickstart: tiny model, a few steps, then generate."""
    from repro.configs import get_config
    from repro.data.pipeline import MarkovTokenDataset, make_batch
    from repro.models import build_model
    from repro.serving.engine import ServingEngine
    from repro.training import train_loop

    cfg = get_config("qwen2-1.5b").reduced(layers=2, d_model=64, vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ds = MarkovTokenDataset(64, 32, 4)
    params, _, hist = train_loop.train(model, params, ds.batches(),
                                       steps=20, log_fn=lambda *_: None)
    eng = ServingEngine(model, params)
    out = eng.generate(make_batch(cfg, 1, 8), steps=4)
    assert out.tokens.shape == (1, 12)
