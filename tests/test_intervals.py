"""Interval-reservation parity (DESIGN.md §12): the reserved-interval
representation of committed background occupancy must be bit-identical —
objectives AND trajectories — to the frozen-phantom construction it
replaces, at every layer: `simulate`/`ScheduleState`, the Python
`neighborhood_search`, the jitted `tabu_search_batched`, the dispatching
`search`/`search_batched`, the fixed-point `search_fleet` (both sweep
backends, all three objectives, (2,3)-ward fleets), the `_FleetEval`
trial evaluator, and the metro `TabuPolicy` replan path (B = 1 solo and
batched)."""
import numpy as np
import pytest

from prop import sweep
from repro.core import scheduler, scheduler_jax
from repro.core.problems import metro_jobs
from repro.core.simulator import (MACHINES, JobSpec, Reservation,
                                  ScheduleState, simulate, simulate_fleet,
                                  _fleet_mpts)
from repro.core.tiers import CC, ED, ES


@pytest.fixture(autouse=True, scope="module")
def _isolate_compiled_shapes():
    """Tests here force the JAX path (jax_threshold=0), which records
    bucketed shapes in the module-global fast-path set — restore it so
    later test modules keep their CPU default dispatch."""
    saved = set(scheduler._COMPILED_SHAPES)
    stats = dict(scheduler._SHAPE_STATS)
    yield
    scheduler._COMPILED_SHAPES.clear()
    scheduler._COMPILED_SHAPES.update(saved)
    scheduler._SHAPE_STATS.update(stats)


def _random_jobs(rng, n):
    return [JobSpec(name=f"J{i}", release=float(rng.integers(0, 30)),
                    weight=float(rng.integers(1, 4)),
                    proc={t: float(rng.integers(1, 30)) for t in MACHINES},
                    trans={CC: float(rng.integers(0, 60)),
                           ES: float(rng.integers(0, 15)), ED: 0.0})
            for i in range(n)]


def _random_reservations(rng, max_per_tier=3):
    resv = {}
    for tier in (CC, ES):
        k = int(rng.integers(0, max_per_tier + 1))
        if k:
            rs = []
            for _ in range(k):
                rel = float(rng.integers(0, 30))
                rs.append(Reservation(
                    arrival=rel + float(rng.integers(0, 40)),
                    proc=float(rng.integers(1, 30)), release=rel,
                    weight=float(rng.integers(0, 4))))
            resv[tier] = rs
    return resv


def _phantoms(reserved):
    """The legacy frozen-phantom construction for a reservation map:
    background JobSpecs (appended after the instance's jobs, cloud list
    then edge list) plus their pinned tiers — the §12 oracle."""
    jobs, tiers = [], []
    for tier in (CC, ES):
        for k, r in enumerate((reserved or {}).get(tier) or ()):
            d = r.arrival - r.release
            jobs.append(JobSpec(
                name=f"bg-{tier}-{k}", release=r.release, weight=r.weight,
                proc={CC: r.proc, ES: r.proc, ED: r.proc},
                trans={CC: d, ES: d, ED: 0.0}))
            tiers.append(tier)
    return jobs, tiers


def _objectives(s):
    return (s.weighted_sum, s.unweighted_sum, s.last_end)


# --------------------------------------------------------- simulator layer
class TestSimulateParity:
    def test_reservations_equal_phantoms(self):
        """simulate(jobs, a, reserved=R) is bit-identical — all three
        sums AND per-reservation (arrival, start, end) — to simulating
        the phantom-augmented instance."""
        def check(rng):
            jobs = _random_jobs(rng, int(rng.integers(1, 10)))
            assign = [MACHINES[int(rng.integers(3))] for _ in jobs]
            resv = _random_reservations(rng)
            mpt = {CC: int(rng.integers(1, 3)), ES: int(rng.integers(1, 3))}
            busy = ({CC: [float(rng.integers(0, 20))]}
                    if rng.integers(2) else None)
            ph_jobs, ph_tiers = _phantoms(resv)
            ref = simulate(jobs + ph_jobs, assign + ph_tiers,
                           machines_per_tier=mpt, busy_until=busy)
            got = simulate(jobs, assign, machines_per_tier=mpt,
                           busy_until=busy, reserved=resv)
            assert _objectives(got) == _objectives(ref)
            # reservation timings == the phantom entries they replace
            ph = ref.entries[len(jobs):]
            k = 0
            for tier in (CC, ES):
                for t in (got.reserved_times or {}).get(tier, ()):
                    assert t == (ph[k].arrival, ph[k].start, ph[k].end)
                    k += 1
            assert k == len(ph_jobs)
        sweep(check, n_cases=25, seed=0)

    def test_tie_breaks_job_first_then_list_order(self):
        """At equal (arrival, release) a real job dispatches before a
        reservation, and reservations keep input-list order — exactly
        the phantom append order."""
        job = JobSpec(name="J", release=0.0, weight=1.0,
                      proc={CC: 5.0, ES: 5.0, ED: 50.0},
                      trans={CC: 0.0, ES: 0.0, ED: 0.0})
        rs = [Reservation(arrival=0.0, proc=3.0, release=0.0, weight=1.0),
              Reservation(arrival=0.0, proc=7.0, release=0.0, weight=1.0)]
        s = simulate([job], [CC], reserved={CC: rs})
        assert s.entries[0].start == 0.0
        (a0, s0, e0), (a1, s1, e1) = s.reserved_times[CC]
        assert (s0, e0) == (5.0, 8.0)       # first listed runs first
        assert (s1, e1) == (8.0, 15.0)

    def test_schedule_state_tracks_simulate(self):
        """ScheduleState with reservations: score / try_move /
        apply_move / to_schedule all agree with fresh `simulate` calls
        on every objective through a random move sequence."""
        def check(rng):
            jobs = _random_jobs(rng, int(rng.integers(2, 8)))
            assign = [MACHINES[int(rng.integers(3))] for _ in jobs]
            resv = _random_reservations(rng)
            mpt = {CC: 2, ES: 1}
            state = ScheduleState(jobs, list(assign),
                                  machines_per_tier=mpt, reserved=resv)
            for _ in range(6):
                k = int(rng.integers(len(jobs)))
                dst = MACHINES[int(rng.integers(3))]
                moved = list(state.assign)
                moved[k] = dst
                ref = simulate(jobs, moved, machines_per_tier=mpt,
                               reserved=resv)
                for obj in ("weighted", "unweighted", "last"):
                    assert state.try_move(k, dst, obj) == ref.objective(obj)
                if dst != state.assign[k]:
                    state.apply_move(k, dst)
                    for obj in ("weighted", "unweighted", "last"):
                        assert state.score(obj) == ref.objective(obj)
            final = state.to_schedule()
            ref = simulate(jobs, state.assign, machines_per_tier=mpt,
                           reserved=resv)
            assert _objectives(final) == _objectives(ref)
        sweep(check, n_cases=12, seed=7)

    def test_reservations_shared_tiers_only(self):
        job = _random_jobs(np.random.default_rng(0), 1)[0]
        bad = {ED: [Reservation(arrival=0.0, proc=1.0, release=0.0)]}
        with pytest.raises(ValueError):
            simulate([job], [CC], reserved=bad)
        with pytest.raises(ValueError):
            ScheduleState([job], [CC], reserved=bad)


# ------------------------------------------------------ python search layer
class TestPythonSearchParity:
    @pytest.mark.parametrize("objective", ["weighted", "unweighted", "last"])
    def test_neighborhood_search_matches_phantom(self, objective):
        """Same trajectory: the interval search's move sequence equals
        the frozen-phantom search's (movable candidates, scores and ties
        all agree), so assignments and objectives are bit-identical."""
        def check(rng):
            jobs = _random_jobs(rng, int(rng.integers(2, 8)))
            resv = _random_reservations(rng)
            init = [MACHINES[int(rng.integers(3))] for _ in jobs]
            mpt = {CC: int(rng.integers(1, 3)), ES: 1}
            ph_jobs, ph_tiers = _phantoms(resv)
            got = scheduler.neighborhood_search(
                jobs, initial=init, max_count=5, objective=objective,
                machines_per_tier=mpt, reserved=resv or None)
            ref = scheduler.neighborhood_search(
                jobs + ph_jobs, initial=init + ph_tiers, max_count=5,
                objective=objective, machines_per_tier=mpt,
                frozen=[False] * len(jobs) + [True] * len(ph_jobs))
            assert got.assignment() == ref.assignment()[:len(jobs)]
            assert _objectives(got) == _objectives(ref)
        sweep(check, n_cases=10, seed=31)

    def test_reservations_require_initial(self):
        jobs = _random_jobs(np.random.default_rng(1), 4)
        resv = {CC: [Reservation(arrival=0.0, proc=5.0, release=0.0)]}
        with pytest.raises(ValueError):
            scheduler.neighborhood_search(jobs, reserved=resv)
        with pytest.raises(ValueError):
            scheduler.search(jobs, reserved=resv, jax_threshold=0)
        with pytest.raises(ValueError):
            scheduler_jax.tabu_search_batched([jobs], reserved=[resv])
        with pytest.raises(ValueError, match="wards"):
            scheduler.search_batched([jobs, jobs], reserved=[None, resv])


# ------------------------------------------------------------ kernel layer
class TestKernelParity:
    MPT = [(2, 1)]

    def _case(self, seed, n=6):
        rng = np.random.default_rng(seed)
        jobs = _random_jobs(rng, n)
        resv = _random_reservations(rng)
        if not resv:
            resv = {CC: [Reservation(arrival=3.0, proc=4.0, release=1.0,
                                     weight=2.0)]}
        init = [int(rng.integers(3)) for _ in jobs]
        return jobs, resv, init

    @pytest.mark.parametrize("objective", ["weighted", "unweighted", "last"])
    def test_batched_reserved_equals_frozen(self, objective):
        """tabu_search_batched: reserved rows vs frozen-phantom rows are
        bit-identical in value and assignment on integer instances."""
        for seed in (0, 1, 2):
            jobs, resv, init = self._case(seed)
            ph_jobs, ph_tiers = _phantoms(resv)
            ph_idx = [MACHINES.index(t) for t in ph_tiers]
            v1, a1 = scheduler_jax.tabu_search_batched(
                [jobs], [init], objective=objective,
                machines_per_tier=self.MPT, reserved=[resv], pad_to=16)
            v2, a2 = scheduler_jax.tabu_search_batched(
                [jobs + ph_jobs], [init + ph_idx], objective=objective,
                machines_per_tier=self.MPT,
                frozen=[[False] * len(jobs) + [True] * len(ph_jobs)],
                pad_to=16)
            assert float(v1[0]) == float(v2[0])
            assert list(a1[0]) == list(a2[0])[:len(jobs)]

    def test_search_jax_equals_python_with_reservations(self):
        """The dispatching `search`: forced-JAX and Python backends land
        on the same objective for a reserved instance, and the JAX value
        is exact (rescored by `simulate`)."""
        jobs, resv, init = self._case(5)
        init_t = [MACHINES[i] for i in init]
        mpt = {CC: 2, ES: 1}
        jaxed = scheduler.search(jobs, initial=init_t, reserved=resv,
                                 jax_threshold=0, machines_per_tier=mpt)
        py = scheduler.search(jobs, initial=init_t, reserved=resv,
                              jax_threshold=10**9, machines_per_tier=mpt)
        ref = simulate(jobs, jaxed.assignment(), machines_per_tier=mpt,
                       reserved=resv)
        assert jaxed.weighted_sum == ref.weighted_sum
        assert jaxed.weighted_sum == py.weighted_sum

    def test_search_batched_reserved_per_ward(self):
        """Per-ward reservation maps ride the batched path and each
        ward's result is exact under its own reservations."""
        cases = [self._case(s) for s in (10, 11, 12)]
        problems = [jobs for jobs, _, _ in cases]
        resvs = [resv for _, resv, _ in cases]
        inits = [[MACHINES[i] for i in init] for _, _, init in cases]
        scheds = scheduler.search_batched(
            problems, machines_per_tier=[{CC: 2, ES: 1}] * 3,
            initial=inits, reserved=resvs, min_batch=1, jax_threshold=0)
        for jobs, resv, s in zip(problems, resvs, scheds):
            ref = simulate(jobs, s.assignment(),
                           machines_per_tier={CC: 2, ES: 1}, reserved=resv)
            assert _objectives(s) == _objectives(ref)


# ------------------------------------------------------------- fleet layer
class TestFleetEvalExact:
    def test_matches_simulate_fleet_bitwise(self):
        """_FleetEval replays `simulate_fleet`'s heap arithmetic — every
        random trial plan scores bit-identically on all objectives."""
        def check(rng):
            B = int(rng.integers(1, 4))
            wards = [_random_jobs(rng, int(rng.integers(1, 8)))
                     for _ in range(B)]
            shared = (CC,) if rng.integers(2) else (CC, ES)
            mpt = {CC: int(rng.integers(1, 3)), ES: int(rng.integers(1, 3))}
            busy = ({CC: [float(rng.integers(0, 15))]}
                    if rng.integers(2) else None)
            wbusy = ([{ES: [float(rng.integers(0, 15))]}
                      for _ in range(B)]
                     if (ES not in shared and rng.integers(2)) else None)
            mpts = _fleet_mpts(mpt, B, shared)
            ev = scheduler._FleetEval(wards, mpts, busy, wbusy, shared)
            for _ in range(5):
                plan = [[MACHINES[int(rng.integers(3))] for _ in jobs]
                        for jobs in wards]
                ref = simulate_fleet(wards, plan, machines_per_tier=mpts,
                                     busy_until=busy,
                                     ward_busy_until=wbusy,
                                     shared_tiers=shared)
                for obj in ("weighted", "unweighted", "last"):
                    assert ev(plan, obj) == ref.objective(obj)
        sweep(check, n_cases=12, seed=90)


class TestSearchFleetParity:
    MPT = {CC: 2, ES: 1}

    def _wards(self, seed, B, n=8):
        rng = np.random.default_rng(seed)
        return [metro_jobs(rng, n=n) for _ in range(B)]

    @pytest.mark.parametrize("objective", ["weighted", "unweighted", "last"])
    @pytest.mark.parametrize("backend", ["python", "batched"])
    def test_interval_equals_phantom(self, objective, backend):
        """The tentpole contract: `search_fleet` with interval
        reservations reproduces the frozen-phantom path's plan —
        identical assignments, sweeps and fleet-true objectives — on
        both sweep backends and all three objectives."""
        wards = self._wards(42, B=3)
        kw = dict(machines_per_tier=self.MPT, objective=objective,
                  max_count=5, max_sweeps=3, sweep_backend=backend,
                  pad_bucket=16)
        pi = scheduler.search_fleet(wards, background="interval", **kw)
        pp = scheduler.search_fleet(wards, background="phantom", **kw)
        assert pi.assignments == pp.assignments
        assert pi.sweeps == pp.sweeps
        assert pi.fleet.objective(objective) == \
            pp.fleet.objective(objective)
        assert _objectives(pi.naive_fleet) == _objectives(pp.naive_fleet)

    def test_two_ward_fleet_parity(self):
        """(2,3) fleets per the issue: the B = 2 case too."""
        wards = self._wards(7, B=2, n=6)
        for backend in ("python", "batched"):
            pi = scheduler.search_fleet(
                wards, machines_per_tier=self.MPT, max_count=4,
                max_sweeps=2, sweep_backend=backend, pad_bucket=16)
            pp = scheduler.search_fleet(
                wards, machines_per_tier=self.MPT, max_count=4,
                max_sweeps=2, sweep_backend=backend, pad_bucket=16,
                background="phantom")
            assert pi.assignments == pp.assignments
            assert pi.fleet.weighted_sum == pp.fleet.weighted_sum

    def test_background_validated(self):
        with pytest.raises(ValueError):
            scheduler.search_fleet(self._wards(0, B=2, n=3),
                                   machines_per_tier=self.MPT,
                                   background="hologram")


# ------------------------------------------------------- metro replan layer
class TestMetroReplanParity:
    def _request(self, seed, n=6, bg=2):
        from repro.metro.policies import ReplanRequest
        rng = np.random.default_rng(seed)
        jobs = _random_jobs(rng, n)
        bg_specs = _random_jobs(rng, bg)
        cur = [MACHINES[int(rng.integers(3))] for _ in jobs]
        return ReplanRequest(
            ward=0, movable=list(range(n)), shifted=jobs,
            current=list(cur), fresh=[], busy={CC: [0.0, 0.0]},
            reserved={CC: [0.0, 0.0]},
            machines_per_tier={CC: 2, ES: 1}, background=bg_specs)

    def test_tabu_policy_background_equals_phantom_search(self):
        """TabuPolicy's reservation replan (metro B = 1 decide) lands on
        the frozen-phantom reference search bit-identically."""
        from repro.metro.policies import TabuPolicy
        for seed in (3, 4, 5):
            req = self._request(seed)
            got = TabuPolicy(max_count=5).decide([req], now=0.0)[0]
            n = len(req.shifted)
            ph = list(req.background)
            ref = scheduler.search(
                req.shifted + ph,
                initial=req.current + [CC] * len(ph),
                frozen=[False] * n + [True] * len(ph), max_count=5,
                machines_per_tier=req.machines_per_tier,
                busy_until=req.busy)
            assert got == ref.assignment()[:n]

    def test_tabu_policy_solo_equals_batched(self):
        """One request through the solo path == the same request forced
        through the batched path (min_batch=1) — decisions identical."""
        from repro.metro.policies import TabuPolicy
        req = self._request(13)
        solo = TabuPolicy(max_count=5).decide([req], now=0.0)
        resv, init = TabuPolicy._reservations(req)
        batched = scheduler.search_batched(
            [list(req.shifted)], max_count=5,
            machines_per_tier=[req.machines_per_tier],
            busy_until=[req.busy], initial=[init], reserved=[resv],
            min_batch=10**9)
        assert solo == [batched[0].assignment()]
