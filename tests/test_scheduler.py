"""Paper reproduction (Tables VI/VII) + scheduling invariants."""
import numpy as np
import pytest

from prop import sweep
from repro.core import scheduler, scheduler_jax
from repro.core.lower_bound import load_lower_bound, paper_lower_bound
from repro.core.problems import table6_jobs
from repro.core.simulator import MACHINES, JobSpec, simulate
from repro.core.tiers import CC, ED, ES


# ------------------------------------------------------- paper Table VII
class TestPaperTableVII:
    def test_our_strategy_matches_paper(self):
        """Paper: ours = 150 whole response / 43 last completion."""
        s = scheduler.neighborhood_search(table6_jobs())
        assert s.unweighted_sum == 150
        assert s.last_end == 43

    def test_all_device_matches_paper(self):
        s = scheduler.all_on_tier(table6_jobs(), ED)
        assert s.unweighted_sum == 366 and s.last_end == 94

    def test_single_tier_strategies_match_paper_with_label_swap(self):
        """Paper reports {cloud: 291, edge: 416} with the cloud/edge labels
        swapped relative to its own Table VI transmission columns
        (DESIGN.md §1): our all-edge = 291, all-cloud = 416/100."""
        e = scheduler.all_on_tier(table6_jobs(), ES)
        c = scheduler.all_on_tier(table6_jobs(), CC)
        assert e.unweighted_sum == 291
        assert c.unweighted_sum == 416 and c.last_end == 100

    def test_heuristic_close_to_exact_optimum(self):
        jobs = table6_jobs()
        ours = scheduler.neighborhood_search(jobs)
        opt = scheduler.exact_optimum(jobs, objective="weighted")
        assert ours.weighted_sum <= opt.weighted_sum * 1.05

    def test_beats_every_baseline(self):
        jobs = table6_jobs()
        ours = scheduler.neighborhood_search(jobs)
        for strat in (scheduler.per_job_optimal(jobs),
                      scheduler.all_on_tier(jobs, CC),
                      scheduler.all_on_tier(jobs, ES),
                      scheduler.all_on_tier(jobs, ED)):
            assert ours.weighted_sum <= strat.weighted_sum

    def test_lower_bound_holds(self):
        from repro.core.lower_bound import jobwise_last_bound
        jobs = table6_jobs()
        opt = scheduler.exact_optimum(jobs, objective="weighted")
        assert paper_lower_bound(jobs) <= opt.weighted_sum
        assert jobwise_last_bound(jobs) <= load_lower_bound(jobs)
        assert load_lower_bound(jobs) <= opt.last_end + 1e-9


# ------------------------------------------------------------- properties
def random_jobs(rng, n=None):
    n = n or int(rng.integers(3, 9))
    jobs = []
    for i in range(n):
        proc = {t: float(rng.integers(1, 30)) for t in MACHINES}
        trans = {CC: float(rng.integers(0, 60)),
                 ES: float(rng.integers(0, 15)), ED: 0.0}
        jobs.append(JobSpec(name=f"J{i}", release=float(rng.integers(0, 30)),
                            weight=float(rng.integers(1, 3)),
                            proc=proc, trans=trans))
    return jobs


def check_schedule_valid(jobs, sched):
    for e in sched.entries:
        assert e.start >= e.job.release + e.job.trans[e.machine] - 1e-9
        assert abs(e.end - e.start - e.job.proc[e.machine]) < 1e-9
    # no overlap on shared machines
    for tier in (CC, ES):
        spans = sorted((e.start, e.end) for e in sched.entries
                       if e.machine == tier)
        for (s1, e1), (s2, _) in zip(spans, spans[1:]):
            assert s2 >= e1 - 1e-9, "overlap on shared machine"


def test_property_schedules_valid_and_ordered():
    def check(rng):
        jobs = random_jobs(rng)
        ours = scheduler.neighborhood_search(jobs)
        check_schedule_valid(jobs, ours)
        # heuristic respects lower bound and beats/meets baselines
        assert ours.weighted_sum >= paper_lower_bound(jobs) - 1e-9
        for t in MACHINES:
            base = scheduler.all_on_tier(jobs, t)
            check_schedule_valid(jobs, base)
            assert ours.weighted_sum <= base.weighted_sum + 1e-9
    sweep(check, n_cases=15)


def test_property_exact_optimum_below_heuristic():
    def check(rng):
        jobs = random_jobs(rng, n=int(rng.integers(3, 7)))
        ours = scheduler.neighborhood_search(jobs)
        opt = scheduler.exact_optimum(jobs)
        assert opt.weighted_sum <= ours.weighted_sum + 1e-9
        assert opt.weighted_sum >= paper_lower_bound(jobs) - 1e-9
    sweep(check, n_cases=10)


def test_jax_evaluator_matches_python_simulator():
    def check(rng):
        jobs = random_jobs(rng)
        n = len(jobs)
        assigns = rng.integers(0, 3, size=(8, n))
        rel, w, proc, trans = scheduler_jax.specs_to_arrays(jobs)
        m = scheduler_jax.evaluate_assignments(
            np.asarray(assigns, np.int32), rel, w, proc, trans)
        for a_idx in range(8):
            assign = [MACHINES[j] for j in assigns[a_idx]]
            s = simulate(jobs, assign)
            assert abs(float(m["weighted"][a_idx]) - s.weighted_sum) < 1e-3
            assert abs(float(m["last"][a_idx]) - s.last_end) < 1e-3
    sweep(check, n_cases=8)


def test_jax_exact_optimum_matches_python():
    rng = np.random.default_rng(42)
    jobs = random_jobs(rng, n=6)
    v, a = scheduler_jax.exact_optimum_jax(jobs, objective="weighted")
    opt = scheduler.exact_optimum(jobs, objective="weighted")
    assert abs(v - opt.weighted_sum) < 1e-6


def test_multi_machine_edge_tier():
    """Two edge machines halve queueing for edge-heavy loads."""
    jobs = [JobSpec(name=f"J{i}", release=0, weight=1,
                    proc={CC: 100, ES: 10, ED: 100},
                    trans={CC: 0, ES: 0, ED: 0}) for i in range(4)]
    one = simulate(jobs, [ES] * 4, machines_per_tier={CC: 1, ES: 1})
    two = simulate(jobs, [ES] * 4, machines_per_tier={CC: 1, ES: 2})
    assert two.last_end < one.last_end
    check_schedule_valid(jobs, one)
