"""Serving engine + checkpoint + data pipeline tests."""
import pytest

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer
from repro.configs import get_config
from repro.data.pipeline import MarkovTokenDataset, make_batch
from repro.models import build_model
from repro.serving.engine import ServingEngine


@pytest.mark.slow
def test_greedy_generation_matches_teacher_forced_argmax():
    cfg = get_config("qwen2-1.5b").reduced(layers=2, d_model=64, vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params)
    batch = make_batch(cfg, 2, 8, seed=1)
    res = eng.generate(batch, steps=4)
    assert res.tokens.shape == (2, 12)
    # re-derive greedily with teacher forcing over the generated stream
    toks = res.tokens
    for t in range(8, 12):
        full, _ = model.forward(params, {"tokens": toks[:, :t]})
        want = jnp.argmax(full[:, -1], -1)
        np.testing.assert_array_equal(np.asarray(want),
                                      np.asarray(toks[:, t]))
    assert res.prefill_seconds > 0 and res.decode_seconds > 0


def test_checkpoint_roundtrip_and_errors():
    cfg = get_config("gemma-2b").reduced(layers=2, d_model=64, vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        checkpointer.save(d, 7, {"params": params})
        assert checkpointer.latest_step(d) == 7
        restored = checkpointer.restore(d, {"params": params})
        for a, b in zip(jax.tree.leaves(restored),
                        jax.tree.leaves({"params": params})):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # shape mismatch must raise
        bad = {"params": jax.tree.map(
            lambda a: jnp.zeros(a.shape + (1,), a.dtype), params)}
        try:
            checkpointer.restore(d, bad)
            raise AssertionError("expected shape mismatch error")
        except ValueError:
            pass


def test_markov_dataset_deterministic():
    a = MarkovTokenDataset(64, 16, 4, seed=3)
    b = MarkovTokenDataset(64, 16, 4, seed=3)
    ba = next(iter(a.batches()))
    bb = next(iter(b.batches()))
    np.testing.assert_array_equal(np.asarray(ba["tokens"]),
                                  np.asarray(bb["tokens"]))
    # tokens follow the bigram table
    tok = np.asarray(ba["tokens"])
    for row in tok:
        for t in range(1, len(row)):
            assert row[t] in a.table[row[t - 1]]


def test_icu_generator_shapes_and_signal():
    from repro.configs.icu_lstm import ICU_WORKLOADS
    from repro.data import icu
    for wl in ICU_WORKLOADS:
        x, y = icu.generate(wl, 32, seed=1)
        assert x.shape == (32, wl.seq_len, wl.input_dim)
        if wl.num_classes == 25:
            assert y.shape == (32, 25)
        else:
            assert set(np.unique(y)) <= {0, 1}
            # label-conditional drift is present
            pos = x[y == 1, -1, :4].mean()
            neg = x[y == 0, -1, :4].mean()
            assert pos > neg
