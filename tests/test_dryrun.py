"""Dry-run machinery tests.

The full 40-combination sweep is executed by ``python -m
repro.launch.dryrun --all`` (EXPERIMENTS.md §Dry-run); here we check the
machinery itself: the 512-device env bootstrap, the mesh builders, the
collective-bytes HLO parser, and one real (small-arch) lower+compile in a
subprocess (device count must be set before jax initialises, so the main
pytest process — which sees 1 CPU — can't do it inline)."""
import pytest

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, timeout=560):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", code], check=True,
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


@pytest.mark.slow
def test_production_mesh_shapes_in_subprocess():
    out = run_py(
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512';"
        "from repro.launch.mesh import make_production_mesh;"
        "m1=make_production_mesh();m2=make_production_mesh(multi_pod=True);"
        "print(dict(m1.shape), dict(m2.shape))")
    assert "{'data': 16, 'model': 16}" in out.stdout
    assert "{'pod': 2, 'data': 16, 'model': 16}" in out.stdout


@pytest.mark.slow
def test_single_case_dryrun_subprocess():
    """qwen2-1.5b decode_32k: fastest-compiling real case (~3 s)."""
    out = run_py(
        "from repro.launch.dryrun import run_case;"
        "import json;"
        "r=run_case('qwen2-1.5b','decode_32k',verbose=False);"
        "print(json.dumps({k:r[k] for k in ('arch','shape','mesh','devices',"
        "'hlo_flops')}));"
        "assert r['collectives']['total_bytes']>0;"
        "assert r['memory'].get('temp_size_in_bytes',0)>0")
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 256 and rec["mesh"] == "16x16"


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[4,4]{1,0} all-reduce(%y), to_apply=%add
  ROOT %t = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-to-all(%a, %b)
  %done = f32[4]{0} all-reduce-done(%start)
"""
    got = collective_bytes(hlo)
    assert got["bytes_by_op"]["all-gather"] == 8 * 128 * 2
    assert got["bytes_by_op"]["all-reduce"] == 64
    assert got["bytes_by_op"]["all-to-all"] == 32
    assert got["count_by_op"]["all-to-all"] == 1


def test_variant_for_shape_rules():
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch.dryrun import variant_for_shape
    long = INPUT_SHAPES["long_500k"]
    # pure full-attention dense arch gets the explicit window variant
    v = variant_for_shape(get_config("qwen2-1.5b"), long)
    assert v.long_context_window == 4096
    # native-SWA / recurrent archs run unmodified
    assert variant_for_shape(get_config("mixtral-8x7b"),
                             long).long_context_window is None
    assert variant_for_shape(get_config("xlstm-350m"),
                             long).long_context_window is None
    # non-long shapes never modified
    assert variant_for_shape(get_config("qwen2-1.5b"),
                             INPUT_SHAPES["train_4k"]) \
        == get_config("qwen2-1.5b")
