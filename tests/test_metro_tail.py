"""Tail-tolerance tests for the metro engine (DESIGN.md §13):
fail-slow slowdown windows and their re-timing math, the hedge
watchdog/backup/cancellation lifecycle, bounded retries with backoff,
the HedgingPolicy class gate, the fail_slow_tail ranking invariant, the
metro_hedging regression-gate logic, and a fuzzed chaos-invariant sweep
over every fleet-event kind."""
import os
import sys

import numpy as np
import pytest

from prop import random_fleet_events, sweep
from repro.core.simulator import JobSpec
from repro.core.tiers import CC, ED, ES
from repro.metro import traces
from repro.metro.engine import (FailureEvent, MetroEngine, SlowdownEvent,
                                _Pool, _finish_time, _work_done,
                                simulate_metro)
from repro.metro.metrics import MetroMetrics, StreamingQuantiles
from repro.metro.policies import (GreedyPolicy, HedgeRequest, HedgingPolicy,
                                  TabuPolicy)

MPT = {CC: 1, ES: 1}


def _cloud_job(name, release, proc_c, trans_c=2.0, proc_d=500.0,
               deadline=float("inf"), weight=1.0, workload=""):
    return JobSpec(name=name, release=release, weight=weight,
                   proc={CC: proc_c, ES: 500.0, ED: proc_d},
                   trans={CC: trans_c, ES: 0.0, ED: 0.0},
                   deadline=deadline, workload=workload)


class _HedgeTo:
    """Test policy: inner decisions untouched, hedge always to `tier`."""
    name = "hedge_to"
    joint = False
    replans_on_fleet_events = False

    def __init__(self, tier):
        self.inner = GreedyPolicy()
        self.tier = tier

    def decide(self, requests, now):
        return self.inner.decide(requests, now)

    def hedge(self, req, now):
        return self.tier


# -------------------------------------------------- fail-slow re-timing
def test_work_done_and_finish_time_are_inverse():
    win = [(5.0, 25.0, 0.5), (10.0, 15.0, 0.4)]     # overlap compounds
    for start, work in ((0.0, 3.0), (2.0, 10.0), (7.0, 4.0), (30.0, 5.0)):
        end = _finish_time(win, start, work)
        assert _work_done(win, start, end) == pytest.approx(work)
    # the exact early-returns (no window): bit-identical wall clock
    assert _work_done([], 3.0, 11.0) == 8.0
    assert _finish_time([], 3.0, 8.0) == 11.0
    # work past every window resumes nominal rate
    assert _finish_time([(0.0, 10.0, 0.5)], 0.0, 20.0) == 25.0


def test_slowdown_stretches_in_flight_job_exactly():
    # A starts at 2 (trans 2), nominal end 12; at t=5 the machine slows
    # to half speed for 20: 3 of 10 units done, 7 remain at 0.5 -> 14
    # wall seconds -> end 19, placement unchanged (C2)
    jobs = [_cloud_job("A", 0.0, proc_c=10.0)]
    slow = SlowdownEvent(time=5.0, tier=CC, duration=20.0, factor=0.5)
    res = simulate_metro([jobs], GreedyPolicy(), machines_per_tier=MPT,
                         slowdowns=[slow])
    (a,) = res.wards[0].entries
    assert (a.machine, a.start, a.end) == (CC, 2.0, 19.0)
    assert ("slow", 5.0, CC, -1, 0, 25.0, 0.5) in res.event_log
    assert ("slowend", 25.0, CC, -1) in res.event_log
    assert res.metrics.retries == 0          # nothing was lost


def test_slowdown_delays_queued_successor():
    # B queues behind A on the single cloud machine; A's stretch must
    # push B's start/end through the replay, and B's own run inside the
    # window is slowed too
    jobs = [_cloud_job("A", 0.0, proc_c=10.0),
            _cloud_job("B", 0.0, proc_c=4.0, trans_c=3.0)]
    slow = SlowdownEvent(time=5.0, tier=CC, duration=100.0, factor=0.5)
    res = simulate_metro([jobs], GreedyPolicy(), machines_per_tier=MPT,
                         slowdowns=[slow])
    a, b = sorted(res.wards[0].entries, key=lambda e: e.start)
    assert a.end == pytest.approx(19.0)      # as above
    assert b.start == pytest.approx(19.0)    # FIFO successor
    assert b.end == pytest.approx(19.0 + 4.0 / 0.5)


def test_slowdown_validation():
    jobs = [[_cloud_job("A", 0.0, proc_c=1.0)]]
    with pytest.raises(ValueError, match="factor"):
        MetroEngine(jobs, GreedyPolicy(), machines_per_tier=MPT,
                    slowdowns=[SlowdownEvent(time=0.0, factor=1.0)])
    with pytest.raises(ValueError, match="duration"):
        MetroEngine(jobs, GreedyPolicy(), machines_per_tier=MPT,
                    slowdowns=[SlowdownEvent(time=0.0, duration=0.0)])


def test_capacity_integral_prices_slowdowns_and_outages():
    pool = _Pool(CC, 1)
    slot = pool.slots[0]
    # a lone half-speed window [10, 20) forgoes 5 machine-seconds
    slot.slowdowns = [(10.0, 20.0, 0.5)]
    assert pool.capacity_integral(30.0) == pytest.approx(30.0 - 5.0)
    # a window inside an outage is NOT double-subtracted: the outage
    # already removed those seconds entirely
    slot.outages = [(8.0, 22.0)]
    assert pool.capacity_integral(30.0) == pytest.approx(30.0 - 14.0)
    # partial overlap: only the uncovered part of the window is shaved
    slot.outages = [(15.0, 40.0)]
    assert pool.capacity_integral(30.0) == \
        pytest.approx(30.0 - 15.0 - 0.5 * 5.0)


# ------------------------------------------------------ hedge lifecycle
def test_hedge_backup_wins_and_primary_cancelled():
    # A on cloud (start 2, nominal end 12) crawls at 0.1x from t=4: end
    # stretches to 84, the 1.5x watchdog fires at 17, the device backup
    # lands at 30 and wins; the loser is cut at 30 having consumed
    # 2 + 26*0.1 = 4.6 service units
    jobs = [_cloud_job("A", 0.0, proc_c=10.0, proc_d=13.0)]
    slow = SlowdownEvent(time=4.0, tier=CC, duration=100.0, factor=0.1)
    res = simulate_metro([jobs], _HedgeTo(ED), machines_per_tier=MPT,
                         slowdowns=[slow], hedge_factor=1.5)
    (a,) = res.wards[0].entries
    assert (a.machine, a.start, a.end) == (ED, 17.0, 30.0)
    assert ("hedge", 17.0, 0, 0, CC, ED) in res.event_log
    cancel = next(e for e in res.event_log if e[0] == "hedge_cancel")
    assert cancel[:5] == ("hedge_cancel", 30.0, 0, 0, CC)
    assert cancel[5] == pytest.approx(4.6)
    m = res.metrics
    assert (m.hedges, m.hedge_wins) == (1, 1)
    assert m.hedge_waste == pytest.approx(4.6)
    assert m.hedge_by_tier == {ED: 1}
    assert m.hedge_waste_by_tier == {CC: pytest.approx(4.6)}
    comp = next(e for e in res.event_log if e[0] == "complete")
    assert comp[4] == ED and comp[1] == 30.0


def test_hedge_primary_wins_and_backup_cancelled():
    # milder slowdown: primary ends at 20, the device backup (end 217)
    # loses the race and is cancelled at 20 with 3 wall seconds consumed
    jobs = [_cloud_job("A", 0.0, proc_c=10.0, proc_d=200.0)]
    slow = SlowdownEvent(time=4.0, tier=CC, duration=100.0, factor=0.5)
    res = simulate_metro([jobs], _HedgeTo(ED), machines_per_tier=MPT,
                         slowdowns=[slow], hedge_factor=1.5)
    (a,) = res.wards[0].entries
    assert (a.machine, a.end) == (CC, 20.0)
    cancel = next(e for e in res.event_log if e[0] == "hedge_cancel")
    assert cancel[:5] == ("hedge_cancel", 20.0, 0, 0, ED)
    assert cancel[5] == pytest.approx(3.0)
    m = res.metrics
    assert (m.hedges, m.hedge_wins) == (1, 0)
    assert m.hedge_waste == pytest.approx(3.0)


def test_crash_on_hedged_primary_promotes_backup():
    # the crash takes the straggling primary AFTER a backup is in
    # flight: no re-decision — the backup is promoted to THE commitment
    jobs = [_cloud_job("A", 0.0, proc_c=10.0, proc_d=13.0)]
    slow = SlowdownEvent(time=4.0, tier=CC, duration=100.0, factor=0.1)
    crash = FailureEvent(time=20.0, tier=CC, duration=5.0,
                         kill_running=True)
    res = simulate_metro([jobs], _HedgeTo(ED), machines_per_tier=MPT,
                         slowdowns=[slow], failures=[crash],
                         hedge_factor=1.5)
    (a,) = res.wards[0].entries
    assert (a.machine, a.end) == (ED, 30.0)
    assert ("hedge_promote", 20.0, 0, 0, ED) in res.event_log
    comp = next(e for e in res.event_log if e[0] == "complete")
    assert comp[-1] == 2                     # the kill still counts
    m = res.metrics
    assert m.retries == 1 and m.completions == 1
    assert m.hedge_wins == 1                 # the backup's completion won


def test_crash_on_backup_is_a_cancellation_not_a_loss():
    # primary runs on the ward edge; the hedge races a cloud backup; the
    # cloud crash takes the BACKUP — the primary keeps running and the
    # job never counts as killed
    job = JobSpec(name="A", release=0.0, weight=1.0,
                  proc={CC: 30.0, ES: 10.0, ED: 500.0},
                  trans={CC: 2.0, ES: 0.0, ED: 0.0})
    slow = SlowdownEvent(time=2.0, tier=ES, ward=0, duration=100.0,
                         factor=0.1)
    crash = FailureEvent(time=20.0, tier=CC, duration=5.0,
                         kill_running=True)
    res = simulate_metro([[job]], _HedgeTo(CC), machines_per_tier=MPT,
                         slowdowns=[slow], failures=[crash],
                         hedge_factor=1.5)
    (a,) = res.wards[0].entries
    assert a.machine == ES and a.end == pytest.approx(82.0)
    cancel = next(e for e in res.event_log if e[0] == "hedge_cancel")
    assert cancel[1:5] == (20.0, 0, 0, CC)
    assert not any(e[0] == "kill" for e in res.event_log)
    m = res.metrics
    assert (m.retries, m.hedges, m.hedge_wins) == (0, 1, 0)
    assert m.completions == 1


def test_at_most_one_hedge_per_job():
    # after the first backup loses, further slowdown re-arms must NOT
    # dispatch a second hedge (self.hedged persists for the job's life)
    jobs = [_cloud_job("A", 0.0, proc_c=10.0, proc_d=200.0)]
    slows = [SlowdownEvent(time=4.0, tier=CC, duration=100.0, factor=0.5),
             SlowdownEvent(time=18.0, tier=CC, duration=50.0, factor=0.5)]
    res = simulate_metro([jobs], _HedgeTo(ED), machines_per_tier=MPT,
                         slowdowns=slows, hedge_factor=1.5)
    assert res.metrics.hedges == 1
    assert sum(1 for e in res.event_log if e[0] == "hedge") == 1


def test_hedge_to_committed_tier_rejected():
    jobs = [_cloud_job("A", 0.0, proc_c=10.0)]
    slow = SlowdownEvent(time=4.0, tier=CC, duration=100.0, factor=0.1)
    with pytest.raises(ValueError, match="hedge policy returned"):
        simulate_metro([jobs], _HedgeTo(CC), machines_per_tier=MPT,
                       slowdowns=[slow], hedge_factor=1.5)


def test_hedging_knob_validation():
    jobs = [[_cloud_job("A", 0.0, proc_c=1.0)]]
    with pytest.raises(ValueError, match="hedge_factor"):
        MetroEngine(jobs, _HedgeTo(ED), machines_per_tier=MPT,
                    hedge_factor=1.0)
    with pytest.raises(ValueError, match="hedge"):
        MetroEngine(jobs, GreedyPolicy(), machines_per_tier=MPT,
                    hedge_factor=1.5)        # no hedge() hook
    with pytest.raises(ValueError, match="retry_backoff"):
        MetroEngine(jobs, GreedyPolicy(), machines_per_tier=MPT,
                    retry_backoff=-1.0)
    with pytest.raises(ValueError, match="max_attempts"):
        MetroEngine(jobs, GreedyPolicy(), machines_per_tier=MPT,
                    max_attempts=0)
    with pytest.raises(ValueError, match="max_attempts"):
        MetroEngine(jobs, GreedyPolicy(), machines_per_tier=MPT,
                    max_attempts={"alert": 0})


# --------------------------------------------- bounded retries / backoff
def test_retry_cap_sheds_with_record():
    # one attempt allowed: the crash kill exhausts the cap immediately
    # and the job is shed-with-record, never re-dispatched
    jobs = [_cloud_job("A", 0.0, proc_c=10.0, deadline=30.0,
                       workload="alert")]
    crash = FailureEvent(time=5.0, tier=CC, duration=10.0,
                         kill_running=True)
    res = simulate_metro([jobs], GreedyPolicy(), machines_per_tier=MPT,
                         failures=[crash], max_attempts=1)
    assert ("giveup", 5.0, 0, 0, "A", 1) in res.event_log
    m = res.metrics
    assert (m.completions, m.shed, m.retry_exhausted) == (0, 1, 1)
    assert m.finished == 1 and m.miss_rate == 1.0
    assert res.wards[0].entries == []
    # per-class cap: an unlisted class stays unbounded
    res2 = simulate_metro([jobs], GreedyPolicy(), machines_per_tier=MPT,
                          failures=[crash],
                          max_attempts={"phenotype": 1})
    assert res2.metrics.completions == 1
    assert res2.metrics.retry_exhausted == 0


def test_retry_backoff_delays_re_decision():
    # immediate-retry legacy path re-decides in the crash instant; with
    # backoff 3 the first retry matures at 5 + 3*2^0 = 8 and the job
    # restarts after the repair at 15
    jobs = [_cloud_job("A", 0.0, proc_c=10.0)]
    crash = FailureEvent(time=5.0, tier=CC, duration=10.0,
                         kill_running=True)
    res = simulate_metro([jobs], GreedyPolicy(), machines_per_tier=MPT,
                         failures=[crash], retry_backoff=3.0)
    assert ("retry", 8.0, 0, 0, 2) in res.event_log
    (a,) = res.wards[0].entries
    assert (a.start, a.end) == (15.0, 25.0)
    comp = next(e for e in res.event_log if e[0] == "complete")
    assert comp[-1] == 2
    # per-tier breakout of the kill (satellite: MetroMetrics.summary)
    s = res.summary()
    assert s["retries_by_tier"] == {CC: 1}
    assert s["wasted_by_tier"][CC] == pytest.approx(3.0)


# -------------------------------------------------- HedgingPolicy gate
def _hedge_req(weight, projected_end, tier=CC, reserved_es=0.0):
    job = JobSpec(name="J", release=0.0, weight=weight,
                  proc={CC: 5.0, ES: 4.0, ED: 50.0},
                  trans={CC: 2.0, ES: 1.0, ED: 0.0})
    return HedgeRequest(ward=0, job=job, tier=tier,
                        projected_end=projected_end,
                        busy={CC: [], ES: []},
                        reserved={CC: [0.0], ES: [reserved_es]},
                        machines_per_tier={CC: 1, ES: 1})


def test_hedging_policy_hedges_only_heaviest_class():
    pol = HedgingPolicy(min_gain=2.0)
    pol._see([JobSpec(name="H", release=0.0, weight=2.0,
                      proc={CC: 1.0}, trans={CC: 0.0})])
    assert pol.hedge(_hedge_req(1.0, projected_end=100.0), 0.0) is None
    assert pol.hedge(_hedge_req(2.0, projected_end=100.0), 0.0) == ES


def test_hedging_policy_declines_without_min_gain():
    pol = HedgingPolicy(min_gain=2.0)
    # best backup: edge, end = max(arr=1, free=0, now=0) + 4 = 5; the
    # hedge needs projected_end > 5 + 2
    assert pol.hedge(_hedge_req(1.0, projected_end=6.9), 0.0) is None
    assert pol.hedge(_hedge_req(1.0, projected_end=7.1), 0.0) == ES
    # a backed-up edge queue prices the backlog in
    assert pol.hedge(_hedge_req(1.0, projected_end=7.1,
                                reserved_es=50.0), 0.0) is None


def test_hedging_policy_proxies_inner():
    inner = TabuPolicy(jax_threshold=10 ** 9)
    pol = HedgingPolicy(inner=inner)
    assert pol.joint == inner.joint
    assert pol.replans_on_fleet_events == inner.replans_on_fleet_events


# --------------------------------------------- streaming tail counters
def test_streaming_quantiles_counts_out_of_range():
    q = StreamingQuantiles(1.0, 100.0, 8)
    for x in (0.5, 0.9, 5.0, 50.0, 200.0):
        q.add(x)
    assert (q.underflow, q.overflow) == (2, 1)
    other = StreamingQuantiles(1.0, 100.0, 8)
    other.add(0.1)
    other.add(1000.0)
    q.merge(other)
    assert (q.underflow, q.overflow) == (3, 2)


def test_metrics_summary_surfaces_tail_counters():
    m = MetroMetrics()
    lo = m.total.lo
    m.record(0.0, "alert", lo / 2.0, 100.0, CC, 1.0)
    s = m.summary()
    assert s["tail_underflow"] == 1 and s["tail_overflow"] == 0
    assert "p999" in s and "p999_by_class" in s


# ---------------------------------------------- fail_slow_tail pack
def test_fail_slow_tail_pack_is_slowdowns_only():
    sc = traces.make_scenario("fail_slow_tail", seed=0)
    assert sc.slowdowns and not sc.failures
    assert all(e.tier == ES and 0.0 < e.factor < 1.0
               for e in sc.slowdowns)
    assert [e.time for e in sc.slowdowns] == \
        sorted(e.time for e in sc.slowdowns)


@pytest.mark.slow
def test_fail_slow_tail_hedged_ranking_invariant():
    """The committed claim (DESIGN.md §13): under the canonical
    fail_slow_tail pack, hedged tabu strictly beats unhedged tabu on
    BOTH the life-critical miss rate and p99 — and the hedged run is
    bit-identical across reruns with backups/cancellations in flight."""
    sc = traces.make_scenario("fail_slow_tail", seed=0)
    mpt = {CC: 2, ES: 2}

    def run(hedged):
        pol = TabuPolicy(jax_threshold=10 ** 9)
        kw = {}
        if hedged:
            pol = HedgingPolicy(inner=pol)
            kw["hedge_factor"] = 1.5
        return simulate_metro(sc.traces, pol, machines_per_tier=mpt,
                              slowdowns=sc.slowdowns, **kw)

    base = run(False).summary()
    h1, h2 = run(True), run(True)
    assert h1.event_log == h2.event_log
    hs = h1.summary()
    assert hs["hedges"] > 0 and hs["hedge_wins"] > 0
    assert hs["critical_miss_rate"] < base["critical_miss_rate"]
    assert hs["p99"] < base["p99"]


# ----------------------------------------- metro_hedging gate logic
class TestHedgingGate:
    """check_regression.py metro_hedging logic (no bench run)."""

    def _mod(self):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        os.pardir, "benchmarks"))
        try:
            import check_regression
        finally:
            sys.path.pop(0)
        return check_regression

    def _reports(self):
        base = {"metro_hedging": {"events_per_s": 10000.0,
                                  "critical_improvement_hedge": 8.0,
                                  "p99_improvement_hedge": 1.003}}
        import copy
        return base, copy.deepcopy(base)

    def test_metric_extraction(self):
        cr = self._mod()
        committed, _ = self._reports()
        assert cr._metro_hedging_metrics(committed) == {
            "metro_hedging/events_per_s": 10000.0,
            "metro_hedging/critical_improvement_hedge": 8.0,
            "metro_hedging/p99_improvement_hedge": 1.003}

    def test_identical_reports_pass(self):
        cr = self._mod()
        committed, fresh = self._reports()
        assert cr.compare(committed, fresh) == []

    def test_ranking_loss_fails_regardless_of_tolerance(self):
        cr = self._mod()
        for field in ("critical_improvement_hedge",
                      "p99_improvement_hedge"):
            committed, fresh = self._reports()
            fresh["metro_hedging"][field] = 0.97
            problems = cr.compare(committed, fresh, tolerance=100.0)
            assert any("no longer beats unhedged" in p for p in problems)

    def test_vacuous_critical_improvement_skipped(self):
        cr = self._mod()
        committed, fresh = self._reports()
        fresh["metro_hedging"]["critical_improvement_hedge"] = None
        assert cr.compare(committed, fresh, tolerance=0.30) == []

    def test_events_floor_is_wall_clock_rerunnable(self):
        cr = self._mod()
        assert cr._is_wall_clock("metro_hedging/events_per_s")
        assert not cr._is_wall_clock(
            "metro_hedging/critical_improvement_hedge")
        committed, fresh = self._reports()
        key = "metro_hedging/events_per_s"
        fresh["metro_hedging"]["events_per_s"] = 1000.0
        assert cr.compare(committed, fresh) != []
        assert cr.compare(committed, fresh, best={key: 9500.0}) == []


# ------------------------------------------- fuzzed chaos invariants
@pytest.mark.slow
def test_fuzzed_event_interleavings_hold_engine_invariants():
    """Random crash/slowdown/scale/network orderings: every policy —
    hedged included — finishes every job completed-or-shed, never
    consumes more machine-seconds than the fleet could deliver
    (capacity-integral >= busy-time per shared pool), and replays
    bit-identically on a fresh engine."""
    mpt = {CC: 2, ES: 2}

    def policies():
        return (GreedyPolicy(),
                TabuPolicy(jax_threshold=10 ** 9),
                HedgingPolicy(inner=TabuPolicy(jax_threshold=10 ** 9),
                              min_gain=1.0))

    def check(rng):
        horizon, wards = 30.0, 2
        tr = traces.metro_traces(rng, wards, horizon, base_rate=0.15)
        if not any(tr):
            return
        events = random_fleet_events(rng, horizon, wards)
        for make in policies():
            runs = []
            for _ in range(2):
                import copy
                pol = copy.deepcopy(make)
                kw = {"hedge_factor": 1.3} \
                    if hasattr(pol, "hedge") else {}
                eng = MetroEngine(tr, pol, machines_per_tier=mpt,
                                  max_attempts=3, retry_backoff=1.0,
                                  **events, **kw)
                runs.append((eng, eng.run()))
            (e1, r1), (_, r2) = runs
            assert r1.event_log == r2.event_log, pol.name
            m = r1.metrics
            total = sum(len(t) for t in tr)
            assert m.finished == total, pol.name
            busy = m.busy_time
            assert e1.cloud.capacity_integral(e1._t_end) >= \
                busy.get(CC, 0.0) - 1e-6, pol.name
            edge_cap = sum(p.capacity_integral(e1._t_end)
                           for p in e1.edges)
            assert edge_cap >= busy.get(ES, 0.0) - 1e-6, pol.name
            for tier, u in r1.utilization.items():
                if tier != "device_concurrency":
                    assert u <= 1.0 + 1e-9, (pol.name, tier, u)

    sweep(check, n_cases=6, seed=11)
