"""Flight-recorder tests (DESIGN.md §15): span-tree structure (one root
per job, nested non-negative phases), sanitizer-I3 agreement, hedge-race
span accounting, the exact additive deadline-miss attribution, the
traced/untraced CRC-parity contract over every chaos pack, exporter
round-trips, the engine self-profile, and the windowed-metrics final
flush."""
import json
import zlib

import pytest

from repro.core.tiers import CC, ES
from repro.metro import traces
from repro.metro.engine import MetroEngine, simulate_metro
from repro.metro.metrics import MetroMetrics
from repro.metro.policies import HedgingPolicy, TabuPolicy
from repro.metro.tracing import TERMS, MetroTrace

MPT = {CC: 2, ES: 2}
ALL_PACKS = ("default", "edge_brownout", "mass_casualty_crash",
             "degraded_network", "diurnal_day", "fail_slow_tail")


def _run(pack="edge_brownout", seed=0, wards=2, horizon=45.0,
         hedged=False, **kw):
    sc = traces.make_scenario(pack, seed, wards=wards, horizon=horizon)
    pol = TabuPolicy(jax_threshold=10 ** 9)
    ekw = {}
    if hedged:
        pol = HedgingPolicy(inner=pol)
        ekw["hedge_factor"] = 1.5
    return simulate_metro(sc.traces, pol, machines_per_tier=MPT,
                          failures=sc.failures, scale_events=sc.scales,
                          network_events=sc.network,
                          slowdowns=sc.slowdowns, **ekw, **kw)


@pytest.fixture(scope="module")
def traced_brownout():
    return _run("edge_brownout", trace=True)


@pytest.fixture(scope="module")
def traced_tail():
    # the pack's canonical shape: reduced horizons never enter the deep
    # slowdown windows, so no hedge race would fire
    return _run("fail_slow_tail", wards=None, horizon=None, hedged=True,
                trace=True, profile=True, retry_backoff=0.5,
                max_attempts=4)


# ------------------------------------------------------- span structure
def test_off_by_default_and_zero_state():
    res = _run("diurnal_day", horizon=30.0)
    assert res.trace is None
    assert res.profile is None


def test_one_root_span_per_job(traced_brownout):
    res = traced_brownout
    roots = [sp for sp in res.trace.spans if sp.name == "root"]
    total = res.metrics.completions + res.metrics.shed
    assert len(roots) == total
    assert len({sp.trace for sp in roots}) == len(roots)
    # every root carries the job identity and closed non-negatively
    for sp in roots:
        assert sp.parent is None and sp.cat == "job"
        assert {"episode", "wclass", "weight", "deadline",
                "outcome", "missed"} <= set(sp.attrs)
        assert sp.t1 >= sp.t0


def test_span_nesting_and_no_negative_durations(traced_brownout,
                                                traced_tail):
    for res in (traced_brownout, traced_tail):
        by_id = {sp.span: sp for sp in res.trace.spans}
        for sp in res.trace.spans:
            assert sp.t1 >= sp.t0, (sp.name, sp.t0, sp.t1)
            if sp.parent is not None:
                par = by_id[sp.parent]
                assert par.t0 <= sp.t0 and sp.t1 <= par.t1, \
                    (sp.name, par.name)


def test_decision_backoff_and_attempt_span_counts(traced_tail):
    res = traced_tail
    spans = res.trace.spans
    # crash retries open a new attempt: attempt spans per job == the
    # completion record's attempt count (each killed attempt closes one
    # span, the final completion closes the last)
    completed = {}
    for rec in res.event_log:
        if rec[0] == "complete":
            completed[(rec[2], rec[3])] = rec[9]     # attempts
    by_job = {}
    for sp in spans:
        if sp.cat == "attempt" and sp.name == "attempt":
            by_job.setdefault(sp.trace, []).append(sp)
    for (b, i), attempts in completed.items():
        got = by_job.get(f"w{b}j{i}", [])
        assert len(got) == attempts, (b, i)
        outcomes = [sp.attrs["outcome"] for sp in got]
        assert outcomes.count("complete") == 1
        assert all(o == "killed" for o in outcomes[:-1])
    # retry records with a real backoff gap produce backoff spans
    n_backoff = sum(1 for sp in spans if sp.name == "backoff")
    n_retry = sum(1 for rec in res.event_log if rec[0] == "retry")
    assert n_backoff <= n_retry
    assert res.metrics.retries == 0 or n_retry > 0


# --------------------------------------------------- sanitizer agreement
def test_sanitizer_started_attempts_match_traced_spans():
    sc = traces.make_scenario("mass_casualty_crash", 0, wards=2,
                              horizon=45.0)
    eng = MetroEngine(sc.traces, TabuPolicy(jax_threshold=10 ** 9),
                      machines_per_tier=MPT, failures=sc.failures,
                      scale_events=sc.scales, network_events=sc.network,
                      slowdowns=sc.slowdowns)
    res = eng.run(sanitize=True, trace=True)
    started = eng._san._started
    assert started, "sanitizer saw no started attempts"
    # every attempt the sanitizer registered as STARTED must be visible
    # in the trace as a span occupying that (machine, slot)
    occupancy = {}
    for sp in res.trace.spans:
        if sp.cat == "attempt" and "machine" in sp.attrs:
            occupancy.setdefault(sp.trace, []).append(
                (sp.attrs["machine"], sp.attrs.get("slot")))
    for (b, i, _is_hedge, _k), (machine, slot, _t0) in started.items():
        assert (machine, slot) in occupancy.get(f"w{b}j{i}", []), \
            (b, i, machine, slot)


# ----------------------------------------------------------- hedge races
def test_hedge_race_one_winner_one_loser(traced_tail):
    res = traced_tail
    spans = res.trace.spans
    cancels = [rec for rec in res.event_log if rec[0] == "hedge_cancel"]
    losers = [sp for sp in spans if sp.name == "hedge_loser"]
    assert res.metrics.hedges > 0, "pack no longer exercises hedging"
    # one cancelled-loser span per cancellation, cut at the winner
    assert len(losers) == len(cancels)
    assert all(sp.attrs["outcome"] == "cancelled" for sp in losers)
    # hedge uniqueness (engine I5): at most one dispatch marker per job
    n_hedge = {}
    for sp in spans:
        if sp.name == "hedge":
            n_hedge[sp.trace] = n_hedge.get(sp.trace, 0) + 1
    assert all(n == 1 for n in n_hedge.values())
    # a won race: exactly one completing attempt flagged hedge_win with
    # its loser span present on the same job trace
    won = [r for r in res.trace.rows if r["hedge_win"]]
    assert len(won) == res.metrics.hedge_wins
    loser_traces = {sp.trace for sp in losers}
    for r in won:
        tid = f"w{r['ward']}j{r['index']}"
        wins = [sp for sp in spans
                if sp.trace == tid and sp.name == "attempt"
                and sp.attrs.get("hedge_win")]
        assert len(wins) == 1
        promoted = any(sp.trace == tid and sp.name == "hedge_promote"
                       for sp in spans)
        assert promoted or tid in loser_traces


def test_service_segments_partition_service_span(traced_tail):
    res = traced_tail
    by_id = {sp.span: sp for sp in res.trace.spans}
    segs = {}
    for sp in res.trace.spans:
        if sp.name == "service_seg":
            segs.setdefault(sp.parent, []).append(sp)
    assert segs, "fail_slow_tail produced no segmented service spans"
    for parent_id, parts in segs.items():
        svc = by_id[parent_id]
        parts.sort(key=lambda s: s.t0)
        assert parts[0].t0 == svc.t0 and parts[-1].t1 == svc.t1
        for a, b in zip(parts, parts[1:]):
            assert a.t1 == b.t0
        assert any(s.attrs["rate"] != 1.0 for s in parts)


# ----------------------------------------------------------- attribution
def test_attribution_terms_sum_exactly(traced_brownout, traced_tail):
    for res in (traced_brownout, traced_tail):
        assert res.trace.rows, "no finished jobs"
        for r in res.trace.rows:
            assert set(r["terms"]) == set(TERMS)
            assert sum(r["terms"].values()) == \
                pytest.approx(r["response"], abs=1e-9)
            assert r["dominant"] in TERMS
            # no negative components: waiting/transmit/service/slowdown
            # are physical durations, retry_waste is time actually lost
            for t, v in r["terms"].items():
                assert v >= -1e-9, (r["job"], t, v)


def test_blame_table_aggregates_missed_rows(traced_tail):
    tr = traced_tail.trace
    missed = tr.attribution(missed_only=True)
    table = tr.blame_table()
    assert sum(row["misses"] for row in table) == len(missed)
    for row in table:
        assert row["dominant"] in TERMS
        for t in TERMS:
            assert row["total_terms"][t] == pytest.approx(
                sum(r["terms"][t] for r in missed
                    if (r["wclass"], r["tier"])
                    == (row["wclass"], row["tier"])), abs=1e-9)
    text = tr.format_postmortem("tabu", traced_tail.profile)
    assert text.startswith("postmortem[tabu]")
    pm = tr.postmortem_json("tabu", traced_tail.profile)
    assert json.dumps(pm)        # JSON-serializable end to end


def test_shed_jobs_attribute_all_time_to_wait_and_retries():
    res = _run("mass_casualty_crash", horizon=45.0, trace=True,
               max_attempts=1)
    dropped = [r for r in res.trace.rows if r["outcome"] != "complete"]
    assert dropped, "pack no longer exhausts any retry budget"
    for r in dropped:
        assert r["terms"]["service"] == 0.0
        assert r["terms"]["transmit"] == 0.0
        assert r["terms"]["slowdown"] == 0.0
        assert r["missed"]


# ------------------------------------------------------------ parity
@pytest.mark.parametrize("pack", ALL_PACKS)
def test_traced_run_is_bit_identical(pack):
    hedged = pack == "fail_slow_tail"
    base = _run(pack, horizon=30.0, hedged=hedged)
    traced = _run(pack, horizon=30.0, hedged=hedged, trace=True,
                  profile=True)
    assert zlib.crc32(repr(base.event_log).encode()) == \
        zlib.crc32(repr(traced.event_log).encode())
    assert base.metrics.summary(base.utilization) == \
        traced.metrics.summary(traced.utilization)


# ------------------------------------------------------------ exporters
def test_jsonl_export_roundtrip(tmp_path, traced_brownout):
    path = tmp_path / "trace.jsonl"
    n = traced_brownout.trace.write(str(path), "jsonl")
    lines = path.read_text().splitlines()
    assert n == len(lines) == len(traced_brownout.trace.spans)
    for line, sp in zip(lines, traced_brownout.trace.spans):
        d = json.loads(line)
        assert d["span"] == sp.span and d["name"] == sp.name


def test_chrome_export_structure(tmp_path, traced_tail):
    path = tmp_path / "trace.chrome.json"
    n = traced_tail.trace.write(str(path), "chrome")
    doc = json.loads(path.read_text())
    ev = doc["traceEvents"]
    assert n == len(ev)
    phases = {e["ph"] for e in ev}
    assert {"M", "X", "b", "e"} <= phases
    assert all(e["dur"] >= 0.0 for e in ev if e["ph"] == "X")
    # async begin/end events balance per (id, name)
    opens = {}
    for e in ev:
        if e["ph"] == "b":
            opens[(e["id"], e["name"])] = \
                opens.get((e["id"], e["name"]), 0) + 1
        elif e["ph"] == "e":
            opens[(e["id"], e["name"])] -= 1
    assert all(v == 0 for v in opens.values())
    # machine-slot occupancy rows never overlap (engine invariant I2)
    rows = {}
    for e in ev:
        if e["ph"] == "X" and e.get("cat") == "occupancy":
            rows.setdefault((e["pid"], e["tid"]), []).append(
                (e["ts"], e["ts"] + e["dur"]))
    assert rows
    for spans in rows.values():
        spans.sort()
        for (_, end), (start, _) in zip(spans, spans[1:]):
            assert start >= end - 1e-6


def test_unknown_trace_format_rejected(tmp_path):
    tr = MetroTrace(spans=[], rows=[])
    with pytest.raises(ValueError, match="unknown trace format"):
        tr.write(str(tmp_path / "x"), "protobuf")


# ------------------------------------------------------------- profiling
def test_engine_profile_accounts_for_the_run(traced_tail):
    prof = traced_tail.profile
    assert prof is not None
    assert prof["events"] == traced_tail.summary()["events"]
    assert prof["seconds_total"] > 0.0
    assert prof["decide_calls"] > 0
    assert prof["heap_pushes"] >= prof["events"]
    assert prof["handlers_by_kind"]
    busy = (prof["replay"] + prof["policy"] + prof["sanitize"]
            + prof["hedge_hook"])
    assert 0.0 <= busy <= prof["seconds_total"] * 1.05
    assert set(prof["compiled_shapes_delta"]) == \
        {"hits", "misses", "evictions"}


# ------------------------------------------- windowed metrics final flush
def test_metrics_flush_preserves_open_window():
    m = MetroMetrics(window=60.0)
    m.record(10.0, "c", response=25.0, deadline=20.0, tier=CC, proc=5.0)
    m.record_shed(30.0, "c")
    assert not m.recent            # both land in the still-open window
    m.flush()
    assert len(m.recent) == 1
    m.flush()                      # idempotent: nothing open anymore
    assert len(m.recent) == 1
    s = m.summary()
    assert s["recent_windows"] == 1
    assert s["recent_finished"] == 2
    assert s["recent_misses"] >= 1
    assert 0.0 <= s["recent_miss_rate"] <= 1.0


def test_engine_flushes_final_partial_window(traced_brownout):
    s = traced_brownout.metrics.summary(traced_brownout.utilization)
    assert s["recent_windows"] >= 1
    assert s["recent_finished"] > 0
    assert s["recent_p99"] >= 0.0
