"""Tiny property-sweep helper (hypothesis is not installed in this offline
container — DESIGN.md §6). Runs a check over seeded random cases and
reports every failing seed, plus a fuzzed fleet-event generator for the
metro engine's chaos invariants (DESIGN.md §11/§13)."""
from __future__ import annotations

import numpy as np


def sweep(check, n_cases: int = 20, seed: int = 0):
    """check(rng) raises AssertionError on property violation."""
    failures = []
    for i in range(n_cases):
        rng = np.random.default_rng(seed + i)
        try:
            check(rng)
        except AssertionError as e:
            failures.append((seed + i, str(e)))
    assert not failures, f"{len(failures)}/{n_cases} cases failed: " \
                         f"{failures[:3]}"


def random_fleet_events(rng: np.random.Generator, horizon: float,
                        wards: int):
    """A fuzzed interleaving of every fleet-event kind the metro engine
    consumes — drain and crash failures, fail-slow slowdown windows,
    elastic scale events, degraded-network windows — on random tiers
    and wards, for the chaos-invariant property sweeps. Returns kwargs
    for `simulate_metro`."""
    from repro.core.tiers import CC, ES
    from repro.metro.engine import (FailureEvent, NetworkEvent, ScaleEvent,
                                    SlowdownEvent)

    def tier_ward():
        if rng.uniform() < 0.5:
            return CC, None
        return ES, int(rng.integers(wards))

    failures = []
    for _ in range(int(rng.integers(0, 4))):
        t, w = tier_ward()
        failures.append(FailureEvent(
            time=float(rng.uniform(0, horizon)), tier=t, ward=w,
            duration=float(rng.uniform(2, 0.3 * horizon)),
            kill_running=bool(rng.uniform() < 0.5)))
    slowdowns = []
    for _ in range(int(rng.integers(0, 4))):
        t, w = tier_ward()
        slowdowns.append(SlowdownEvent(
            time=float(rng.uniform(0, horizon)), tier=t, ward=w,
            duration=float(rng.uniform(2, 0.4 * horizon)),
            factor=float(rng.uniform(0.05, 0.8))))
    scales, downs = [], 0
    for _ in range(int(rng.integers(0, 3))):
        t, w = tier_ward()
        # at most one retirement: pools start at 2 machines and the
        # engine (rightly) rejects a scale-down below 1
        delta = int(rng.choice([-1, 1])) if downs == 0 else 1
        downs += delta < 0
        scales.append(ScaleEvent(
            time=float(rng.uniform(0, horizon)), tier=t, ward=w,
            delta=delta))
    network = []
    for _ in range(int(rng.integers(0, 3))):
        network.append(NetworkEvent(
            time=float(rng.uniform(0, horizon)), tier=CC,
            duration=float(rng.uniform(2, 0.3 * horizon)),
            factor=float(rng.uniform(1.5, 8.0))))
    return {"failures": sorted(failures, key=lambda e: e.time),
            "slowdowns": sorted(slowdowns, key=lambda e: e.time),
            "scale_events": sorted(scales, key=lambda e: e.time),
            "network_events": sorted(network, key=lambda e: e.time)}
