"""Tiny property-sweep helper (hypothesis is not installed in this offline
container — DESIGN.md §6). Runs a check over seeded random cases and
reports every failing seed."""
from __future__ import annotations

import numpy as np


def sweep(check, n_cases: int = 20, seed: int = 0):
    """check(rng) raises AssertionError on property violation."""
    failures = []
    for i in range(n_cases):
        rng = np.random.default_rng(seed + i)
        try:
            check(rng)
        except AssertionError as e:
            failures.append((seed + i, str(e)))
    assert not failures, f"{len(failures)}/{n_cases} cases failed: " \
                         f"{failures[:3]}"
