"""Per-arch smoke tests (reduced variants: <=2 groups, d_model<=512,
<=4 experts) + the decode-vs-teacher-forcing consistency invariant.

The full arch sweep is compile-bound (minutes on CPU); the fast tier-1
loop (`-m "not slow"`) runs one representative arch, the rest carry the
`slow` marker (DESIGN.md §6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model

FAST_ARCHS = ("qwen2-1.5b",)
ARCH_PARAMS = [pytest.param(n, marks=()) if n in FAST_ARCHS else
               pytest.param(n, marks=pytest.mark.slow) for n in ARCH_NAMES]
# the train-step smoke is eager (jit=False) and traces fwd+bwd for every
# arch — slow-tier everywhere; decode keeps fast forward coverage
SMOKE_PARAMS = [pytest.param(n, marks=pytest.mark.slow) for n in ARCH_NAMES]


def reduced_cfg(name):
    cfg = get_config(name)
    layers = 2 if len(cfg.group_pattern) <= 2 else None
    return cfg.reduced(layers=layers, d_model=128, vocab=256)


def make_batch(cfg, b, l, seed=0):
    rng = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(rng, (b, l), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            rng, (b, cfg.cross_attn_states, cfg.vision_dim))
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            rng, (b, cfg.encoder_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", SMOKE_PARAMS)
def test_smoke_forward_and_train_step(name):
    """One forward + one train step on CPU: shapes right, no NaNs."""
    cfg = reduced_cfg(name)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, l = 2, 32
    batch = make_batch(cfg, b, l)

    logits, _ = model.forward(params, batch)
    assert logits.shape[:2] == (b, l)
    assert logits.shape[2] >= cfg.vocab_size          # padded vocab
    assert not bool(jnp.any(jnp.isnan(logits)))

    from repro.training import optimizer, train_loop
    opt_cfg = optimizer.AdamWConfig(total_steps=10)
    step = train_loop.make_train_step(model, opt_cfg, jit=False)
    opt_state = optimizer.init(params)
    params2, _, metrics = step(params, opt_state, batch)
    assert not bool(jnp.isnan(metrics["loss"]))
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b_.astype(jnp.float32))))
                for a, b_ in zip(jax.tree.leaves(params),
                                 jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_decode_matches_teacher_forcing(name):
    """prefill + decode_step logits == full-sequence forward logits."""
    cfg = reduced_cfg(name)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, l = 2, 24
    batch = make_batch(cfg, b, l)
    full, _ = model.forward(params, batch)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :l - 3]
    logits, cache = model.prefill(params, pre, max_len=l)
    np.testing.assert_allclose(logits, full[:, l - 4], atol=2e-3, rtol=1e-2)
    for t in range(l - 3, l):
        logits, cache = model.decode_step(params, batch["tokens"][:, t],
                                          cache)
        np.testing.assert_allclose(logits, full[:, t], atol=2e-3, rtol=1e-2)


@pytest.mark.slow
def test_sliding_window_decode_ring_buffer():
    """With a window cache, decoding past the window still matches the
    windowed teacher-forced forward (ring buffer correctness)."""
    import dataclasses
    cfg = dataclasses.replace(reduced_cfg("mixtral-8x7b"), attn_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, l = 1, 24
    batch = make_batch(cfg, b, l, seed=2)
    full, _ = model.forward(params, batch)
    pre = {"tokens": batch["tokens"][:, :12]}
    logits, cache = model.prefill(params, pre, max_len=l)
    for t in range(12, l):   # decode well past the window of 8
        logits, cache = model.decode_step(params, batch["tokens"][:, t],
                                          cache)
        np.testing.assert_allclose(logits, full[:, t], atol=2e-3, rtol=1e-2)


def test_moe_router_load_balance_aux_positive():
    cfg = reduced_cfg("mixtral-8x7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32)
    x = model._embed(params, batch["tokens"])
    _, _, aux = model.stack.apply(params["stack"], x,
                                  model._ctx(params, batch), mode="train")
    assert float(aux["moe_aux"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz


def test_vocab_padding_masked():
    """seamless vocab 256206 pads to 256256; pad logits must be -inf-ish."""
    cfg = reduced_cfg("seamless-m4t-large-v2")
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=250)   # pads to 256
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 1, 8)
    batch["tokens"] = jnp.clip(batch["tokens"], 0, 249)
    logits, _ = model.forward(params, batch)
    assert logits.shape[-1] == 256
    assert float(jnp.max(logits[..., 250:])) < -1e20


def test_icu_lstm_forward_and_loss():
    from repro.configs.icu_lstm import ICU_WORKLOADS
    from repro.data import icu
    from repro.models.lstm import ICULSTM
    for wl in ICU_WORKLOADS:
        model = ICULSTM(wl)
        params = model.init(jax.random.PRNGKey(0))
        x, y = icu.generate(wl, 4, seed=0)
        logits = model.forward(params, jnp.asarray(x))
        expect = (4, wl.num_classes)
        assert logits.shape == expect
        loss = model.loss(params, {"features": jnp.asarray(x),
                                   "labels": jnp.asarray(y)})
        assert not bool(jnp.isnan(loss))
