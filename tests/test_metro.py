"""Metro traffic engine tests (DESIGN.md §10): seeded determinism,
event-order/capacity invariants, failure semantics (a running job is
never dropped; its machine's successors are delayed), B=1 parity with
`online_schedule`, and the streaming metrics layer."""
import numpy as np
import pytest

from repro.core import online
from repro.core.problems import poisson_jobs
from repro.core.tiers import CC, ED, ES
from repro.metro import traces
from repro.metro.engine import (FailureEvent, MetroEngine, ScaleEvent,
                                simulate_metro)
from repro.metro.metrics import MetroMetrics, StreamingQuantiles
from repro.metro.policies import (GreedyPolicy, TabuPolicy, make_policy)
from repro.core.simulator import JobSpec

MPT = {CC: 2, ES: 2}


def _scenario(seed=0, wards=2, horizon=60.0, **kw):
    return traces.default_scenario(seed, wards, horizon, **kw)


def _cloud_job(name, release, proc_c, trans_c=2.0, deadline=float("inf")):
    """A job only the cloud can run sensibly (edge/device prohibitive)."""
    return JobSpec(name=name, release=release, weight=1.0,
                   proc={CC: proc_c, ES: 500.0, ED: 500.0},
                   trans={CC: trans_c, ES: 0.0, ED: 0.0},
                   deadline=deadline)


# ------------------------------------------------------------ determinism
def test_seed_determinism_bit_identical():
    runs = []
    for _ in range(2):
        tr, fails, scales = _scenario(seed=7)
        res = simulate_metro(tr, TabuPolicy(), machines_per_tier=MPT,
                             failures=fails, scale_events=scales)
        runs.append(res)
    a, b = runs
    assert a.event_log == b.event_log
    assert a.metrics.summary(a.utilization) == \
        b.metrics.summary(b.utilization)
    for sa, sb in zip(a.wards, b.wards):
        assert sa.weighted_sum == sb.weighted_sum


def test_trace_determinism_and_episode_structure():
    t1 = traces.ward_trace(np.random.default_rng(3), 0, 90.0)
    t2 = traces.ward_trace(np.random.default_rng(3), 0, 90.0)
    assert [(j.name, j.release, j.deadline) for j in t1] == \
        [(j.name, j.release, j.deadline) for j in t2]
    # every episode is the full cascade, in clinical order
    by_ep = {}
    for j in t1:
        by_ep.setdefault(j.name.split("-")[0], []).append(j)
    stage_of = {s.short: s for s in traces.EPISODE_STAGES}
    for ep_jobs in by_ep.values():
        assert len(ep_jobs) == len(traces.EPISODE_STAGES)
        order = {j.name.split("-")[1]: j for j in ep_jobs}
        assert order["alert"].release <= order["phenotype"].release \
            <= order["threat"].release
        for short, j in order.items():
            st = stage_of[short]
            assert (j.weight, j.deadline, j.workload) == \
                (st.weight, st.deadline, st.workload)


def test_intensity_surge_and_diurnal():
    lam_base = traces.intensity(10.0, 1.0, diurnal_amp=0.0)
    assert lam_base == 1.0
    surged = traces.intensity(10.0, 1.0, diurnal_amp=0.0,
                              surges=[(5.0, 15.0, 3.0)])
    assert surged == pytest.approx(4.0)
    # surge windows really carry more episodes
    times = traces.episode_times(np.random.default_rng(0), 400.0, 0.2,
                                 diurnal_amp=0.0,
                                 surges=[(100.0, 200.0, 4.0)])
    inside = sum(100.0 <= t < 200.0 for t in times)
    outside = len(times) - inside
    assert inside > outside
    # overlapping surges COMPOUND; the thinning envelope must cover the
    # product or the sampled rate silently caps below the declared one
    over = traces.episode_times(np.random.default_rng(1), 40.0, 0.5,
                                diurnal_amp=0.0,
                                surges=[(0.0, 30.0, 3.0),
                                        (10.0, 40.0, 3.0)])
    in_overlap = sum(10.0 <= t < 30.0 for t in over)    # 16x base rate
    in_single = sum(t < 10.0 for t in over)             # 4x base rate
    assert in_overlap > 2 * in_single


# ------------------------------------------------- parity with DESIGN.md §7
def test_b1_no_failure_tabu_matches_online_schedule():
    for seed in range(4):
        for mpt in ({CC: 1, ES: 1}, {CC: 2, ES: 3}):
            jobs = poisson_jobs(np.random.default_rng(seed), n=14,
                                rate=0.3)
            ref = online.online_schedule(jobs, replan="tabu",
                                         machines_per_tier=mpt)
            got = simulate_metro([jobs], TabuPolicy(),
                                 machines_per_tier=mpt).wards[0]
            assert len(ref.entries) == len(got.entries)
            for a, b in zip(ref.entries, got.entries):
                assert (a.machine, a.arrival, a.start, a.end) == \
                    (b.machine, b.arrival, b.start, b.end)
            assert ref.weighted_sum == got.weighted_sum


# ------------------------------------------------------- event invariants
def _check_schedule_invariants(result, machines_per_tier, elastic=False):
    for sched in result.wards:
        for e in sched.entries:
            assert e.arrival >= e.job.release - 1e-9
            assert e.start >= e.arrival - 1e-9
            assert e.end == pytest.approx(e.start + e.job.proc[e.machine])
    # shared-pool concurrency never exceeds capacity (sweep line); the
    # cloud pool is fleet-wide, edge pools per ward
    def overlap_ok(spans, cap):
        events = sorted((s, 1) for s, _ in spans) + \
            sorted((t, -1) for _, t in spans)
        events.sort()
        live = peak = 0
        for _, d in events:
            live += d
            peak = max(peak, live)
        return peak <= cap
    cloud_spans = [(e.start, e.end) for s in result.wards
                   for e in s.entries if e.machine == CC]
    if not elastic:
        assert overlap_ok(cloud_spans, machines_per_tier[CC])
    for s in result.wards:
        spans = [(e.start, e.end) for e in s.entries if e.machine == ES]
        assert overlap_ok(spans, machines_per_tier[ES])
    # the log's completions carry the committed, deadline-scored truth
    completes = [ev for ev in result.event_log if ev[0] == "complete"]
    assert len(completes) == sum(len(s.entries) for s in result.wards)
    for _, t, b, i, tier, start, end, response, missed, attempts \
            in completes:
        e = result.wards[b].entries[i]
        assert (tier, start, end) == (e.machine, e.start, e.end)
        assert t == end and start <= end
        assert response == pytest.approx(end - e.job.release)
        assert missed == int(response > e.job.deadline)
        assert attempts >= 1


@pytest.mark.parametrize("policy", ["greedy", "tabu", "fleet"])
def test_event_order_invariants(policy):
    tr, fails, _ = _scenario(seed=11, wards=2, horizon=45.0,
                             elastic=False)
    kw = dict(max_count=2, max_sweeps=1) if policy == "fleet" else {}
    res = simulate_metro(tr, make_policy(policy, **kw),
                         machines_per_tier=MPT, failures=fails)
    _check_schedule_invariants(res, MPT)
    assert 0.0 <= res.metrics.miss_rate <= 1.0
    assert res.events > sum(len(t) for t in tr)


# ------------------------------------------------------- failure semantics
def test_failure_never_drops_running_job_and_delays_successors():
    jobs = [_cloud_job("A", 0.0, proc_c=10.0),
            _cloud_job("B", 1.0, proc_c=5.0, trans_c=1.0)]
    base = simulate_metro([jobs], GreedyPolicy(),
                          machines_per_tier={CC: 1, ES: 1})
    a0, b0 = base.wards[0].entries
    assert (a0.machine, b0.machine) == (CC, CC)
    assert a0.end == 12.0 and b0.start == 12.0
    # machine fails mid-run of A: A is NOT dropped (end unchanged), the
    # machine repairs after finishing A, and B waits for the repair
    fail = FailureEvent(time=5.0, tier=CC, duration=10.0)
    res = simulate_metro([jobs], GreedyPolicy(),
                         machines_per_tier={CC: 1, ES: 1},
                         failures=[fail])
    a, b = res.wards[0].entries
    assert (a.start, a.end) == (a0.start, a0.end)
    assert b.start == a0.end + 10.0 and b.end == b.start + 5.0
    kinds = [ev[0] for ev in res.event_log]
    assert "fail" in kinds and "recover" in kinds
    fail_ev = next(ev for ev in res.event_log if ev[0] == "fail")
    assert fail_ev[5] == a0.end + 10.0            # repaired after A drains


def test_tabu_replans_around_failure():
    # same fleet, but an edge escape route exists: the replanner should
    # beat (or match) greedy's committed-and-wait response
    jobs = [JobSpec("A", 0.0, 1.0, {CC: 10.0, ES: 30.0, ED: 60.0},
                    {CC: 2.0, ES: 1.0, ED: 0.0}),
            JobSpec("B", 1.0, 1.0, {CC: 5.0, ES: 12.0, ED: 60.0},
                    {CC: 1.0, ES: 1.0, ED: 0.0})]
    fail = FailureEvent(time=5.0, tier=CC, duration=30.0)
    greedy = simulate_metro([jobs], GreedyPolicy(),
                            machines_per_tier={CC: 1, ES: 1},
                            failures=[fail])
    tabu = simulate_metro([jobs], TabuPolicy(),
                          machines_per_tier={CC: 1, ES: 1},
                          failures=[fail])
    assert tabu.wards[0].weighted_sum <= greedy.wards[0].weighted_sum
    # the running job is immutable for BOTH policies
    assert tabu.wards[0].entries[0].end == \
        greedy.wards[0].entries[0].end


def test_elastic_scale_up_and_down():
    jobs = [_cloud_job("A", 0.0, proc_c=20.0),
            _cloud_job("B", 0.0, proc_c=20.0, trans_c=3.0)]
    seq = simulate_metro([jobs], GreedyPolicy(),
                         machines_per_tier={CC: 1, ES: 1})
    a0, b0 = seq.wards[0].entries
    assert b0.start >= a0.end                       # one machine: serial
    up = simulate_metro([jobs], GreedyPolicy(),
                        machines_per_tier={CC: 1, ES: 1},
                        scale_events=[ScaleEvent(time=1.0, tier=CC,
                                                 delta=1)])
    a1, b1 = up.wards[0].entries
    assert b1.start < a1.end                        # overlapping now
    with pytest.raises(ValueError):
        simulate_metro([jobs], GreedyPolicy(),
                       machines_per_tier={CC: 1, ES: 1},
                       scale_events=[ScaleEvent(time=1.0, tier=CC,
                                                delta=-1)])


# --------------------------------------------------------------- metrics
def test_streaming_quantiles_accuracy_and_merge():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(2.0, 1.0, size=5000)
    sq = StreamingQuantiles()
    half = StreamingQuantiles()
    for i, x in enumerate(xs):
        (sq if i % 2 == 0 else half).add(float(x))
    sq.merge(half)
    assert sq.n == len(xs)
    for q in (0.5, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        assert sq.quantile(q) == pytest.approx(exact, rel=0.06)
    assert sq.max == pytest.approx(float(xs.max()))
    assert sq.mean == pytest.approx(float(xs.mean()))


def test_metrics_windowing_bounded_and_miss_accounting():
    m = MetroMetrics(window=10.0, keep_windows=3)
    for k in range(400):
        t = float(k)
        m.record(t, "threat" if k % 2 else "alert",
                 response=5.0 + (k % 7), deadline=8.0, tier=CC, proc=1.0)
    assert len(m.recent) == 3                       # ring stays bounded
    assert m.completions == 400
    by = m.miss_rate_by_class()
    assert set(by) == {"threat", "alert"}
    # responses cycle 5..11 against deadline 8 -> misses are exact
    expect = sum(1 for k in range(400) if 5.0 + (k % 7) > 8.0) / 400
    assert m.miss_rate == pytest.approx(expect)
    assert m.recent_quantile(0.5) > 0
    assert m.busy_time[CC] == pytest.approx(400.0)


def test_metrics_in_engine_summary():
    tr, fails, scales = _scenario(seed=5, wards=2, horizon=40.0)
    res = simulate_metro(tr, GreedyPolicy(), machines_per_tier=MPT,
                         failures=fails, scale_events=scales)
    s = res.summary()
    for key in ("p50", "p95", "p99", "miss_rate", "utilization",
                "events_per_s", "completions"):
        assert key in s
    assert s["completions"] == sum(len(t) for t in tr)
    assert 0.0 < s["utilization"]["cloud"] <= 1.0
    assert 0.0 < s["utilization"]["edge"] <= 1.0


def test_engine_rejects_reuse_and_bad_events():
    tr, _, _ = _scenario(seed=1, wards=1, horizon=20.0)
    eng = MetroEngine(tr, GreedyPolicy(), machines_per_tier=MPT)
    eng.run()
    with pytest.raises(ValueError):
        eng.run()
    with pytest.raises(ValueError):
        MetroEngine(tr, GreedyPolicy(), machines_per_tier=MPT,
                    failures=[FailureEvent(time=1.0, tier=CC, ward=0)])
    with pytest.raises(ValueError):
        MetroEngine(tr, GreedyPolicy(), machines_per_tier=MPT,
                    failures=[FailureEvent(time=1.0, tier=ED)])
    with pytest.raises(ValueError):
        MetroEngine([], GreedyPolicy())


# ------------------------------------------------------ policy comparison
def test_policy_comparison_smoke():
    tr, fails, scales = _scenario(seed=9, wards=2, horizon=50.0)
    out = {}
    for name in ("greedy", "tabu", "fleet"):
        kw = dict(max_count=2, max_sweeps=1) if name == "fleet" else {}
        out[name] = simulate_metro(
            tr, make_policy(name, **kw), machines_per_tier=MPT,
            failures=fails, scale_events=scales)
    # replanners should not lose to commit-and-hold on mean response
    assert out["tabu"].metrics.total.mean <= \
        out["greedy"].metrics.total.mean * 1.05
    for res in out.values():
        assert res.metrics.completions == sum(len(t) for t in tr)


def test_make_policy_rejects_unknown():
    with pytest.raises(ValueError):
        make_policy("nope")
