"""Batched fleet-scale scheduling (DESIGN.md §8): padding/masking
semantics, batched-vs-per-instance parity, the search_batched dispatch,
the fleet-aware stochastic search, and the ValueError busy guards.

Instances are integer-valued (float32-exact), so "identical trajectories"
is testable as bit-identical objectives after exact re-simulation."""
import os
import sys

import numpy as np
import pytest

from prop import sweep
from repro.core import online, scheduler, scheduler_jax
from repro.core.problems import ward_batch
from repro.core.simulator import MACHINES, JobSpec, simulate
from repro.core.tiers import CC, ED, ES


def _random_jobs(rng, n):
    return [JobSpec(name=f"J{i}", release=float(rng.integers(0, 30)),
                    weight=float(rng.integers(1, 4)),
                    proc={t: float(rng.integers(1, 30)) for t in MACHINES},
                    trans={CC: float(rng.integers(0, 60)),
                           ES: float(rng.integers(0, 15)), ED: 0.0})
            for i in range(n)]


def _random_fleet(rng):
    """(machines_per_tier pair, busy_until pair) with some machines deep
    busy and some idle."""
    mpt = (int(rng.integers(1, 4)), int(rng.integers(1, 4)))
    busy = tuple(
        [float(rng.choice([0.0, float(rng.integers(1, 40))]))
         for _ in range(int(rng.integers(0, m + 1)))]
        for m in mpt)
    return mpt, busy


def _exact(jobs, assign, mpt=(1, 1), busy=None, objective="weighted"):
    s = simulate(jobs, [MACHINES[int(i)] for i in assign],
                 machines_per_tier={CC: mpt[0], ES: mpt[1]},
                 busy_until=None if busy is None
                 else {CC: busy[0], ES: busy[1]})
    return {"weighted": s.weighted_sum, "unweighted": s.unweighted_sum,
            "last": s.last_end}[objective]


def _assert_batch_parity(batch, mpts, busys, objective="weighted"):
    """Batched search == per-instance tabu_search_jax, bit-identical after
    exact re-simulation, and reported values match the simulator."""
    vals, assigns = scheduler_jax.tabu_search_batched(
        batch, objective=objective, machines_per_tier=mpts,
        busy_until=busys)
    for jobs, mpt, busy, vb, ab in zip(batch, mpts, busys, vals, assigns):
        assert len(ab) == len(jobs)
        v1, a1 = scheduler_jax.tabu_search_jax(
            jobs, objective=objective, machines_per_tier=mpt,
            busy_until=busy)
        got = _exact(jobs, ab, mpt, busy, objective)
        solo = _exact(jobs, a1, mpt, busy, objective)
        assert got == solo, (got, solo)
        assert abs(vb - got) < 1e-3, (vb, got)


class TestBatchedParity:
    def test_mixed_sizes_fast(self):
        """Small fast-tier case: mixed ward sizes force phantom padding."""
        batch = [_random_jobs(np.random.default_rng(50 + i), n)
                 for i, n in enumerate((4, 11, 7))]
        B = len(batch)
        _assert_batch_parity(batch, [(1, 1)] * B, [None] * B)

    def test_fleet_and_busy_fast(self):
        """(2,3) fleet with occupied machines, single fast case."""
        batch = [_random_jobs(np.random.default_rng(60 + i), n)
                 for i, n in enumerate((6, 9))]
        mpts = [(2, 3), (1, 2)]
        busys = [([5.0, 17.0], [0.0, 3.0, 21.0]), (None)]
        _assert_batch_parity(batch, mpts, busys)

    # job counts drawn from a fixed grid so jit caches stay warm across
    # sweep cases (DESIGN.md §6)
    N_GRID = (4, 9, 14)

    @pytest.mark.slow
    @pytest.mark.parametrize("objective", ["weighted", "unweighted",
                                           "last"])
    def test_parity_sweep(self, objective):
        """Mixed-size batches, mixed fleets incl (2,3), nonzero
        busy_until — batched trajectories identical to solo runs."""
        def check(rng):
            B = int(rng.integers(2, 5))
            batch = [_random_jobs(rng, int(rng.choice(self.N_GRID)))
                     for _ in range(B)]
            fleets = [_random_fleet(rng) for _ in range(B)]
            if rng.integers(2):          # half the cases: uniform fleet
                fleets = [fleets[0]] * B
            _assert_batch_parity(batch, [f[0] for f in fleets],
                                 [f[1] for f in fleets], objective)
        sweep(check, n_cases=6, seed={"weighted": 0, "unweighted": 100,
                                      "last": 200}[objective])

    @pytest.mark.slow
    def test_parity_explicit_23_fleet_sweep(self):
        """The acceptance fleet: every ward on (2, 3) with busy machines."""
        def check(rng):
            B = int(rng.integers(2, 5))
            batch = [_random_jobs(rng, int(rng.choice(self.N_GRID)))
                     for _ in range(B)]
            busys = [([float(rng.integers(0, 25))],
                      [float(rng.integers(0, 25)),
                       float(rng.integers(0, 25))]) for _ in range(B)]
            _assert_batch_parity(batch, [(2, 3)] * B, busys)
        sweep(check, n_cases=5, seed=300)

    @pytest.mark.slow
    def test_ward_batch_generator_plans(self):
        """problems.ward_batch feeds search_batched end-to-end: every
        scenario yields valid exact schedules for mixed-size wards."""
        rng = np.random.default_rng(7)
        for scenario in ("poisson", "surge", "quiet"):
            batch = ward_batch(rng, 4, n_lo=4, n_hi=10, scenario=scenario)
            scheds = scheduler.search_batched(batch, max_count=5,
                                              min_batch=1)
            for jobs, s in zip(batch, scheds):
                assert len(s.entries) == len(jobs)
                ref = simulate(jobs, s.assignment())
                assert s.weighted_sum == ref.weighted_sum


class TestPhantomPadding:
    def test_phantoms_contribute_zero(self):
        """A ward padded next to a larger one returns exactly its solo
        objective — phantom jobs add 0 to every objective."""
        small = _random_jobs(np.random.default_rng(1), 4)
        big = _random_jobs(np.random.default_rng(2), 15)
        for objective in ("weighted", "unweighted", "last"):
            vals, assigns = scheduler_jax.tabu_search_batched(
                [small, big], objective=objective)
            v_solo, _ = scheduler_jax.tabu_search_jax(
                small, objective=objective)
            assert vals[0] == v_solo
            assert len(assigns[0]) == 4

    def test_greedy_probe_matches_python_greedy(self):
        """max_rounds=0 returns the greedy initial — and the in-graph
        batched greedy is the same schedule as greedy_schedule."""
        def check(rng):
            jobs = _random_jobs(rng, int(rng.integers(2, 15)))
            mpt, busy = _random_fleet(rng)
            py = scheduler.greedy_schedule(
                jobs, machines_per_tier={CC: mpt[0], ES: mpt[1]},
                busy_until={CC: busy[0], ES: busy[1]})
            _, assigns = scheduler_jax.tabu_search_batched(
                [jobs], max_rounds=0, machines_per_tier=[mpt],
                busy_until=[busy])
            assert [MACHINES[int(i)] for i in assigns[0]] == py
        sweep(check, n_cases=10, seed=400)

    def test_empty_batch_and_empty_ward(self):
        vals, assigns = scheduler_jax.tabu_search_batched([])
        assert len(vals) == 0 and assigns == []
        vals, assigns = scheduler_jax.tabu_search_batched(
            [[], _random_jobs(np.random.default_rng(0), 5)])
        assert vals[0] == 0.0 and len(assigns[0]) == 0
        assert len(assigns[1]) == 5


class TestSearchBatchedDispatch:
    def test_batched_path_returns_exact_schedules(self):
        problems = [_random_jobs(np.random.default_rng(10 + i), n)
                    for i, n in enumerate((8, 13, 5, 10))]
        mpt = {CC: 2, ES: 1}
        scheds = scheduler.search_batched(problems, max_count=5,
                                          machines_per_tier=mpt,
                                          min_batch=1)
        for jobs, s in zip(problems, scheds):
            ref = simulate(jobs, s.assignment(), machines_per_tier=mpt)
            assert s.weighted_sum == ref.weighted_sum
            for t in MACHINES:
                assert s.weighted_sum <= scheduler.all_on_tier(
                    jobs, t, machines_per_tier=mpt).weighted_sum + 1e-6

    def test_sequential_fallback_below_min_batch(self):
        problems = [_random_jobs(np.random.default_rng(20 + i), 7)
                    for i in range(2)]
        a = scheduler.search_batched(problems, min_batch=10)
        b = [scheduler.search(p) for p in problems]
        for s1, s2 in zip(a, b):
            assert s1.weighted_sum == s2.weighted_sum

    def test_per_ward_fleets_and_busy(self):
        problems = [_random_jobs(np.random.default_rng(30 + i), 9)
                    for i in range(4)]
        mpts = [{CC: 1, ES: 1}, {CC: 2, ES: 3}, {CC: 1, ES: 2},
                {CC: 3, ES: 1}]
        busys = [None, {CC: [4.0], ES: [2.0, 9.0]}, None, {CC: [7.0]}]
        scheds = scheduler.search_batched(problems, max_count=5,
                                          machines_per_tier=mpts,
                                          busy_until=busys, min_batch=1)
        for jobs, m, b, s in zip(problems, mpts, busys, scheds):
            ref = simulate(jobs, s.assignment(), machines_per_tier=m,
                           busy_until=b)
            assert s.weighted_sum == ref.weighted_sum

    def test_competitive_ratio_batch_matches_solo(self):
        instances = [_random_jobs(np.random.default_rng(40 + i), 8)
                     for i in range(3)]
        ratios = online.competitive_ratio_batch(
            instances, replans=("greedy", "tabu"), min_batch=99)
        for replan in ("greedy", "tabu"):
            solo = [online.competitive_ratio(jobs, replan=replan)
                    for jobs in instances]
            assert np.allclose(ratios[replan], solo)


class TestStochasticFleet:
    def test_stochastic_search_scores_the_real_fleet(self):
        """The seed bug: candidates were scored on an idle (1,1) fleet.
        The claimed objective must now match the exact simulator under
        the deployed fleet and occupancy."""
        jobs = _random_jobs(np.random.default_rng(5), 12)
        mpt = (2, 3)
        busy = ([6.0, 14.0], [3.0])
        import jax
        initial = np.asarray(
            [MACHINES.index(t) for t in scheduler.greedy_schedule(
                jobs, machines_per_tier={CC: mpt[0], ES: mpt[1]},
                busy_until={CC: busy[0], ES: busy[1]})], np.int32)
        v, a = scheduler_jax.stochastic_search(
            jobs, jax.random.PRNGKey(0), initial, iters=30,
            machines_per_tier=mpt, busy_until=busy)
        exact = simulate(jobs, [MACHINES[int(i)] for i in a],
                         machines_per_tier={CC: mpt[0], ES: mpt[1]},
                         busy_until={CC: busy[0], ES: busy[1]})
        assert abs(v - exact.weighted_sum) < 1e-2


class TestBusyGuardsRaise:
    """The overfull-busy guards are ValueError, not assert — they must
    survive ``python -O`` (DESIGN.md §7)."""

    def test_normalize_busy_overfull(self):
        with pytest.raises(ValueError):
            scheduler_jax._normalize_busy(([1.0, 2.0], ()), (1, 1))

    def test_busy_vectors_overfull(self):
        jobs = _random_jobs(np.random.default_rng(0), 2)
        commits = [online._Commit(jobs[0], CC, 0.0, 0.0, 50.0),
                   online._Commit(jobs[1], CC, 0.0, 0.0, 60.0)]
        with pytest.raises(ValueError):
            online._busy_vectors(commits, [], now=10.0,
                                 machines_per_tier={CC: 1, ES: 1})

    def test_mpt_length_mismatch(self):
        batch = [_random_jobs(np.random.default_rng(0), 3)] * 3
        with pytest.raises(ValueError):
            scheduler_jax.tabu_search_batched(
                batch, machines_per_tier=[(1, 1), (2, 2)])


class TestRegressionGate:
    """benchmarks/check_regression.py compare() logic (no bench run)."""

    def _reports(self):
        base = {
            "head_to_head": [
                {"n": 100, "methods": {
                    "incremental": {"seconds": 0.01,
                                    "speedup_vs_reference": 30.0},
                    "jax": {"seconds": 0.005,
                            "speedup_vs_reference": 60.0}}},
            ],
            "batched": {"speedup_batched_vs_sequential": 5.0,
                        "wards_per_s_batched": 600.0,
                        "parity_mismatches": 0},
        }
        import copy
        return base, copy.deepcopy(base)

    def _compare(self):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        os.pardir, "benchmarks"))
        try:
            from check_regression import compare
        finally:
            sys.path.pop(0)
        return compare

    def test_identical_reports_pass(self):
        compare = self._compare()
        committed, fresh = self._reports()
        assert compare(committed, fresh) == []

    def test_within_tolerance_passes(self):
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["batched"]["speedup_batched_vs_sequential"] = 4.0  # -20%
        assert compare(committed, fresh, tolerance=0.30) == []

    def test_regression_fails(self):
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["batched"]["wards_per_s_batched"] = 300.0          # -50%
        fresh["head_to_head"][0]["methods"]["jax"]["seconds"] = 0.02
        problems = compare(committed, fresh, tolerance=0.30)
        assert any("wards_per_s" in p for p in problems)
        assert any("jax_vs_incremental" in p for p in problems)

    def test_parity_mismatch_fails(self):
        compare = self._compare()
        committed, fresh = self._reports()
        fresh["batched"]["parity_mismatches"] = 2
        assert any("parity" in p for p in compare(committed, fresh))
