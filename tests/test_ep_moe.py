"""Expert-parallel MoE (shard_map all-to-all) vs the TP reference path.

Needs >1 device, so it runs in a subprocess with forced host devices."""
import pytest

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

pytestmark = pytest.mark.slow

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MOE
from repro.models import blocks
from repro.sharding import policy

cfg_tp = ModelConfig(name="t", family="moe", num_layers=1, d_model=64,
                     num_heads=2, num_kv_heads=2, head_dim=32, d_ff=64,
                     vocab_size=64, group_pattern=(MOE,), num_experts=4,
                     num_experts_per_tok=2, moe_capacity_factor=4.0,
                     dtype="float32")
cfg_ep = dataclasses.replace(cfg_tp, moe_ep_shards=2)

key = jax.random.PRNGKey(0)
p_tp = blocks._init_moe(key, cfg_tp)
p_ep = blocks._init_moe(key, cfg_ep)
# same logical weights: convert TP -> EP layout explicitly
e, d, f, r = 4, 64, 64, 2
fr = f // r
we = p_tp["experts"]
p_ep["experts"] = {
    "ep_gate": we["w_gate"].reshape(e, d, r, fr).transpose(0, 2, 1, 3)
    .reshape(e * r, d, fr),
    "ep_up": we["w_up"].reshape(e, d, r, fr).transpose(0, 2, 1, 3)
    .reshape(e * r, d, fr),
    "ep_down": we["w_down"].reshape(e, r, fr, d).reshape(e * r, fr, d),
}
p_ep["router"] = p_tp["router"]
p_ep["moe_norm"] = p_tp["moe_norm"]

x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))

# reference: TP path on one device
y_tp, aux_tp = blocks._moe_ffn(p_tp, x, cfg_tp)

# EP path under a (1, 8) mesh
mesh = jax.make_mesh((1, 8), ("data", "model"))
with mesh, policy.activation_policy(mesh):
    y_ep, aux_ep = jax.jit(lambda p, x: blocks._moe_ffn(p, x, cfg_ep))(p_ep, x)

err = float(jnp.max(jnp.abs(y_tp - y_ep)))
print("max_err", err, "aux", float(aux_tp), float(aux_ep))
assert err < 2e-4, err
# aux load-balance metric: same order (EP is an inference layout; aux only
# regularises training, where the TP path is used)
import math as _math
assert _math.isfinite(float(aux_ep)) and float(aux_ep) > 0.5

# EP fallback path (no mesh) must also match
y_fb, _ = blocks._moe_ffn(p_ep, x, cfg_ep)
err2 = float(jnp.max(jnp.abs(y_tp - y_fb)))
print("fallback_err", err2)
assert err2 < 2e-4, err2
print("EP_OK")
"""


def test_ep_moe_matches_tp_reference():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "EP_OK" in out.stdout, out.stdout
