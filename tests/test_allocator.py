"""Algorithm 1 (single-job allocation) + cost model tests."""
import numpy as np
import pytest

from prop import sweep
from repro.core import allocator
from repro.core.cost_model import (AnalyticCostModel, CalibratedCostModel,
                                   Job, RooflineCostModel, Workload)
from repro.core.tiers import CC, ED, ES, TierSpec, paper_tiers, tpu_tiers


def test_allocation_is_argmin():
    def check(rng):
        cm = AnalyticCostModel(paper_tiers(), lam1=1.0,
                               lam2=float(rng.uniform(1e5, 1e8)))
        wl = Workload("w", comp=float(rng.uniform(1e3, 1e6)),
                      unit_bytes=float(rng.uniform(1e3, 1e5)))
        job = Job(wl, size=float(rng.integers(1, 2048)))
        a = allocator.allocate_single(cm, job)
        per = a.per_tier_response
        assert abs(a.response - min(per.values())) < 1e-12
        assert per[a.tier] == min(per.values())
    sweep(check, n_cases=25)


def test_small_models_prefer_device_large_prefer_upper_tiers():
    """The paper's Section VIII observation: light models + slow network ->
    compute near the user; heavy compute -> offload up."""
    cm = AnalyticCostModel(paper_tiers(), lam2=1.0)
    light = Job(Workload("light", comp=1e4, unit_bytes=1e4), size=100)
    assert allocator.allocate_single(cm, light).tier == ED
    # heavy compute, tiny payload: cloud's 4.4x FLOPS advantage wins
    heavy = Job(Workload("heavy", comp=1e10, unit_bytes=10.0), size=100)
    assert allocator.allocate_single(cm, heavy).tier == CC


def test_response_monotone_in_size():
    cm = AnalyticCostModel(paper_tiers())
    wl = Workload("w", comp=1e5, unit_bytes=1e4)
    prev = -1.0
    for size in (1, 4, 16, 64, 256):
        t = allocator.allocate_single(cm, Job(wl, size=size)).response
        assert t >= prev
        prev = t


def test_calibrated_model_reproduces_measurements():
    tiers = paper_tiers()
    meas = {("w", CC): (10.0, 20.0, 2.0), ("w", ES): (12.0, 4.0, 2.0),
            ("w", ED): (30.0, 0.0, 2.0)}
    cm = CalibratedCostModel.from_measurements(tiers, meas)
    job = Job(Workload("w", comp=1, unit_bytes=1), size=4.0)
    assert cm.processing_time(CC, job) == pytest.approx(20.0)
    assert cm.transmission_time(ES, job) == pytest.approx(8.0)
    assert cm.transmission_time(ED, job) == 0.0


def test_roofline_cost_model_memory_bound_decode():
    """A memory-bound decode job must cost max(compute, memory), and the
    FLOPS-only model must under-estimate it — the beyond-paper fix."""
    tiers = tpu_tiers()
    wl = Workload("decode", comp=2e9, unit_bytes=10.0, hbm_bytes=3e9)
    job = Job(wl, size=1.0)
    roof = RooflineCostModel(tiers)
    paper = AnalyticCostModel(tiers)
    t = roof.processing_time(ED, job)
    assert t == pytest.approx(3e9 / tiers[ED].hbm_bw)
    assert paper.processing_time(ED, job) < t


def test_tier_efficiency_derate():
    t = TierSpec("x", flops=100.0, efficiency=0.5)
    assert t.effective_flops == 50.0
