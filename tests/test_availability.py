"""Machine-availability (busy_until) semantics across all three evaluator
layers (DESIGN.md §7): the reference simulator, the incremental
ScheduleState, and the JAX batched evaluator must agree when shared
machines start occupied, on single- and multi-server fleets."""
import numpy as np
import pytest

from prop import sweep
from repro.core import scheduler, scheduler_jax
from repro.core.lower_bound import (jobwise_last_bound, load_lower_bound,
                                    paper_lower_bound)
from repro.core.problems import table6_jobs
from repro.core.simulator import (MACHINES, JobSpec, ScheduleState,
                                  machine_free_times, simulate)
from repro.core.tiers import CC, ED, ES

MPT_GRID = ((1, 1), (2, 3))


def _random_jobs(rng, n):
    return [JobSpec(name=f"J{i}", release=float(rng.integers(0, 30)),
                    weight=float(rng.integers(1, 4)),
                    proc={t: float(rng.integers(1, 30)) for t in MACHINES},
                    trans={CC: float(rng.integers(0, 60)),
                           ES: float(rng.integers(0, 15)), ED: 0.0})
            for i in range(n)]


def _random_busy(rng, mpt):
    """Random machine free times; some machines idle (0), some deep busy."""
    return {t: sorted(float(rng.choice([0.0, rng.integers(1, 40)]))
                      for _ in range(m))
            for t, m in ((CC, mpt[0]), (ES, mpt[1]))}


def _assert_triple_parity(jobs, assigns, mpt, busy):
    mptd = {CC: mpt[0], ES: mpt[1]}
    busy_jax = (busy[CC], busy[ES])
    rel, w, proc, trans = scheduler_jax.specs_to_arrays(jobs)
    m = scheduler_jax.evaluate_assignments(
        assigns, rel, w, proc, trans, machines_per_tier=mpt,
        busy_until=busy_jax)
    for ai in range(assigns.shape[0]):
        a = [MACHINES[j] for j in assigns[ai]]
        s = simulate(jobs, a, machines_per_tier=mptd, busy_until=busy)
        st = ScheduleState(jobs, a, machines_per_tier=mptd, busy_until=busy)
        # reference == incremental, exactly
        assert abs(st.score("weighted") - s.weighted_sum) < 1e-9
        assert abs(st.score("unweighted") - s.unweighted_sum) < 1e-9
        assert abs(st.score("last") - s.last_end) < 1e-9
        for e in s.entries:
            assert abs(st.end[jobs.index(e.job)] - e.end) < 1e-9
        # reference == JAX (float32) within tolerance
        assert abs(float(m["weighted"][ai]) - s.weighted_sum) < 1e-2
        assert abs(float(m["unweighted"][ai]) - s.unweighted_sum) < 1e-2
        assert abs(float(m["last"][ai]) - s.last_end) < 1e-2


class TestBusyUntilParity:
    """simulate(busy_until=...) == ScheduleState(busy_until=...) == JAX."""

    def test_parity_small(self):
        def check(rng):
            jobs = _random_jobs(rng, int(rng.integers(3, 8)))
            for mpt in MPT_GRID:
                busy = _random_busy(rng, mpt)
                assigns = rng.integers(0, 3, size=(4, len(jobs))).astype(
                    np.int32)
                _assert_triple_parity(jobs, assigns, mpt, busy)
        sweep(check, n_cases=6)

    @pytest.mark.slow
    @pytest.mark.parametrize("n,mpt,cases", [
        (6, (1, 1), 20), (6, (2, 3), 20),
        (10, (1, 1), 15), (10, (2, 3), 15),
    ])
    def test_parity_sweep(self, n, mpt, cases):
        for case in range(cases):
            rng = np.random.default_rng(hash((n, mpt)) % (2 ** 31) + case)
            jobs = _random_jobs(rng, n)
            busy = _random_busy(rng, mpt)
            assigns = rng.integers(0, 3, size=(8, n)).astype(np.int32)
            _assert_triple_parity(jobs, assigns, mpt, busy)

    def test_incremental_moves_with_busy(self):
        """try_move/apply_move stay exact against re-simulation when the
        fleet starts occupied."""
        for seed in range(10):
            rng = np.random.default_rng(seed)
            jobs = _random_jobs(rng, 8)
            mptd = {CC: 2, ES: 3}
            busy = {CC: [5.0, 17.0], ES: [0.0, 3.0, 21.0]}
            st = ScheduleState(jobs, [MACHINES[j]
                                      for j in rng.integers(0, 3, 8)],
                               machines_per_tier=mptd, busy_until=busy)
            for _ in range(12):
                k = int(rng.integers(0, 8))
                dst = MACHINES[int(rng.integers(0, 3))]
                pred = st.try_move(k, dst, "weighted")
                st.apply_move(k, dst)
                ref = simulate(jobs, st.assign, machines_per_tier=mptd,
                               busy_until=busy)
                assert abs(pred - ref.weighted_sum) < 1e-6
                assert abs(st.score("weighted") - ref.weighted_sum) < 1e-9


class TestBusyUntilSemantics:
    def test_no_start_before_machine_free(self):
        """With every machine on a tier busy until B, nothing starts
        before B there."""
        jobs = _random_jobs(np.random.default_rng(0), 6)
        B = 100.0
        busy = {CC: [B, B], ES: [B]}
        s = simulate(jobs, [CC, CC, CC, ES, ES, ES],
                     machines_per_tier={CC: 2, ES: 1}, busy_until=busy)
        for e in s.entries:
            assert e.start >= B

    def test_partial_fleet_busy(self):
        """One idle machine out of two: the first job runs immediately,
        queueing resumes only when the busy machine matters."""
        jobs = [JobSpec(name=f"J{i}", release=0.0, weight=1.0,
                        proc={CC: 10.0, ES: 10.0, ED: 99.0},
                        trans={CC: 0.0, ES: 0.0, ED: 0.0})
                for i in range(2)]
        s = simulate(jobs, [CC, CC], machines_per_tier={CC: 2, ES: 1},
                     busy_until={CC: [0.0, 50.0]})
        starts = sorted(e.start for e in s.entries)
        assert starts == [0.0, 10.0]    # both fit on the idle machine

    def test_machine_free_times_validates(self):
        assert machine_free_times(None, CC, 2) == [0.0, 0.0]
        assert machine_free_times({CC: [7.0]}, CC, 2) == [0.0, 7.0]
        # ValueError (not assert) so the guard survives python -O
        with pytest.raises(ValueError):
            machine_free_times({CC: [1.0, 2.0, 3.0]}, CC, 2)

    def test_greedy_respects_busy_and_fleet(self):
        """greedy_schedule's claimed completion matches the simulator's
        on the schedule it builds, busy fleet included."""
        def check(rng):
            jobs = _random_jobs(rng, 8)
            mpt = {CC: 2, ES: 2}
            busy = {CC: [9.0, 0.0], ES: [4.0]}
            assign = scheduler.greedy_schedule(jobs, machines_per_tier=mpt,
                                               busy_until=busy)
            s = simulate(jobs, assign, machines_per_tier=mpt,
                         busy_until=busy)
            for e in s.entries:
                if e.machine == CC:
                    assert e.start >= 0.0    # idle machine may run at once
        sweep(check, n_cases=6)

    def test_search_paths_agree_with_busy(self):
        """Python and JAX search both optimise the constrained problem and
        return exact schedules scored against it."""
        jobs = _random_jobs(np.random.default_rng(7), 9)
        mpt = {CC: 2, ES: 1}
        busy = {CC: [4.0, 9.0], ES: [2.0]}
        s_py = scheduler.search(jobs, machines_per_tier=mpt,
                                busy_until=busy, jax_threshold=100)
        s_jax = scheduler.search(jobs, machines_per_tier=mpt,
                                 busy_until=busy, jax_threshold=2)
        for s in (s_py, s_jax):
            ref = simulate(jobs, s.assignment(), machines_per_tier=mpt,
                           busy_until=busy)
            assert s.weighted_sum == ref.weighted_sum
        # the search had the busy machines in its objective: with a huge
        # busy horizon everything shifts off the blocked tier
        blocked = scheduler.search(
            jobs, machines_per_tier=mpt,
            busy_until={CC: [1e6, 1e6], ES: [1e6]}, jax_threshold=100)
        assert all(t == ED for t in blocked.assignment())


# ------------------------------------------------------- load lower bound
class TestLoadLowerBound:
    def test_sandwich_on_paper_instance(self):
        jobs = table6_jobs()
        opt = scheduler.exact_optimum(jobs, objective="weighted")
        lb_job = jobwise_last_bound(jobs)
        lb = load_lower_bound(jobs)
        assert lb_job <= lb <= opt.last_end + 1e-6
        # on Table VI the forcing argument is strictly tighter (41 -> 43)
        assert lb > lb_job

    def test_sandwich_property(self):
        """jobwise <= load bound <= best last completion over ALL
        assignments (not just the weighted optimum's)."""
        import itertools

        def check(rng):
            jobs = _random_jobs(rng, 5)
            lb_job = jobwise_last_bound(jobs)
            lb = load_lower_bound(jobs)
            best_last = min(
                simulate(jobs, c).last_end
                for c in itertools.product(MACHINES, repeat=5))
            assert lb_job - 1e-9 <= lb <= best_last + 1e-6
            assert paper_lower_bound(jobs) <= \
                scheduler.exact_optimum(jobs).weighted_sum + 1e-9
        sweep(check, n_cases=8)

    def test_multi_machine_fleet_weakens_forcing(self):
        """More machines can only lower (or keep) the load bound."""
        def check(rng):
            jobs = _random_jobs(rng, 6)
            one = load_lower_bound(jobs, machines_per_tier={CC: 1, ES: 1})
            many = load_lower_bound(jobs, machines_per_tier={CC: 3, ES: 3})
            assert many <= one + 1e-9
        sweep(check, n_cases=8)
