"""Distribution correctness: sharded training/serving == single-device.

The strongest evidence the FSDP x TP policy + activation constraints are
semantics-preserving: the same reduced model, same data, trained 5 steps on
a (2 data x 4 model) mesh with the full sharding policy vs unsharded — the
loss trajectories must match to float tolerance. Runs in a subprocess with
8 forced host devices."""
import pytest

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

pytestmark = pytest.mark.slow

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.data.pipeline import MarkovTokenDataset
from repro.models import build_model
from repro.sharding import policy
from repro.training import optimizer, train_loop

cfg = get_config("qwen2-1.5b").reduced(layers=2, d_model=128, vocab=512)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
ds = MarkovTokenDataset(vocab_size=512, seq_len=32, batch_size=8)
batches = [b for b, _ in zip(ds.batches(), range(5))]
opt_cfg = optimizer.AdamWConfig(total_steps=5, warmup_steps=1)

def run(sharded):
    p = jax.tree.map(jnp.copy, params)   # train_step donates its args
    o = optimizer.init(p)
    losses = []
    if sharded:
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p_sh = policy.to_shardings(policy.param_specs(p, mesh), mesh)
        o_sh = policy.to_shardings(policy.param_specs(o, mesh), mesh)
        p = jax.device_put(p, p_sh)
        o = jax.device_put(o, o_sh)
        step = train_loop.make_train_step(model, opt_cfg, jit=True)
        with mesh, policy.activation_policy(mesh):
            for b in batches:
                b_sh = policy.to_shardings(policy.batch_specs(b, mesh), mesh)
                b = jax.device_put(b, b_sh)
                p, o, m = step(p, o, b)
                losses.append(float(m["loss"]))
    else:
        step = train_loop.make_train_step(model, opt_cfg, jit=True)
        for b in batches:
            p, o, m = step(p, o, b)
            losses.append(float(m["loss"]))
    return losses, p

l1, p1 = run(False)
l2, p2 = run(True)
print("single:", [f"{x:.6f}" for x in l1])
print("sharded:", [f"{x:.6f}" for x in l2])
np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
print("max param diff:", d)
assert d < 5e-3, d
print("PARITY_OK")
"""


def test_sharded_training_matches_single_device():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-2500:])
    assert "PARITY_OK" in out.stdout, out.stdout
