"""Sharding policy unit tests (no devices needed — specs only)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model
from repro.sharding import policy


class FakeMesh:
    """Just enough of a Mesh for the spec rules (no devices)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_divisible(specs, tree):
    sizes = {"data": 16, "model": 16, "pod": 2}
    leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    leaves_t = jax.tree.leaves(tree)
    assert len(leaves_s) == len(leaves_t)
    for spec, leaf in zip(leaves_s, leaves_t):
        for dim, part in zip(leaf.shape, tuple(spec)):
            if part is None:
                continue
            parts = (part,) if isinstance(part, str) else part
            k = int(np.prod([sizes[p] for p in parts]))
            assert dim % k == 0, (spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["16x16", "2x16x16"])
def test_param_specs_divisible_for_full_configs(arch, mesh):
    """Every full-size param leaf gets a spec whose sharded dims divide
    exactly — no reliance on GSPMD padding."""
    model = build_model(get_config(arch))
    specs = policy.param_specs(model.param_specs(), mesh)
    _check_divisible(specs, model.param_specs())


def test_qkv_rules():
    mesh = MESH1
    specs = policy.param_specs(
        {"wq": jax.ShapeDtypeStruct((4096, 32, 128), jax.numpy.bfloat16),
         "wk": jax.ShapeDtypeStruct((4096, 12, 128), jax.numpy.bfloat16)},
        mesh)
    assert tuple(specs["wq"]) == ("data", "model", None)
    # 12 heads don't divide 16 -> fall back to head_dim
    assert tuple(specs["wk"]) == ("data", None, "model")


def test_constrain_noop_without_policy():
    x = jax.numpy.ones((4, 4))
    assert policy.constrain(x, (policy.DP, None)) is x


def test_cache_specs_long_context_batch1():
    """Batch-1 long decode: KV slots go context-parallel on data axis."""
    mesh = MESH1
    cache = {"groups": {"b0": {"attn": {
        "k": jax.ShapeDtypeStruct((46, 1, 16, 524288, 128),
                                  jax.numpy.bfloat16)}}}}
    spec = policy.cache_specs(cache, mesh)
    s = tuple(spec["groups"]["b0"]["attn"]["k"])
    assert s[0] is None                   # stacked groups axis
    assert s[2] == "model"                # kv heads
    assert s[3] == "data"                 # context-parallel slots
