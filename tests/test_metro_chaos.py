"""Crash-consistency tests for the metro engine (DESIGN.md §11):
job-killing failures with retry/failover, SHED accounting, degraded-
network pricing, seeded determinism of every chaos scenario pack, and
the per-scenario regression-gate logic."""
import os
import sys

import pytest

from repro.core.tiers import CC, ED, ES
from repro.core.simulator import JobSpec
from repro.metro import traces
from repro.metro.engine import (FailureEvent, MetroEngine, NetworkEvent,
                                ScaleEvent, _Pool, simulate_metro)
from repro.metro.policies import (SHED, GreedyPolicy, SheddingPolicy,
                                  TabuPolicy)

MPT = {CC: 2, ES: 2}


def _cloud_job(name, release, proc_c, trans_c=2.0, deadline=float("inf"),
               weight=1.0, workload=""):
    """A job only the cloud can run sensibly (edge/device prohibitive)."""
    return JobSpec(name=name, release=release, weight=weight,
                   proc={CC: proc_c, ES: 500.0, ED: 500.0},
                   trans={CC: trans_c, ES: 0.0, ED: 0.0},
                   deadline=deadline, workload=workload)


def _run_pack(name, policy, seed=0, wards=None, horizon=None):
    sc = traces.make_scenario(name, seed, wards=wards, horizon=horizon)
    res = simulate_metro(sc.traces, policy,
                         machines_per_tier=MPT, failures=sc.failures,
                         scale_events=sc.scales, network_events=sc.network,
                         slowdowns=sc.slowdowns)
    return sc, res


# ------------------------------------------------------------ crash kills
def test_crash_kills_in_flight_job_and_retries_it():
    # A starts at t=2 (trans 2), would end at 12; the crash at t=5 kills
    # it mid-run: 3 machine-seconds wasted, re-dispatched as a fresh
    # arrival, restarted once the machine repairs at 15
    jobs = [_cloud_job("A", 0.0, proc_c=10.0)]
    crash = FailureEvent(time=5.0, tier=CC, duration=10.0,
                         kill_running=True)
    res = simulate_metro([jobs], GreedyPolicy(),
                         machines_per_tier={CC: 1, ES: 1},
                         failures=[crash])
    (a,) = res.wards[0].entries
    assert (a.machine, a.start, a.end) == (CC, 15.0, 25.0)
    kill = next(ev for ev in res.event_log if ev[0] == "kill")
    assert kill == ("kill", 5.0, 0, 0, CC, 0, 3.0, 1)
    fail = next(ev for ev in res.event_log if ev[0] == "fail")
    assert fail == ("fail", 5.0, CC, -1, 0, 15.0, 1)
    comp = next(ev for ev in res.event_log if ev[0] == "complete")
    assert comp[-1] == 2                              # attempts
    m = res.metrics
    assert (m.retries, m.max_attempts) == (1, 2)
    assert m.wasted_seconds == pytest.approx(3.0)
    assert m.completions == 1 and m.shed == 0
    # the event log's kinds tell the whole story, in order
    assert [ev[0] for ev in res.event_log] == \
        ["arrive", "fail", "kill", "recover", "complete"]


def test_crash_retry_fails_over_to_another_tier():
    # the edge is a viable escape: when the crash takes the only cloud
    # machine down for 50, the tabu replanner re-dispatches the killed
    # job to the edge instead of waiting out the repair
    job = JobSpec(name="A", release=0.0, weight=1.0,
                  proc={CC: 10.0, ES: 12.0, ED: 100.0},
                  trans={CC: 2.0, ES: 1.0, ED: 0.0})
    crash = FailureEvent(time=5.0, tier=CC, duration=50.0,
                         kill_running=True)
    res = simulate_metro([[job]], TabuPolicy(jax_threshold=10 ** 9),
                         machines_per_tier={CC: 1, ES: 1},
                         failures=[crash])
    (a,) = res.wards[0].entries
    assert a.machine == ES                    # failover, not wait-for-repair
    assert a.end == 5.0 + 1.0 + 12.0          # re-shipped at the kill time
    assert res.metrics.retries == 1
    comp = next(ev for ev in res.event_log if ev[0] == "complete")
    assert comp[4] == ES and comp[-1] == 2


def test_crash_strikes_the_busiest_machine():
    # two cloud machines: A (long) on slot 0, B (short) on slot 1; by
    # t=10 B has drained, so the LATEST-free machine is A's — a crash
    # must kill A, not strike the idle slot
    jobs = [_cloud_job("A", 0.0, proc_c=20.0),
            _cloud_job("B", 0.0, proc_c=3.0, trans_c=1.0)]
    crash = FailureEvent(time=10.0, tier=CC, duration=5.0,
                         kill_running=True)
    res = simulate_metro([jobs], GreedyPolicy(),
                         machines_per_tier={CC: 2, ES: 1},
                         failures=[crash])
    kills = [ev for ev in res.event_log if ev[0] == "kill"]
    assert len(kills) == 1 and kills[0][2:4] == (0, 0)   # ward 0, job A
    assert res.metrics.completions == 2                  # B untouched + A retried


def test_drain_failure_still_never_kills():
    jobs = [_cloud_job("A", 0.0, proc_c=10.0)]
    drain = FailureEvent(time=5.0, tier=CC, duration=10.0)
    res = simulate_metro([jobs], GreedyPolicy(),
                         machines_per_tier={CC: 1, ES: 1},
                         failures=[drain])
    assert not any(ev[0] == "kill" for ev in res.event_log)
    (a,) = res.wards[0].entries
    assert (a.start, a.end) == (2.0, 12.0)               # run undisturbed
    assert res.metrics.retries == 0


def test_failure_on_fully_retired_pool_logs_and_skips():
    eng = MetroEngine([[_cloud_job("A", 0.0, proc_c=1.0)]],
                      GreedyPolicy(), machines_per_tier={CC: 1, ES: 1})
    for s in eng.cloud.slots:
        s.retired_at = 0.0
        s.down = float("inf")
    eng._on_fail(3.0, FailureEvent(time=3.0, tier=CC, duration=5.0,
                                   kill_running=True))
    assert ("fail", 3.0, CC, -1, -1, 3.0, 1) in eng.event_log
    # no machine was struck: no outage recorded, no recovery scheduled
    assert all(not s.outages for s in eng.cloud.slots)
    assert not any(p[0] == "recover" for _, _, _, p in eng._heap)


def test_same_timestamp_fail_scale_recover_ordering():
    # at t=10 three fleet events collide; the engine must apply the NEW
    # failure first, then the scale-up, then the recovery of the t=5
    # failure (_P_FAIL < _P_SCALE < _P_RECOVER)
    jobs = [_cloud_job("A", 0.0, proc_c=1.0)]
    res = simulate_metro([jobs], GreedyPolicy(),
                         machines_per_tier={CC: 2, ES: 1},
                         failures=[FailureEvent(time=5.0, duration=5.0),
                                   FailureEvent(time=10.0, duration=3.0)],
                         scale_events=[ScaleEvent(time=10.0, delta=1)])
    at_10 = [ev[0] for ev in res.event_log
             if ev[0] in ("fail", "scale", "recover") and ev[1] == 10.0]
    assert at_10 == ["fail", "scale", "recover"]


def test_capacity_integral_merges_overlaps_and_clips_retirement():
    pool = _Pool(CC, 1)
    slot = pool.slots[0]
    slot.outages = [(2.0, 8.0), (5.0, 12.0),    # overlap -> union [2, 12)
                    (18.0, 25.0)]               # straddles the retirement
    slot.retired_at = 20.0
    # lifetime [0, 20): 20 - union([2,12)) - clip([18,25) -> [18,20))
    assert pool.capacity_integral(30.0) == pytest.approx(20 - 10 - 2)
    # before the retirement the clip is t_end itself
    assert pool.capacity_integral(6.0) == pytest.approx(6 - 4)
    # a double-struck machine never goes negative
    slot.outages.append((0.0, 50.0))
    assert pool.capacity_integral(30.0) == 0.0


# --------------------------------------------------------------- shedding
class _ShedAll:
    """Degenerate policy: sheds every movable job (accounting probe)."""
    name = "shed_all"
    joint = False
    replans_on_fleet_events = False

    def decide(self, requests, now):
        return [[SHED] * len(req.movable) for req in requests]


def test_shed_accounting_and_run_invariant():
    jobs = [_cloud_job("A", 0.0, proc_c=5.0, deadline=30.0,
                       weight=2.0, workload="alert"),
            _cloud_job("B", 1.0, proc_c=5.0, deadline=30.0,
                       weight=1.0, workload="phenotype")]
    res = simulate_metro([jobs], _ShedAll(),
                         machines_per_tier={CC: 1, ES: 1})
    m = res.metrics
    assert (m.completions, m.shed, m.finished) == (0, 2, 2)
    assert m.miss_rate == 1.0 and m.shed_rate == 1.0
    assert m.weighted_miss_rate == 1.0
    assert m.by_class == {"alert": [0, 0, 1], "phenotype": [0, 0, 1]}
    assert res.wards[0].entries == []         # nothing ever ran
    sheds = [ev for ev in res.event_log if ev[0] == "shed"]
    assert sheds == [("shed", 0.0, 0, 0, "A"), ("shed", 1.0, 0, 1, "B")]


def test_bad_policy_decision_rejected_centrally():
    class _Mars:
        name = "mars"
        joint = False
        replans_on_fleet_events = False

        def decide(self, requests, now):
            return [["mars"] * len(req.movable) for req in requests]

    with pytest.raises(ValueError, match="mars"):
        simulate_metro([[_cloud_job("A", 0.0, proc_c=1.0)]], _Mars(),
                       machines_per_tier={CC: 1, ES: 1})


def test_shedding_policy_spares_the_life_critical_class():
    # under the saturation pack the shedder drops work — but never a job
    # of the heaviest weight class (alerts/threats, w=2): it chooses
    # WHICH deadline to miss, and w=1 phenotype reports pay
    _, res = _run_pack("mass_casualty_crash", SheddingPolicy())
    m = res.metrics
    assert m.shed > 0
    w_max = max(m.class_weight.values())
    for cls, (done, missed, shed) in m.by_class.items():
        if m.class_weight[cls] >= w_max:
            assert shed == 0, f"shed a {cls} job (w={m.class_weight[cls]})"
    assert any(shed for _, _, shed in m.by_class.values())
    # and the protection is the point: life-critical misses beat greedy's
    _, greedy = _run_pack("mass_casualty_crash", GreedyPolicy())
    assert m.critical_miss_rate < greedy.metrics.critical_miss_rate


# ------------------------------------------------------- degraded network
def test_network_window_reroutes_decisions():
    # cloud normally wins (arrival 3, end 8 vs edge 21); inside a 10x
    # degraded-uplink window the shipped-to-cloud price is 21 > edge 21?
    # no: trans 2 -> 20, end 1+20+5 = 26 > edge 1+1+20 = 22 -> edge
    job = JobSpec(name="A", release=1.0, weight=1.0,
                  proc={CC: 5.0, ES: 20.0, ED: 200.0},
                  trans={CC: 2.0, ES: 1.0, ED: 0.0})
    base = simulate_metro([[job]], GreedyPolicy(),
                          machines_per_tier={CC: 1, ES: 1})
    assert base.wards[0].entries[0].machine == CC
    net = NetworkEvent(time=0.0, duration=10.0, tier=CC, factor=10.0)
    res = simulate_metro([[job]], GreedyPolicy(),
                         machines_per_tier={CC: 1, ES: 1},
                         network_events=[net])
    (a,) = res.wards[0].entries
    assert a.machine == ES                      # the window re-routed it
    assert a.arrival == 2.0                     # edge trans NOT degraded
    opens = [ev for ev in res.event_log if ev[0] == "net"]
    assert opens == [("net", 0.0, CC, 10.0, 1), ("net", 10.0, CC, 10.0, 0)]


def test_network_factors_compound_and_unwind():
    eng = MetroEngine([[_cloud_job("A", 0.0, proc_c=1.0)]],
                      GreedyPolicy(), machines_per_tier={CC: 1, ES: 1})
    e1 = NetworkEvent(time=0.0, duration=10.0, tier=CC, factor=2.0)
    e2 = NetworkEvent(time=1.0, duration=5.0, tier=CC, factor=3.0)
    eng._on_net(0.0, e1, True)
    eng._on_net(1.0, e2, True)
    assert eng._net_factor(CC) == pytest.approx(6.0)    # windows compound
    assert eng._net_factor(ES) == 1.0
    eng._on_net(6.0, e2, False)
    assert eng._net_factor(CC) == pytest.approx(2.0)
    eng._on_net(10.0, e1, False)
    assert eng._net_factor(CC) == 1.0 and not eng._net


def test_network_event_validation():
    jobs = [[_cloud_job("A", 0.0, proc_c=1.0)]]
    with pytest.raises(ValueError, match="shared tier"):
        MetroEngine(jobs, GreedyPolicy(), machines_per_tier={CC: 1, ES: 1},
                    network_events=[NetworkEvent(time=0.0, tier=ED)])
    with pytest.raises(ValueError, match="factor"):
        MetroEngine(jobs, GreedyPolicy(), machines_per_tier={CC: 1, ES: 1},
                    network_events=[NetworkEvent(time=0.0, factor=0.0)])


# --------------------------------------------------- scenario-pack chaos
@pytest.mark.parametrize("pack", sorted(traces.SCENARIO_PACKS))
def test_every_pack_is_deterministic_and_crash_consistent(pack):
    runs = [_run_pack(pack, GreedyPolicy(), seed=3) for _ in range(2)]
    (sc, a), (_, b) = runs
    assert a.event_log == b.event_log
    assert a.metrics.summary(a.utilization) == \
        b.metrics.summary(b.utilization)
    # crash consistency: every job in the pack ends completed or shed,
    # and retries only appear in the crash packs
    m = a.metrics
    assert m.finished == sc.jobs
    kills = sum(1 for ev in a.event_log if ev[0] == "kill")
    assert kills == m.retries
    if any(f.kill_running for f in sc.failures):
        completes = [ev for ev in a.event_log if ev[0] == "complete"]
        assert max(ev[-1] for ev in completes) == m.max_attempts
    else:
        assert m.retries == 0 and m.wasted_seconds == 0.0
    if sc.network:
        net = [ev for ev in a.event_log if ev[0] == "net"]
        assert len(net) == 2 * len(sc.network)


def test_search_policy_deterministic_on_crash_pack():
    # the replanning path through kills/failovers, pinned off the JAX
    # dispatch cache (jax_threshold) so the run is call-order-independent
    runs = [_run_pack("edge_brownout", TabuPolicy(jax_threshold=10 ** 9),
                      seed=1, wards=2, horizon=40.0) for _ in range(2)]
    (sc, a), (_, b) = runs
    assert a.event_log == b.event_log
    assert a.metrics.finished == sc.jobs
    assert a.metrics.retries == sum(1 for ev in a.event_log
                                    if ev[0] == "kill")


def test_unknown_pack_rejected():
    with pytest.raises(ValueError, match="unknown scenario pack"):
        traces.make_scenario("nope")


# ------------------------------------------------- per-scenario perf gate
class TestScenarioGate:
    """check_regression.py metro_scenarios logic (no bench run)."""

    def _mod(self):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        os.pardir, "benchmarks"))
        try:
            import check_regression
        finally:
            sys.path.pop(0)
        return check_regression

    def _reports(self):
        base = {"metro_scenarios": {
            "edge_brownout": {"events_per_s": 1000.0,
                              "miss_rate_improvement": 3.0,
                              "critical_improvement_shed": 4.0},
            "diurnal_day": {"events_per_s": 5000.0,
                            "miss_rate_improvement": None,
                            "critical_improvement_shed": None}}}
        import copy
        return base, copy.deepcopy(base)

    def test_metric_extraction_skips_vacuous(self):
        cr = self._mod()
        committed, _ = self._reports()
        keys = cr._metro_scenario_metrics(committed)
        assert keys == {
            "metro_scenarios/edge_brownout/events_per_s": 1000.0,
            "metro_scenarios/edge_brownout/miss_rate_improvement": 3.0,
            "metro_scenarios/edge_brownout/critical_improvement_shed": 4.0,
            "metro_scenarios/diurnal_day/events_per_s": 5000.0}

    def test_identical_reports_pass(self):
        cr = self._mod()
        committed, fresh = self._reports()
        assert cr.compare(committed, fresh) == []

    def test_floor_regression_fails(self):
        cr = self._mod()
        committed, fresh = self._reports()
        fresh["metro_scenarios"]["edge_brownout"]["events_per_s"] = 100.0
        problems = cr.compare(committed, fresh, tolerance=0.30)
        assert any("edge_brownout/events_per_s" in p for p in problems)

    def test_ranking_flip_fails_regardless_of_tolerance(self):
        cr = self._mod()
        committed, fresh = self._reports()
        fresh["metro_scenarios"]["edge_brownout"][
            "critical_improvement_shed"] = 0.9
        problems = cr.compare(committed, fresh, tolerance=10.0)
        assert any("no longer wins" in p for p in problems)

    def test_fresh_vacuous_improvement_is_not_a_flip(self):
        cr = self._mod()
        committed, fresh = self._reports()
        fresh["metro_scenarios"]["edge_brownout"][
            "miss_rate_improvement"] = None
        assert cr.compare(committed, fresh, tolerance=0.30) == []

    def test_best_of_n_overlay_rescues_wall_clock_only(self):
        cr = self._mod()
        committed, fresh = self._reports()
        key = "metro_scenarios/edge_brownout/events_per_s"
        fresh["metro_scenarios"]["edge_brownout"]["events_per_s"] = 100.0
        assert cr.compare(committed, fresh) != []
        assert cr.compare(committed, fresh, best={key: 950.0}) == []
        # the overlay never rescues a ranking invariant
        fresh["metro_scenarios"]["edge_brownout"][
            "critical_improvement_shed"] = 0.5
        problems = cr.compare(
            committed, fresh,
            best={key: 950.0,
                  "metro_scenarios/edge_brownout/critical_improvement_shed":
                  9.0})
        assert any("no longer wins" in p for p in problems)

    def test_wall_clock_key_classifier(self):
        cr = self._mod()
        assert cr._is_wall_clock("metro_scenarios/edge_brownout/"
                                 "events_per_s")
        assert cr._is_wall_clock("batched/wards_per_s_batched")
        assert not cr._is_wall_clock(
            "metro_scenarios/edge_brownout/critical_improvement_shed")
