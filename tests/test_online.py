"""Online (non-clairvoyant) scheduler tests."""
import numpy as np

from prop import sweep
from repro.core import online, scheduler
from repro.core.problems import table6_jobs
from repro.core.simulator import MACHINES, JobSpec
from repro.core.tiers import CC, ED, ES


def _random_jobs(rng, n=8):
    return [JobSpec(name=f"J{i}", release=float(rng.integers(0, 40)),
                    weight=float(rng.integers(1, 3)),
                    proc={t: float(rng.integers(1, 30)) for t in MACHINES},
                    trans={CC: float(rng.integers(0, 60)),
                           ES: float(rng.integers(0, 15)), ED: 0.0})
            for i in range(n)]


def _check_valid(jobs, sched):
    for e in sched.entries:
        assert e.start >= e.job.release + e.job.trans[e.machine] - 1e-9
        assert abs(e.end - e.start - e.job.proc[e.machine]) < 1e-9
    for tier in (CC, ES):
        spans = sorted((e.start, e.end) for e in sched.entries
                       if e.machine == tier)
        for (s1, e1), (s2, _) in zip(spans, spans[1:]):
            assert s2 >= e1 - 1e-9


def test_online_valid_and_bounded():
    def check(rng):
        jobs = _random_jobs(rng)
        for replan in ("greedy", "tabu"):
            s = online.online_schedule(jobs, replan=replan)
            _check_valid(jobs, s)
            assert len(s.entries) == len(jobs)
    sweep(check, n_cases=12)


def test_online_never_beats_exact_clairvoyant():
    """vs the EXACT offline optimum the ratio is provably >= 1 (the online
    scheduler may beat the offline *heuristic* — observed on seed 8)."""
    from repro.core.scheduler import exact_optimum

    def check(rng):
        jobs = _random_jobs(rng, n=6)
        on = online.online_schedule(jobs, replan="tabu")
        opt = exact_optimum(jobs, objective="weighted")
        r = on.weighted_sum / max(opt.weighted_sum, 1e-9)
        assert r >= 1.0 - 1e-9, r
        assert r < 5.0, r       # sane upper bound on these instances
    sweep(check, n_cases=8)


def test_online_on_paper_jobs():
    jobs = table6_jobs()
    on = online.online_schedule(jobs, replan="tabu")
    off = scheduler.neighborhood_search(jobs)
    _check_valid(jobs, on)
    # clairvoyance is worth something but the online plan stays close
    assert on.weighted_sum >= off.weighted_sum - 1e-9
    assert on.weighted_sum <= off.weighted_sum * 2.0


def test_tabu_replan_no_worse_than_greedy_on_average():
    rng = np.random.default_rng(0)
    g_total, t_total = 0.0, 0.0
    for seed in range(10):
        jobs = _random_jobs(np.random.default_rng(seed), n=10)
        g_total += online.online_schedule(jobs, replan="greedy").weighted_sum
        t_total += online.online_schedule(jobs, replan="tabu").weighted_sum
    assert t_total <= g_total * 1.05
