"""Online (non-clairvoyant) scheduler tests, including the DESIGN.md §7
invariant: the objective the replan search reports equals bit-for-bit the
objective of the commits it records."""
import numpy as np
import pytest

from prop import sweep
from repro.core import online, scheduler
from repro.core.problems import ONLINE_SCENARIOS, table6_jobs
from repro.core.simulator import MACHINES, JobSpec
from repro.core.tiers import CC, ED, ES

FLEETS = ({CC: 1, ES: 1}, {CC: 2, ES: 3})


def _random_jobs(rng, n=8):
    return [JobSpec(name=f"J{i}", release=float(rng.integers(0, 40)),
                    weight=float(rng.integers(1, 3)),
                    proc={t: float(rng.integers(1, 30)) for t in MACHINES},
                    trans={CC: float(rng.integers(0, 60)),
                           ES: float(rng.integers(0, 15)), ED: 0.0})
            for i in range(n)]


def _check_valid(jobs, sched):
    for e in sched.entries:
        assert e.start >= e.job.release + e.job.trans[e.machine] - 1e-9
        assert abs(e.end - e.start - e.job.proc[e.machine]) < 1e-9
    for tier in (CC, ES):
        spans = sorted((e.start, e.end) for e in sched.entries
                       if e.machine == tier)
        for (s1, e1), (s2, _) in zip(spans, spans[1:]):
            assert s2 >= e1 - 1e-9


def test_online_valid_and_bounded():
    def check(rng):
        jobs = _random_jobs(rng)
        for replan in ("greedy", "tabu"):
            s = online.online_schedule(jobs, replan=replan)
            _check_valid(jobs, s)
            assert len(s.entries) == len(jobs)
    sweep(check, n_cases=12)


def test_online_multi_server_valid():
    """Multi-server fleets are honored: never more concurrent jobs on a
    tier than it has machines, in both replan modes."""
    def check(rng):
        jobs = _random_jobs(rng, n=10)
        mpt = {CC: 2, ES: 3}
        for replan in ("greedy", "tabu"):
            s = online.online_schedule(jobs, replan=replan,
                                       machines_per_tier=mpt)
            assert len(s.entries) == len(jobs)
            for e in s.entries:
                assert e.start >= e.job.release + e.job.trans[e.machine] \
                    - 1e-9
            for tier, m in mpt.items():
                spans = [(e.start, e.end) for e in s.entries
                         if e.machine == tier]
                for t0, _ in spans:   # concurrency at each start instant
                    running = sum(1 for s0, e0 in spans if s0 <= t0 < e0)
                    assert running <= m, (tier, t0, running)
    sweep(check, n_cases=8)


def test_replan_objective_parity():
    """Acceptance invariant (DESIGN.md §7): at every tabu replan event the
    objective the search reports for its chosen assignment equals
    BIT-FOR-BIT the objective of the commits actually recorded — over 50+
    seeded instances, single- and multi-server fleets."""
    events = 0
    for seed in range(26):
        rng = np.random.default_rng(seed)
        jobs = _random_jobs(rng, n=int(rng.integers(5, 9)))
        for mpt in FLEETS:
            trace = []
            online.online_schedule(jobs, replan="tabu",
                                   machines_per_tier=mpt, trace=trace)
            assert trace, "tabu mode must trace replan events"
            for ev in trace:
                assert ev["reported"] == ev["committed"], \
                    (seed, mpt, ev["reported"], ev["committed"])
            events += len(trace)
    assert events >= 50 * 2


def test_online_never_commits_before_busy_until():
    """Regression: a replanned start can never precede the machine
    availability the replan was given (the seed scored candidates as if
    all machines were idle at t=0)."""
    for seed in range(12):
        rng = np.random.default_rng(seed)
        jobs = _random_jobs(rng, n=10)
        for mpt in FLEETS:
            trace = []
            s = online.online_schedule(jobs, replan="tabu",
                                       machines_per_tier=mpt, trace=trace)
            by_name = {e.job.name: e for e in s.entries}
            # a job's surviving commit comes from the LAST event that
            # replanned it — check it against that event's availability
            last_ev = {}
            for ev in trace:
                for i in ev["movable"]:
                    last_ev[i] = ev
            for i, ev in last_ev.items():
                e = by_name[jobs[i].name]
                assert e.start >= ev["now"] - 1e-9
                if e.machine == ED:
                    continue
                # with every server of the tier occupied, nothing can
                # start before the earliest machine frees up
                busy = ev["busy"][e.machine]
                if len(busy) == mpt[e.machine]:
                    assert e.start >= min(busy) - 1e-9, \
                        (seed, mpt, ev["now"], e)


def test_online_never_beats_exact_clairvoyant():
    """vs the EXACT offline optimum the ratio is provably >= 1 (the online
    scheduler may beat the offline *heuristic* — observed on seed 8)."""
    from repro.core.scheduler import exact_optimum

    def check(rng):
        jobs = _random_jobs(rng, n=6)
        on = online.online_schedule(jobs, replan="tabu")
        opt = exact_optimum(jobs, objective="weighted")
        r = on.weighted_sum / max(opt.weighted_sum, 1e-9)
        assert r >= 1.0 - 1e-9, r
        assert r < 5.0, r       # sane upper bound on these instances
    sweep(check, n_cases=8)


@pytest.mark.slow
def test_online_never_beats_exact_clairvoyant_sweep():
    """Acceptance sweep: competitive ratio >= 1 - 1e-9 on 50+ seeded
    instances, single- AND multi-server fleets."""
    from repro.core.scheduler import exact_optimum

    checked = 0
    for seed in range(50):
        rng = np.random.default_rng(seed)
        jobs = _random_jobs(rng, n=6)
        for mpt in FLEETS:
            on = online.online_schedule(jobs, replan="tabu",
                                        machines_per_tier=mpt)
            opt = exact_optimum(jobs, objective="weighted",
                                machines_per_tier=mpt)
            r = on.weighted_sum / max(opt.weighted_sum, 1e-9)
            assert r >= 1.0 - 1e-9, (seed, mpt, r)
            checked += 1
    assert checked >= 50


def test_competitive_ratio_dispatches_through_search():
    """Satellite regression: competitive_ratio goes through the
    size-dispatched scheduler.search, so a tiny jax_threshold exercises
    the jitted path end-to-end (the seed called neighborhood_search
    directly and bypassed it)."""
    jobs = _random_jobs(np.random.default_rng(5), n=10)
    r_py = online.competitive_ratio(jobs, replan="tabu", jax_threshold=100)
    r_jax = online.competitive_ratio(jobs, replan="tabu", jax_threshold=4)
    for r in (r_py, r_jax):
        assert 1.0 - 1e-9 <= r < 10.0


def test_scenario_generators_online_ready():
    """Poisson / ER-surge / nightly-quiet generators produce sorted,
    online-schedulable instances; quiet wards track clairvoyance closely."""
    for name, gen in ONLINE_SCENARIOS.items():
        jobs = gen(np.random.default_rng(0))
        rel = [j.release for j in jobs]
        assert rel == sorted(rel)
        # offline side is the HEURISTIC search, which online may
        # legitimately beat on occasion — only sanity-bound the ratio
        r = online.competitive_ratio(jobs, replan="tabu")
        assert 0.9 <= r < 5.0, (name, r)
    quiet = ONLINE_SCENARIOS["quiet"](np.random.default_rng(1))
    assert online.competitive_ratio(quiet, replan="tabu") < 1.2


def test_online_on_paper_jobs():
    jobs = table6_jobs()
    on = online.online_schedule(jobs, replan="tabu")
    off = scheduler.neighborhood_search(jobs)
    _check_valid(jobs, on)
    # clairvoyance is worth something but the online plan stays close
    assert on.weighted_sum >= off.weighted_sum - 1e-9
    assert on.weighted_sum <= off.weighted_sum * 2.0


def test_tabu_replan_no_worse_than_greedy_on_average():
    rng = np.random.default_rng(0)
    g_total, t_total = 0.0, 0.0
    for seed in range(10):
        jobs = _random_jobs(np.random.default_rng(seed), n=10)
        g_total += online.online_schedule(jobs, replan="greedy").weighted_sum
        t_total += online.online_schedule(jobs, replan="tabu").weighted_sum
    assert t_total <= g_total * 1.05
