"""Fast-path scheduler tests: incremental ScheduleState invariants, the
rescore-after-move (float drift) regression, the randomized Python/JAX
parity suite (>= 200 instances), and the size-dispatched search."""
import numpy as np
import pytest

from repro.core import scheduler, scheduler_jax
from repro.core.problems import table6_jobs
from repro.core.simulator import (MACHINES, JobSpec, ScheduleState, simulate)
from repro.core.tiers import CC, ED, ES


def _random_jobs(rng, n, *, tie_heavy=False):
    """tie_heavy: tiny release/transmission ranges force many simultaneous
    arrivals, exercising the (arrival, release, index) FIFO tiebreak."""
    rel_hi, tc_hi, te_hi = (3, 2, 2) if tie_heavy else (30, 60, 15)
    return [JobSpec(name=f"J{i}", release=float(rng.integers(0, rel_hi)),
                    weight=float(rng.integers(1, 4)),
                    proc={t: float(rng.integers(1, 30)) for t in MACHINES},
                    trans={CC: float(rng.integers(0, tc_hi)),
                           ES: float(rng.integers(0, te_hi)), ED: 0.0})
            for i in range(n)]


# --------------------------------------------- incremental state invariants
class TestScheduleState:
    def test_matches_simulate_under_random_move_sequences(self):
        for seed in range(40):
            rng = np.random.default_rng(seed)
            n = int(rng.integers(3, 12))
            jobs = _random_jobs(rng, n)
            mpt = {CC: int(rng.integers(1, 3)), ES: int(rng.integers(1, 3))}
            assign = [MACHINES[j] for j in rng.integers(0, 3, n)]
            st = ScheduleState(jobs, assign, machines_per_tier=mpt)
            for _ in range(15):
                k = int(rng.integers(0, n))
                dst = MACHINES[int(rng.integers(0, 3))]
                pred = {o: st.try_move(k, dst, o)
                        for o in ("weighted", "unweighted", "last")}
                st.apply_move(k, dst)
                ref = simulate(jobs, st.assign, machines_per_tier=mpt)
                assert abs(pred["weighted"] - ref.weighted_sum) < 1e-6
                assert abs(pred["unweighted"] - ref.unweighted_sum) < 1e-6
                assert abs(pred["last"] - ref.last_end) < 1e-6
                assert abs(st.score("weighted") - ref.weighted_sum) < 1e-9
                for e in ref.entries:
                    i = jobs.index(e.job)
                    assert abs(st.end[i] - e.end) < 1e-9

    def test_noop_move_is_identity(self):
        jobs = table6_jobs()
        st = ScheduleState(jobs, ["cloud"] * len(jobs))
        before = st.score()
        assert st.try_move(0, st.assign[0]) == before
        st.apply_move(0, st.assign[0])
        assert st.score() == before


# ------------------------------------------------- rescore-after-move fix
class TestDriftRegression:
    def test_pinned_objective_on_paper_instance(self):
        s = scheduler.neighborhood_search(table6_jobs())
        assert s.weighted_sum == 228.0
        assert s.unweighted_sum == 150.0
        assert s.last_end == 43.0

    def test_pinned_objective_on_fractional_instance(self):
        """Fixed instance with 0.1-step times (not exactly representable in
        binary): the seed `best -= v_max` accumulator drifts on these; the
        rescore-after-move search must report the exact re-simulated
        objective, pinned here."""
        rng = np.random.default_rng(123)
        jobs = [JobSpec(
            name=f"F{i}", release=float(rng.integers(0, 30)) * 0.1,
            weight=float(rng.integers(1, 4)) * 0.3,
            proc={t: float(rng.integers(1, 30)) * 0.1 for t in MACHINES},
            trans={CC: float(rng.integers(0, 60)) * 0.1,
                   ES: float(rng.integers(0, 15)) * 0.1, ED: 0.0})
            for i in range(12)]
        s = scheduler.neighborhood_search(jobs)
        assert s.weighted_sum == 8.25
        # the reported objective IS the exact re-simulation of the final
        # assignment — bit-for-bit, no accumulated error
        assert s.weighted_sum == simulate(jobs, s.assignment()).weighted_sum

    def test_incremental_matches_reference_on_integer_instances(self):
        """On integer instances float arithmetic is exact, so the seed
        reference and the incremental search must agree exactly."""
        for seed in range(60):
            rng = np.random.default_rng(seed)
            jobs = _random_jobs(rng, int(rng.integers(3, 12)))
            a = scheduler.neighborhood_search(jobs)
            b = scheduler.neighborhood_search_reference(jobs)
            assert a.weighted_sum == b.weighted_sum, seed


# --------------------------------------------------- Python vs JAX parity
class TestEvaluatorParity:
    """simulate == evaluate_assignments over >= 200 random instances,
    including multi-machine tiers and simultaneous-arrival ties. Instance
    shapes are drawn from a fixed grid so jit caches stay warm."""

    GRID = [  # (n, (cloud_machines, edge_machines), tie_heavy, cases)
        (6, (1, 1), False, 40),
        (6, (2, 1), False, 30),
        (6, (1, 3), True, 30),
        (10, (1, 1), True, 40),
        (10, (2, 2), False, 30),
        (10, (3, 2), True, 40),
    ]

    @pytest.mark.parametrize("n,mpt,tie_heavy,cases", GRID)
    def test_parity(self, n, mpt, tie_heavy, cases):
        for case in range(cases):
            rng = np.random.default_rng(hash((n, mpt, tie_heavy)) %
                                        (2 ** 31) + case)
            jobs = _random_jobs(rng, n, tie_heavy=tie_heavy)
            assigns = rng.integers(0, 3, size=(8, n)).astype(np.int32)
            rel, w, proc, trans = scheduler_jax.specs_to_arrays(jobs)
            m = scheduler_jax.evaluate_assignments(
                assigns, rel, w, proc, trans, machines_per_tier=mpt)
            for ai in range(8):
                s = simulate(jobs, [MACHINES[j] for j in assigns[ai]],
                             machines_per_tier={CC: mpt[0], ES: mpt[1]})
                assert abs(float(m["weighted"][ai]) - s.weighted_sum) < 1e-3
                assert abs(float(m["unweighted"][ai])
                           - s.unweighted_sum) < 1e-3
                assert abs(float(m["last"][ai]) - s.last_end) < 1e-3

    def test_deterministic_tie_break(self):
        """Three jobs arriving at the same instant on the same machine run
        in (release, index) order in both evaluators."""
        jobs = [
            JobSpec(name="A", release=2.0, weight=1.0,
                    proc={CC: 5.0, ES: 5.0, ED: 50.0},
                    trans={CC: 0.0, ES: 0.0, ED: 0.0}),
            JobSpec(name="B", release=0.0, weight=1.0,
                    proc={CC: 3.0, ES: 3.0, ED: 50.0},
                    trans={CC: 2.0, ES: 2.0, ED: 0.0}),
            JobSpec(name="C", release=0.0, weight=1.0,
                    proc={CC: 7.0, ES: 7.0, ED: 50.0},
                    trans={CC: 2.0, ES: 2.0, ED: 0.0}),
        ]
        for assign in ([CC, CC, CC], [ES, ES, ES]):
            s = simulate(jobs, assign)
            by_name = {e.job.name: e for e in s.entries}
            # all arrive at t=2; order must be B (release 0, idx 1),
            # C (release 0, idx 2), A (release 2, idx 0)
            assert by_name["B"].start == 2.0
            assert by_name["C"].start == 5.0
            assert by_name["A"].start == 12.0
            rel, w, proc, trans = scheduler_jax.specs_to_arrays(jobs)
            enc = np.asarray([[MACHINES.index(t) for t in assign]], np.int32)
            m = scheduler_jax.evaluate_assignments(enc, rel, w, proc, trans)
            assert abs(float(m["weighted"][0]) - s.weighted_sum) < 1e-6
            assert abs(float(m["last"][0]) - s.last_end) < 1e-6


# ------------------------------------------------------ jitted tabu search
class TestTabuSearchJax:
    def test_reaches_exact_optimum_on_small_instances(self):
        for seed in range(5):
            jobs = _random_jobs(np.random.default_rng(seed), 7)
            v, a = scheduler_jax.tabu_search_jax(jobs)
            opt, _ = scheduler_jax.exact_optimum_jax(jobs)
            assert v <= opt * 1.05 + 1e-6
            # the returned value is the exact simulation of the returned
            # assignment
            s = simulate(jobs, [MACHINES[int(i)] for i in a])
            assert abs(v - s.weighted_sum) < 1e-3

    def test_improves_on_greedy_start(self):
        jobs = table6_jobs()
        v, _ = scheduler_jax.tabu_search_jax(jobs)
        greedy = simulate(jobs, scheduler.greedy_schedule(jobs))
        assert v <= greedy.weighted_sum + 1e-6


# -------------------------------------------------------- dispatched search
class TestSearchDispatch:
    def test_python_path_below_threshold(self):
        jobs = table6_jobs()
        a = scheduler.search(jobs, jax_threshold=100)
        b = scheduler.neighborhood_search(jobs)
        assert a.weighted_sum == b.weighted_sum

    def test_jax_path_above_threshold(self):
        jobs = _random_jobs(np.random.default_rng(0), 30)
        s = scheduler.search(jobs, jax_threshold=10)
        # valid exact schedule, at least as good as every baseline
        assert len(s.entries) == 30
        for t in MACHINES:
            assert s.weighted_sum <= \
                scheduler.all_on_tier(jobs, t).weighted_sum + 1e-6

    def test_online_replan_through_dispatcher(self):
        from repro.core import online
        jobs = _random_jobs(np.random.default_rng(3), 12)
        on_py = online.online_schedule(jobs, replan="tabu")
        on_jax = online.online_schedule(jobs, replan="tabu",
                                        jax_threshold=4)
        for s in (on_py, on_jax):
            assert len(s.entries) == 12
            for e in s.entries:
                assert e.start >= e.job.release + e.job.trans[e.machine] \
                    - 1e-9


# ------------------------------------------------- compiled-shape dispatch
class TestCompiledShapeCache:
    def test_second_same_shape_call_uses_jax(self, monkeypatch):
        """A CPU `search` whose BUCKETED (rows, movable, fleet,
        objective) shape an earlier call already compiled dispatches to
        the jitted backend (ROADMAP: repeating replans stop paying
        Python-path costs) — and the §12 bucketing means every size in
        the same 16-slot bucket rides the one compiled kernel, so metro
        load's per-event size drift keeps hitting."""
        monkeypatch.setattr(scheduler, "_COMPILED_SHAPES", set())
        calls = []
        real = scheduler_jax.tabu_search_batched

        def spy(*args, **kw):
            calls.append(kw.get("machines_per_tier"))
            return real(*args, **kw)

        monkeypatch.setattr(scheduler_jax, "tabu_search_batched", spy)
        jobs = _random_jobs(np.random.default_rng(0), 9)
        mpt = {CC: 2, ES: 1}

        first = scheduler.search(jobs, machines_per_tier=mpt)
        assert calls == []                      # below threshold: Python
        forced = scheduler.search(jobs, machines_per_tier=mpt,
                                  jax_threshold=0)
        assert len(calls) == 1                  # explicit: compiles shape
        cached = scheduler.search(jobs, machines_per_tier=mpt)
        assert len(calls) == 2                  # same shape: jitted now
        assert cached.weighted_sum == forced.weighted_sum
        assert first.weighted_sum > 0

        other = _random_jobs(np.random.default_rng(1), 10)
        scheduler.search(other, machines_per_tier=mpt)
        assert len(calls) == 3                  # same 16-bucket: jitted
        bigger = _random_jobs(np.random.default_rng(2), 20)
        scheduler.search(bigger, machines_per_tier=mpt)
        assert len(calls) == 3                  # new bucket: Python path
        scheduler.search(jobs, machines_per_tier={CC: 1, ES: 1})
        assert len(calls) == 3                  # new fleet: Python path
        scheduler.search(jobs, machines_per_tier=mpt,
                         objective="unweighted")
        assert len(calls) == 3                  # new objective: Python

    def test_shape_stats_and_cap(self, monkeypatch):
        """`compiled_shape_stats` counts hits/misses, and a miss at the
        cap evicts the whole cache instead of growing without bound."""
        monkeypatch.setattr(scheduler, "_COMPILED_SHAPES", set())
        monkeypatch.setattr(scheduler, "_SHAPE_STATS",
                            {"hits": 0, "misses": 0, "evictions": 0})
        jobs = _random_jobs(np.random.default_rng(0), 9)
        scheduler.search(jobs, jax_threshold=0)       # miss, compiles
        scheduler.search(jobs, jax_threshold=0)       # hit
        stats = scheduler.compiled_shape_stats()
        assert stats["size"] == 1
        assert stats["misses"] == 1 and stats["hits"] == 1
        assert stats["evictions"] == 0

        monkeypatch.setattr(scheduler, "_COMPILED_SHAPES_CAP", 1)
        scheduler.search(jobs, jax_threshold=0,
                         objective="unweighted")      # miss AT cap
        stats = scheduler.compiled_shape_stats()
        assert stats["evictions"] == 1
        assert stats["size"] == 1                     # cleared, re-added


# ----------------------------------------- batched initial/frozen threading
class TestBatchedInitialFrozen:
    def test_mixed_initials_agree_across_dispatch_paths(self):
        """A per-ward `initial` with gaps works on BOTH search_batched
        paths: the sequential fallback and the batched backend (which
        fills the gaps with the same greedy initial the solo path
        uses)."""
        probs = [_random_jobs(np.random.default_rng(s), 6)
                 for s in range(4)]
        initial = [["cloud"] * 6, None, ["device"] * 6, None]
        seq = scheduler.search_batched(probs, max_count=3,
                                       initial=initial, min_batch=99)
        bat = scheduler.search_batched(probs, max_count=3,
                                       initial=initial, min_batch=1)
        for s, b in zip(seq, bat):
            assert len(s.entries) == len(b.entries) == 6
            assert s.weighted_sum > 0 and b.weighted_sum > 0

    def test_frozen_background_via_search_batched(self):
        """frozen masks ride through search_batched to both backends and
        pin the background jobs' tiers."""
        probs = [_random_jobs(np.random.default_rng(s), 5)
                 for s in range(2)]
        initial = [["cloud", "cloud", "device", "device", "device"]] * 2
        frozen = [[True, True, False, False, False]] * 2
        for min_batch in (99, 1):
            plans = scheduler.search_batched(
                probs, max_count=3, initial=initial, frozen=frozen,
                min_batch=min_batch)
            for p in plans:
                assert p.assignment()[:2] == ["cloud", "cloud"]
        with pytest.raises(ValueError):
            scheduler.search_batched(probs, frozen=frozen, min_batch=1)
