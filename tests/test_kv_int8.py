"""int8-quantised KV cache: accuracy + memory accounting."""
import pytest

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.models.attention import _quantize


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 16, 32))
    q, s = _quantize(x)
    deq = q.astype(jnp.float32) * s[..., None]
    err = jnp.max(jnp.abs(deq - x)) / jnp.max(jnp.abs(x))
    assert q.dtype == jnp.int8
    assert float(err) < 1.0 / 127


def _run_decode(cfg, seed=0):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    b, l = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, l), 0,
                              cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    logits, cache = model.prefill(params, {"tokens": toks[:, :l - 3]},
                                  max_len=l)
    outs = []
    for t in range(l - 3, l):
        logits, cache = model.decode_step(params, toks[:, t], cache)
        outs.append(logits)
    return full, outs, cache


@pytest.mark.slow
def test_int8_decode_close_to_native():
    base = get_config("qwen2-1.5b").reduced(layers=2, d_model=128, vocab=256)
    cfg8 = dataclasses.replace(base, kv_cache_dtype="int8")
    full, outs_native, _ = _run_decode(base)
    _, outs_int8, cache = _run_decode(cfg8)
    # cache really is int8 (+ scales)
    leaves = jax.tree.leaves(cache["groups"])
    assert any(l.dtype == jnp.int8 for l in leaves)
    for t, (a, b) in enumerate(zip(outs_native, outs_int8)):
        # quantisation noise in logits stays small and ranks agree
        assert float(jnp.max(jnp.abs(a - b))) < 0.35
        agree = jnp.mean((jnp.argmax(a, -1) == jnp.argmax(b, -1))
                         .astype(jnp.float32))
        assert float(agree) == 1.0


def test_int8_halves_cache_bytes():
    base = get_config("qwen2-1.5b").reduced(layers=2, d_model=128, vocab=256)
    cfg8 = dataclasses.replace(base, kv_cache_dtype="int8")

    def cache_bytes(cfg):
        model = build_model(cfg)
        c = jax.eval_shape(lambda: model.init_cache(4, 4096))
        return sum(np.prod(l.shape) * np.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(c))

    b_native = cache_bytes(base)      # f32 reduced config: 4B/elt
    b_int8 = cache_bytes(cfg8)        # 1B/elt + scale/hd
    assert b_int8 < 0.35 * b_native
