"""Reprolint + metro-sanitizer tests (DESIGN.md §14).

Three layers:

  * rule fixtures — a positive and a negative snippet per rule
    (R001–R006), linted from tmp files so path-scoped rules (R002) see
    realistic repo-relative paths;
  * the linter contract — suppression comments, the CLI's exit codes
    and JSON report, and the acceptance bar that the repo's own `src`
    tree lints clean;
  * the sanitizer — direct violation injections (double-booking, FIFO
    inversion, mutated started job, double hedge, double terminal,
    missing terminal, capacity overdraw) plus the zero-perturbation
    contract: sanitize=True runs produce bit-identical event-log CRCs.
"""
import copy
import json
import os
import subprocess
import sys
import textwrap
import zlib
from pathlib import Path

import pytest

from repro.analysis import ALL_RULES, RULES_BY_ID, lint_paths
from repro.core.simulator import JobSpec
from repro.core.tiers import CC, ED, ES
from repro.metro import traces
from repro.metro.engine import MetroEngine, _Commit, simulate_metro
from repro.metro.policies import GreedyPolicy, HedgingPolicy, TabuPolicy
from repro.metro.sanitizer import MetroSanitizer, SanitizerViolation

from prop import random_fleet_events, sweep

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"
MPT = {CC: 2, ES: 2}


# ===================================================================
# linter fixtures
# ===================================================================

def lint_snippet(tmp_path, code, name="mod.py"):
    f = tmp_path / name
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(code))
    return lint_paths([f], ALL_RULES, root=tmp_path)


def rule_ids(findings):
    return [f.rule for f in findings]


class TestRuleFixtures:
    def test_r001_flags_bare_assert(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            def f(x):
                assert x > 0, "positive"
                return x
        """)
        assert rule_ids(fs) == ["R001"] and fs[0].line == 3

    def test_r001_negative_raise_guard(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            def f(x):
                if not x > 0:
                    raise ValueError(f"need positive, got {x}")
                return x
        """)
        assert fs == []

    def test_r002_flags_wall_clock_in_metro(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import time
            def step(now):
                t0 = time.time()
                return now + time.perf_counter() - t0
        """, name="metro/engine.py")
        assert rule_ids(fs) == ["R002", "R002"]

    def test_r002_resolves_import_aliases(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            from time import monotonic
            from datetime import datetime as dt
            def step():
                return monotonic(), dt.now()
        """, name="core/sim.py")
        assert rule_ids(fs) == ["R002", "R002"]

    def test_r002_scoped_to_simulation_dirs(self, tmp_path):
        # same wall-clock read outside metro/ / core/ is allowed —
        # launchers and benchmarks legitimately measure wall time
        fs = lint_snippet(tmp_path, """
            import time
            def bench():
                return time.perf_counter()
        """, name="launch/bench.py")
        assert fs == []

    def test_r003_flags_global_state_rng(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import random
            import numpy as np
            def draw():
                a = np.random.rand(3)
                b = random.choice([1, 2])
                rng = np.random.default_rng()
                return a, b, rng
        """)
        assert rule_ids(fs) == ["R003", "R003", "R003"]

    def test_r003_negative_seeded_generator(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import numpy as np
            def draw(seed):
                rng = np.random.default_rng(seed)
                return rng.uniform(), np.random.SeedSequence(seed)
        """)
        assert fs == []

    def test_r004_flags_order_revealing_set_iteration(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            def emit(names, heap):
                for n in set(names):
                    heap.append(n)
                order = list({"a", "b"} | set(names))
                pairs = [(n, 1) for n in frozenset(names)]
                return order, pairs
        """)
        assert rule_ids(fs) == ["R004", "R004", "R004"]

    def test_r004_negative_sorted_and_reductions(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            def emit(names, heap):
                for n in sorted(set(names)):
                    heap.append(n)
                return len(set(names)), max({1, 2}), "a" in set(names)
        """)
        assert fs == []

    def test_r005_flags_python_branch_on_traced_arg(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import jax

            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return float(x)
        """)
        assert rule_ids(fs) == ["R005", "R005"]

    def test_r005_negative_static_argnames_and_metadata(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("mode",))
            def f(x, mode):
                if mode == "fast":
                    return x
                if x.ndim > 2 or len(x) == 0:
                    return x
                return x * x.shape[0]
        """)
        assert fs == []

    def test_r005_sees_pallas_kernel_bodies(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import functools
            from jax.experimental import pallas as pl

            def kern(x_ref, o_ref, scale):
                if scale > 1.0:
                    o_ref[...] = x_ref[...] * scale
                v = x_ref[...].item()

            def call(x):
                return pl.pallas_call(functools.partial(kern, scale=2.0),
                                      out_shape=x)(x)
        """)
        # partial-bound `scale` is static (the If is fine); `.item()`
        # on a traced Ref value is not
        assert rule_ids(fs) == ["R005"]
        assert ".item()" in fs[0].message

    def test_r006_flags_immediate_jit_invocation(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import jax
            def step(f, x):
                return jax.jit(f)(x)
        """)
        assert rule_ids(fs) == ["R006"]

    def test_r006_negative_aot_lower_and_hoisted_jit(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import jax
            def compile_once(f, spec):
                return jax.jit(f).lower(spec)
            _step = None
            def step(f, x):
                global _step
                if _step is None:
                    _step = jax.jit(f)
                return _step(x)
        """)
        assert fs == []

    def test_r006_flags_raw_kernel_call_outside_dispatcher(self, tmp_path):
        code = """
            from repro.core.scheduler_jax import tabu_search_jax
            def plan(jobs):
                return tabu_search_jax(jobs)
        """
        assert rule_ids(lint_snippet(
            tmp_path, code, name="metro/policies.py")) == ["R006"]
        # ... but the dispatcher module itself owns those calls
        assert lint_snippet(tmp_path, code,
                            name="core/scheduler.py") == []

    def test_syntax_error_reports_e000(self, tmp_path):
        fs = lint_snippet(tmp_path, "def f(:\n    pass\n")
        assert rule_ids(fs) == ["E000"]


class TestSuppression:
    def test_trailing_comment_suppresses_one_rule(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            def f(x):
                assert x  # reprolint: disable=R001
                assert x
        """)
        assert [(f.rule, f.line) for f in fs] == [("R001", 4)]

    def test_comment_line_covers_line_below(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import time
            def step():
                # reprolint: disable=R002
                return time.time()
        """, name="metro/x.py")
        assert fs == []

    def test_bare_disable_suppresses_all_rules(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            import time
            def step(x):
                assert x and time.time()  # reprolint: disable
        """, name="metro/x.py")
        assert fs == []

    def test_mismatched_rule_id_does_not_suppress(self, tmp_path):
        fs = lint_snippet(tmp_path, """
            def f(x):
                assert x  # reprolint: disable=R002
        """)
        assert rule_ids(fs) == ["R001"]


class TestCLI:
    def _run(self, *argv, cwd=None):
        env = dict(os.environ, PYTHONPATH=str(SRC))
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *argv],
            capture_output=True, text=True, env=env, cwd=cwd or REPO)

    def test_exit_1_and_json_report_on_findings(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("def f(x):\n    assert x\n")
        out = self._run(str(bad), "--format", "json")
        assert out.returncode == 1, out.stderr
        report = json.loads(out.stdout)
        assert report["counts"] == {"R001": 1}
        (f,) = report["findings"]
        assert f["rule"] == "R001" and f["line"] == 2

    def test_exit_0_on_clean_tree(self, tmp_path):
        (tmp_path / "ok.py").write_text(
            "def f(x):\n"
            "    if not x:\n"
            "        raise ValueError('x')\n"
            "    return x\n")
        out = self._run(str(tmp_path))
        assert out.returncode == 0, out.stdout + out.stderr
        assert "0 finding(s)" in out.stdout

    def test_rule_subset_and_output_file(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("import time\nassert time.time()\n")
        rpt = tmp_path / "report.json"
        out = self._run(str(bad), "--rules", "R001",
                        "--output", str(rpt))
        assert out.returncode == 1
        report = json.loads(rpt.read_text())
        assert report["rules"] == ["R001"]
        assert [f["rule"] for f in report["findings"]] == ["R001"]

    def test_unknown_rule_exits_2(self, tmp_path):
        out = self._run(str(tmp_path), "--rules", "R999")
        assert out.returncode == 2
        assert "R999" in out.stderr

    def test_list_rules(self):
        out = self._run("--list-rules")
        assert out.returncode == 0
        for rid in RULES_BY_ID:
            assert rid in out.stdout


def test_repo_src_tree_lints_clean():
    """The acceptance bar: `python -m repro.analysis src` exits 0."""
    findings = lint_paths([SRC], ALL_RULES, root=REPO)
    assert findings == [], "\n".join(f.human() for f in findings)


# ===================================================================
# sanitizer: violation injections
# ===================================================================

def _cloud_job(name, release, proc_c, deadline=float("inf")):
    return JobSpec(name=name, release=release, weight=1.0,
                   proc={CC: proc_c, ES: 500.0, ED: 500.0},
                   trans={CC: 0.0, ES: 0.0, ED: 0.0}, deadline=deadline)


def _engine_with_commits(*commit_specs):
    """An un-run engine with hand-planted cloud commitments, plus its
    sanitizer. commit_specs: (arrival, start, end, slot)."""
    jobs = [_cloud_job(f"J{i}", 0.0, 1.0)
            for i in range(len(commit_specs))]
    eng = MetroEngine([jobs], GreedyPolicy(), machines_per_tier=MPT)
    for i, (arr, start, end, slot) in enumerate(commit_specs):
        eng.commits[0][i] = _Commit(job=jobs[i], machine=CC, arrival=arr,
                                    start=start, end=end, slot=slot,
                                    planned_at=0.0)
    return eng, MetroSanitizer(eng)


class TestSanitizerInjections:
    def test_double_booking_detected(self):
        # two started attempts overlap on cloud slot 0
        eng, san = _engine_with_commits(
            (0.0, 0.0, 10.0, 0), (0.0, 5.0, 15.0, 0))
        with pytest.raises(SanitizerViolation, match="I2-overlap"):
            san.check_pool(eng.cloud, 100.0)

    def test_clean_pool_passes(self):
        eng, san = _engine_with_commits(
            (0.0, 0.0, 10.0, 0), (0.0, 10.0, 20.0, 0))
        san.check_pool(eng.cloud, 100.0)
        assert san.checks == 1

    def test_fifo_inversion_detected(self):
        # job 0 arrived first (t=1) yet starts AFTER job 1 (arrived t=2)
        eng, san = _engine_with_commits(
            (1.0, 20.0, 21.0, 0), (2.0, 15.0, 16.0, 1))
        with pytest.raises(SanitizerViolation, match="I1-fifo"):
            san.check_pool(eng.cloud, 0.0)

    def test_mutated_started_job_detected(self):
        # C2: a started attempt's (machine, slot, start) may never move
        eng, san = _engine_with_commits((0.0, 0.0, 10.0, 0))
        san.check_pool(eng.cloud, 5.0)           # snapshot
        eng.commits[0][0].start = 2.0            # illegal re-timing
        with pytest.raises(SanitizerViolation, match="I3-immutable"):
            san.check_pool(eng.cloud, 5.0)

    def test_end_stretch_is_legal(self):
        # fail-slow re-timing stretches END only — not a C2 violation
        eng, san = _engine_with_commits((0.0, 0.0, 10.0, 0))
        san.check_pool(eng.cloud, 5.0)
        eng.commits[0][0].end = 14.0
        san.check_pool(eng.cloud, 5.0)

    def test_inverted_interval_detected(self):
        eng, san = _engine_with_commits((0.0, 10.0, 4.0, 0))
        with pytest.raises(SanitizerViolation, match="I2-interval"):
            san.check_pool(eng.cloud, 100.0)

    def test_slot_out_of_range_detected(self):
        eng, san = _engine_with_commits((0.0, 0.0, 10.0, 7))
        with pytest.raises(SanitizerViolation, match="I2-slot"):
            san.check_pool(eng.cloud, 100.0)

    def test_event_time_regression_detected(self):
        eng, san = _engine_with_commits((0.0, 0.0, 1.0, 0))
        san.on_event(5.0, ("arrive", 0, 0))
        with pytest.raises(SanitizerViolation, match="I4-monotonic"):
            san.on_event(3.0, ("arrive", 0, 1))

    def test_double_hedge_detected(self):
        eng, san = _engine_with_commits((0.0, 0.0, 1.0, 0))
        san.on_hedge(0, 0)
        with pytest.raises(SanitizerViolation, match="I5-single-hedge"):
            san.on_hedge(0, 0)

    def test_double_terminal_detected(self):
        eng, san = _engine_with_commits((0.0, 0.0, 1.0, 0))
        san.on_terminal(0, 0, "complete")
        with pytest.raises(SanitizerViolation, match="I6-terminal"):
            san.on_terminal(0, 0, "shed")

    def test_missing_terminal_detected_at_exit(self):
        eng, san = _engine_with_commits((0.0, 0.0, 1.0, 0))
        with pytest.raises(SanitizerViolation, match="I6-terminal"):
            san.at_exit(10.0)

    def test_capacity_overdraw_detected_at_exit(self):
        eng, san = _engine_with_commits((0.0, 0.0, 1.0, 0))
        san.on_terminal(0, 0, "complete")
        eng._t_end = 10.0
        eng.metrics.busy_time[CC] = 1e9   # more service than exists
        with pytest.raises(SanitizerViolation, match="I7-capacity"):
            san.at_exit(10.0)


# ===================================================================
# sanitizer: zero-perturbation CRC contract
# ===================================================================

def _crc(res):
    return zlib.crc32(repr(res.event_log).encode())


def _pack_kwargs(sc):
    return dict(machines_per_tier=MPT, failures=sc.failures,
                scale_events=sc.scales, network_events=sc.network,
                slowdowns=sc.slowdowns)


def test_sanitized_run_is_bit_identical_fast():
    sc = traces.make_scenario("default", seed=3, wards=2, horizon=12.0)
    base = simulate_metro(sc.traces, GreedyPolicy(), **_pack_kwargs(sc))
    san = simulate_metro(sc.traces, GreedyPolicy(), **_pack_kwargs(sc),
                         sanitize=True)
    assert san.event_log == base.event_log
    assert _crc(san) == _crc(base)


@pytest.mark.slow
@pytest.mark.parametrize("pack", sorted(traces.SCENARIO_PACKS))
def test_all_packs_sanitize_clean_with_identical_crc(pack):
    """Acceptance: every chaos pack runs sanitize=True without a
    violation and with a bit-identical event-log CRC — hedged execution
    included for the fail-slow pack (DESIGN.md §14)."""
    sc = traces.make_scenario(pack, seed=0)

    def run(sanitize):
        kw = _pack_kwargs(sc)
        if pack == "fail_slow_tail":
            pol = HedgingPolicy(inner=TabuPolicy(jax_threshold=10 ** 9),
                                min_gain=1.0)
            kw.update(hedge_factor=1.3, retry_backoff=1.0,
                      max_attempts=3)
        else:
            pol = TabuPolicy(jax_threshold=10 ** 9)
        return simulate_metro(sc.traces, pol, **kw, sanitize=sanitize)

    base, san = run(False), run(True)
    assert san.event_log == base.event_log, pack
    assert _crc(san) == _crc(base)
    assert san.metrics.finished == sc.jobs


@pytest.mark.slow
def test_fuzzed_fleet_events_run_clean_under_sanitizer():
    """Random crash/slowdown/scale/network interleavings never trip an
    invariant, and sanitized runs replay bit-identically."""
    def policies():
        return (GreedyPolicy(),
                TabuPolicy(jax_threshold=10 ** 9),
                HedgingPolicy(inner=TabuPolicy(jax_threshold=10 ** 9),
                              min_gain=1.0))

    def check(rng):
        horizon, wards = 30.0, 2
        tr = traces.metro_traces(rng, wards, horizon, base_rate=0.15)
        if not any(tr):
            return
        events = random_fleet_events(rng, horizon, wards)
        for make in policies():
            runs = []
            for sanitize in (False, True):
                pol = copy.deepcopy(make)
                kw = {"hedge_factor": 1.3} \
                    if hasattr(pol, "hedge") else {}
                eng = MetroEngine(tr, pol, machines_per_tier=MPT,
                                  max_attempts=3, retry_backoff=1.0,
                                  **events, **kw)
                runs.append(eng.run(sanitize=sanitize))
            base, san = runs
            assert base.event_log == san.event_log, make.name

    sweep(check, n_cases=6, seed=11)
