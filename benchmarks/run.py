"""Benchmark harness: one function per paper table/figure + framework
benches. Prints ``name,us_per_call,derived`` CSV lines.

  python -m benchmarks.run              # everything (+roofline when the
                                        # dry-run artifacts exist)
  python -m benchmarks.run --roofline   # force §Roofline
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--art-dir", default="experiments/dryrun")
    args, _ = ap.parse_known_args()

    from benchmarks.kernel_bench import bench_kernels
    from benchmarks.paper_tables import (bench_fig5_fig6, bench_table5,
                                         bench_table7)
    from benchmarks.scheduler_scale import bench_scheduler_scale

    print("name,us_per_call,derived")
    for bench in (bench_table5, bench_table7, bench_fig5_fig6,
                  bench_scheduler_scale, bench_kernels):
        _, csv = bench()
        for line in csv:
            print(line)

    have_art = os.path.isdir(args.art_dir) and \
        len(os.listdir(args.art_dir)) >= 40
    if args.roofline or have_art:
        from benchmarks.roofline import (bench_roofline, compare_baseline,
                                         to_markdown)
        rows, csv = bench_roofline(args.art_dir)
        for line in csv:
            print(line)
        base_dir = os.path.join(os.path.dirname(args.art_dir) or ".",
                                "dryrun_baseline")
        if os.path.isdir(base_dir):
            for line in compare_baseline(base_dir, args.art_dir):
                print(line)
        md = to_markdown(rows)
        out = os.path.join(os.path.dirname(args.art_dir) or ".",
                           "roofline.md")
        with open(out, "w") as f:
            f.write(md + "\n")
        print(f"# roofline table written to {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
