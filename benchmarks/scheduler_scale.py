"""Scheduler benchmarks beyond the paper's scale.

Head-to-head Algorithm-2 implementations (the repo's single hottest path):

  * reference — the seed full-re-simulation Python tabu search
    (scheduler.neighborhood_search_reference), O(rounds * n^2) simulations;
  * incremental — the ScheduleState-backed tabu search
    (scheduler.neighborhood_search), O(two queues) per candidate move;
  * jax — the fully jitted neighbourhood search
    (scheduler_jax.tabu_search_jax), one vmapped n x 3 neighbourhood
    evaluation per lax.while_loop round, no host syncs.

Also: JAX batched-evaluation throughput, heuristic optimality gap,
fleet-scale batched planning throughput in wards/sec (``batched`` section:
scheduler_jax.tabu_search_batched vs the sequential per-instance
`scheduler.search` loop, DESIGN.md §8), cross-ward shared-cloud contention
(``contention`` section: the double-booking gap of independent per-ward
plans on the fleet-true evaluator and how much of it the fixed-point
`scheduler.search_fleet` recovers, DESIGN.md §9), and the online
(non-clairvoyant) competitive ratio — including, behind ``--online``, per-arrival-scenario
ratios (poisson steady-state / ER-surge burst / nightly-quiet,
core.problems.ONLINE_SCENARIOS) on single- and multi-server fleets, whose
clairvoyant baselines are planned by one batched call per sweep. Results
are printed as the harness CSV and written machine-readable to
BENCH_scheduler.json so the perf trajectory is tracked across PRs —
benchmarks/check_regression.py gates on those floors.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core import scheduler, scheduler_jax
from repro.core.simulator import MACHINES, JobSpec, simulate
from repro.core.tiers import CC, ED, ES

BENCH_JSON = os.environ.get("BENCH_SCHEDULER_JSON", "BENCH_scheduler.json")
# the seed path is O(rounds * n^2) full simulations — unusable beyond this
REFERENCE_N_CAP = 100


def _random_jobs(rng, n):
    jobs = []
    for i in range(n):
        jobs.append(JobSpec(
            name=f"J{i}", release=float(rng.integers(0, 50)),
            weight=float(rng.integers(1, 3)),
            proc={t: float(rng.integers(1, 30)) for t in MACHINES},
            trans={CC: float(rng.integers(0, 60)),
                   ES: float(rng.integers(0, 15)), ED: 0.0}))
    return jobs


def _time(fn):
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def bench_head_to_head(sizes=(10, 100, 1000), max_count=5):
    """Python tabu (seed) vs incremental tabu vs jitted tabu, fixed seeds.

    Returns a list of per-(n, method) records with seconds, weighted
    objective, and speedup vs the reference path.
    """
    records = []
    for n in sizes:
        jobs = _random_jobs(np.random.default_rng(0), n)
        row = {"n": n, "max_count": max_count, "methods": {}}

        if n <= REFERENCE_N_CAP:
            dt, s = _time(lambda: scheduler.neighborhood_search_reference(
                jobs, max_count=max_count))
            row["methods"]["reference"] = {
                "seconds": dt, "weighted": s.weighted_sum}
        else:
            row["methods"]["reference"] = {
                "seconds": None, "weighted": None,
                "note": f"skipped: O(rounds*n^2) simulations at n={n}"}

        dt, s = _time(lambda: scheduler.neighborhood_search(
            jobs, max_count=max_count))
        row["methods"]["incremental"] = {
            "seconds": dt, "weighted": s.weighted_sum}

        # compile outside the timed region: the jitted search is reused
        # across replans of the same instance size in serving
        scheduler_jax.tabu_search_jax(jobs, max_rounds=1)
        dt, (_, a) = _time(lambda: scheduler_jax.tabu_search_jax(
            jobs, max_rounds=max_count))
        # score the returned assignment with the exact (float64) simulator
        # so all three methods' objectives share one evaluator
        exact = simulate(jobs, [MACHINES[int(i)] for i in a])
        row["methods"]["jax"] = {"seconds": dt,
                                 "weighted": exact.weighted_sum}

        ref = row["methods"]["reference"]["seconds"]
        for name, m in row["methods"].items():
            m["speedup_vs_reference"] = (
                ref / m["seconds"] if ref and m["seconds"] else None)
        records.append(row)
    return records


def bench_online_scenarios(seeds=6, n=20):
    """Competitive ratio (online / clairvoyant-offline) per arrival
    scenario and fleet shape. The clairvoyant baselines for a scenario's
    whole seed sweep are planned in ONE batched device call
    (online.competitive_ratio_batch -> scheduler.search_batched), shared
    by both replan modes."""
    from repro.core import online
    from repro.core.problems import ONLINE_SCENARIOS

    out = {}
    for scen, gen in ONLINE_SCENARIOS.items():
        out[scen] = {}
        for fleet, mpt in (("c1e1", {CC: 1, ES: 1}),
                           ("c2e3", {CC: 2, ES: 3})):
            instances = [gen(np.random.default_rng(1000 + seed), n=n)
                         for seed in range(seeds)]
            ratios = online.competitive_ratio_batch(
                instances, replans=("greedy", "tabu"),
                machines_per_tier=mpt)
            out[scen][fleet] = {
                replan: {"mean": float(np.mean(r)), "max": float(np.max(r))}
                for replan, r in ratios.items()}
    return out


def bench_batched(wards=32, n=100, max_count=5, repeats=3):
    """Fleet-scale planning throughput (wards/sec): one batched device
    call (tabu_search_batched) vs the sequential per-instance loop the
    repo used before the batched subsystem existed (`scheduler.search`
    per ward — on CPU that's the incremental Python path; also timed: a
    per-instance jitted `tabu_search_jax` loop). Both sides are measured
    best-of-`repeats` after a warm-up call so jit compiles and load
    spikes don't skew the ratio. Batched-vs-per-instance disagreements
    after exact re-simulation are recorded as ``parity_mismatches``
    (benchmarks/check_regression.py fails on any nonzero value; the test
    suite's parity sweeps guard the same invariant)."""
    from repro.core import scheduler_jax

    instances = [_random_jobs(np.random.default_rng(3000 + i), n)
                 for i in range(wards)]
    max_rounds = max_count

    def _best_of(fn):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best, out

    scheduler_jax.tabu_search_batched(instances, max_rounds=1)   # compile
    scheduler_jax.tabu_search_jax(instances[0], max_rounds=1)
    t_batched, (_, assigns_b) = _best_of(
        lambda: scheduler_jax.tabu_search_batched(
            instances, max_rounds=max_rounds))
    t_jax_loop, assigns_s = _best_of(lambda: [
        scheduler_jax.tabu_search_jax(jobs, max_rounds=max_rounds)[1]
        for jobs in instances])
    t_search_loop, _ = _best_of(lambda: [
        scheduler.search(jobs, max_count=max_count) for jobs in instances])

    # batched == per-instance, re-scored through the exact simulator
    mismatches = sum(
        simulate(jobs, [MACHINES[int(i)] for i in ab]).weighted_sum
        != simulate(jobs, [MACHINES[int(i)] for i in asolo]).weighted_sum
        for jobs, ab, asolo in zip(instances, assigns_b, assigns_s))
    return {
        "wards": wards, "n": n, "max_count": max_count,
        "seconds_batched": t_batched,
        "seconds_sequential_search_loop": t_search_loop,
        "seconds_sequential_jax_loop": t_jax_loop,
        "wards_per_s_batched": wards / t_batched,
        "wards_per_s_sequential": wards / t_search_loop,
        "speedup_batched_vs_sequential": t_search_loop / t_batched,
        "parity_mismatches": int(mismatches),
    }


def bench_contention(wards=32, n=100, cloud_machines=4, edge_machines=2,
                     max_count=5, max_sweeps=4):
    """Cross-ward shared-cloud contention (DESIGN.md §9): how badly B
    independent per-ward plans double-book the metropolitan cloud
    (``contention_gap`` — fleet-true / claimed objective of the naive
    plans, > 1 when overcommitted), how much of that gap the fixed-point
    `scheduler.search_fleet` recovers (``gap_closed``), how many sweeps
    convergence takes, and the contention-aware planning throughput in
    wards/sec. Jobs come from `problems.metro_jobs` (the paper's Table VI
    cost regime — cloud fast but far), the regime where every ward
    really loads the shared cloud.

    Sweeps are pinned to the incremental Python backend so this
    section's committed floors keep measuring the same code path now
    that ``sweep_backend="auto"`` takes the batched kernel on CPU too —
    the kernel path gets its own section, `bench_contention_interval`
    (DESIGN.md §12)."""
    from repro.core.problems import metro_jobs

    instances = [metro_jobs(np.random.default_rng(5000 + i), n=n)
                 for i in range(wards)]
    mpt = {CC: cloud_machines, ES: edge_machines}
    # warm the naive batched search's compile cache at the real shape
    # (max_sweeps=0: the Python sweeps have nothing to compile)
    scheduler.search_fleet(instances, machines_per_tier=mpt,
                           max_count=1, max_sweeps=0,
                           sweep_backend="python")
    t0 = time.perf_counter()
    plan = scheduler.search_fleet(instances, machines_per_tier=mpt,
                                  max_count=max_count,
                                  max_sweeps=max_sweeps,
                                  sweep_backend="python")
    seconds = time.perf_counter() - t0
    return {
        "wards": wards, "n": n,
        "cloud_machines": cloud_machines, "edge_machines": edge_machines,
        "max_count": max_count, "max_sweeps": max_sweeps,
        "naive_reported": plan.naive_reported,
        "naive_fleet_true": plan.naive_fleet.weighted_sum,
        "fleet_true": plan.fleet.weighted_sum,
        "contention_gap": plan.contention_gap,
        "gap_closed": plan.gap_closed,
        "improvement_vs_naive": plan.naive_fleet.weighted_sum
        / max(plan.fleet.weighted_sum, 1e-9),
        "sweeps": plan.sweeps,
        "seconds": seconds,
        "wards_per_s": wards / seconds,
    }


def bench_contention_interval(wards=32, n=100, cloud_machines=4,
                              edge_machines=2, max_count=5, max_sweeps=4):
    """The §12 interval-reservation fleet path: `search_fleet` with its
    defaults — interval background, batched Gauss–Seidel sweeps on CPU
    too — on the exact fleet `bench_contention` times through the pinned
    Python sweeps.

    Guarded: planning throughput (wards/s — the tentpole's >= 10x over
    the pre-interval floor), the recovered gap, and
    ``fraction_of_batched``: this path's throughput as a fraction of ONE
    independent §8 `search_batched` call over the same fleet, timed
    in-section (so ``--runs N`` re-times both sides together) — the
    "fleet sweeps at §8 batched speeds" claim as a committed ratio.
    ``parity_with_phantom`` is a hard invariant downstream: the interval
    plan must reproduce the frozen-phantom construction's plan
    bit-identically, or strictly beat its fleet-true objective.
    ``compiled_shapes`` surfaces the bucketed-dispatch cache counters
    (§3.3): under a healthy bucketing contract the timed run is all
    hits, no evictions."""
    from repro.core.problems import metro_jobs

    instances = [metro_jobs(np.random.default_rng(5000 + i), n=n)
                 for i in range(wards)]
    mpt = {CC: cloud_machines, ES: edge_machines}
    # warm BOTH compiled shapes: the naive batched search at (B, n) and
    # the batched sweep at the padded (jobs + reservations) row bucket —
    # the same naive incumbent (same seeds, same max_count) yields the
    # same first-sweep background, so the warmed bucket is the timed one
    scheduler.search_fleet(instances, machines_per_tier=mpt,
                           max_count=max_count, max_sweeps=1)
    t0 = time.perf_counter()
    plan = scheduler.search_fleet(instances, machines_per_tier=mpt,
                                  max_count=max_count,
                                  max_sweeps=max_sweeps)
    seconds = time.perf_counter() - t0
    # the independent §8 floor on this host: one batched search over the
    # same fleet (compiled already — the naive stage above uses it)
    t0 = time.perf_counter()
    scheduler.search_batched(instances, machines_per_tier=mpt,
                             max_count=max_count)
    t_indep = time.perf_counter() - t0
    phantom = scheduler.search_fleet(instances, machines_per_tier=mpt,
                                     max_count=max_count,
                                     max_sweeps=max_sweeps,
                                     background="phantom")
    parity = plan.assignments == phantom.assignments \
        or plan.fleet.weighted_sum < phantom.fleet.weighted_sum
    return {
        "wards": wards, "n": n,
        "cloud_machines": cloud_machines, "edge_machines": edge_machines,
        "max_count": max_count, "max_sweeps": max_sweeps,
        "naive_reported": plan.naive_reported,
        "naive_fleet_true": plan.naive_fleet.weighted_sum,
        "fleet_true": plan.fleet.weighted_sum,
        "contention_gap": plan.contention_gap,
        "gap_closed": plan.gap_closed,
        "improvement_vs_naive": plan.naive_fleet.weighted_sum
        / max(plan.fleet.weighted_sum, 1e-9),
        "sweeps": plan.sweeps,
        "seconds": seconds,
        "wards_per_s": wards / seconds,
        "seconds_independent_batched": t_indep,
        "fraction_of_batched": t_indep / seconds,
        "phantom_fleet_true": phantom.fleet.weighted_sum,
        "parity_with_phantom": bool(parity),
        "compiled_shapes": scheduler.compiled_shape_stats(),
    }


def bench_metro(wards=4, hours=2.0, seed=0):
    """Streaming metro traffic (DESIGN.md §10): the canonical scenario
    (`metro.traces.default_scenario` — diurnal + surge arrivals, cloud
    failures, elastic capacity) replayed under the greedy, tabu-replan
    and fleet fixed-point policies on identical traces. Guarded metrics:
    engine throughput in events/s (the tabu run — the replanning hot
    path) and the tabu-vs-greedy deadline miss-rate improvement, which
    `check_regression.py` additionally requires to stay strictly > 1
    (replanning must actually beat commit-and-hold)."""
    from repro.launch.serve import run_metro

    out = run_metro(wards=wards, hours=hours, seed=seed, verbose=False)
    g, t, f = out["greedy"], out["tabu"], out["fleet"]
    # improvement is vacuous when greedy is already perfect (None, so the
    # gate skips it rather than hard-failing a flawless run), and a
    # perfect tabu run is floored at half-a-missed-job so one committed
    # baseline can't demand a near-infinite ratio forever after
    g_miss, t_miss = g["miss_rate"], t["miss_rate"]
    improvement = None if g_miss == 0 else \
        g_miss / max(t_miss, 0.5 / max(g["completions"], 1))
    return {
        "wards": wards, "hours": hours, "seed": seed,
        "jobs": g["completions"],
        "events_tabu": t["events"],
        "events_per_s": t["events_per_s"],
        "miss_rate_greedy": g_miss,
        "miss_rate_tabu": t_miss,
        "miss_rate_fleet": f["miss_rate"],
        "miss_rate_improvement": improvement,
        "p50": {k: v["p50"] for k, v in out.items()},
        "p99": {k: v["p99"] for k, v in out.items()},
        "utilization_tabu": t["utilization"],
        # §3.3 bucketed-dispatch cache counters after the three runs —
        # `serve --metro` prints the same line (PR 10, DESIGN.md §15)
        "compiled_shapes": scheduler.compiled_shape_stats(),
    }


CHAOS_PACKS = ("edge_brownout", "mass_casualty_crash",
               "degraded_network", "diurnal_day")


def _ratio(base, other, completions):
    """miss-rate improvement `base/other` with bench_metro's semantics:
    None (vacuous) when the baseline is already perfect, the divisor
    floored at half a missed job so a perfect run can't demand a
    near-infinite ratio forever after."""
    return None if base == 0 else \
        base / max(other, 0.5 / max(completions, 1))


def bench_metro_scenarios(packs=CHAOS_PACKS, seed=0):
    """Chaos scenario packs (DESIGN.md §11): every registered pack
    replayed at its canonical shape under greedy, tabu-replan and the
    shedding wrapper on identical traces/failures/network windows.

    Guarded per pack: engine throughput (events/s, tabu), the
    tabu-vs-greedy miss-rate improvement, and the shed policy's
    life-critical miss-rate improvement vs greedy (the admission-control
    claim: sacrificing a bounded share of the lowest-weight class must
    protect the life-critical SLA). The search backend is pinned to the
    Python path so the committed numbers are call-order-independent
    (metro.engine's determinism note)."""
    from repro.launch.serve import run_metro

    out = {}
    for pack in packs:
        res = run_metro(seed=seed, scenario=pack,
                        policies=("greedy", "tabu", "shed"),
                        verbose=False, jax_threshold=10 ** 9)
        g, t, sh = res["greedy"], res["tabu"], res["shed"]
        out[pack] = {
            "seed": seed,
            "jobs": g["completions"] + g["shed"],
            "events_per_s": t["events_per_s"],
            "miss_rate_greedy": g["miss_rate"],
            "miss_rate_tabu": t["miss_rate"],
            "miss_rate_shed": sh["miss_rate"],
            "miss_rate_improvement": _ratio(
                g["miss_rate"], t["miss_rate"], g["completions"]),
            "critical_miss_greedy": g["critical_miss_rate"],
            "critical_miss_shed": sh["critical_miss_rate"],
            "critical_improvement_shed": _ratio(
                g["critical_miss_rate"], sh["critical_miss_rate"],
                g["completions"]),
            "shed_rate_shed": sh["shed_rate"],
            "retries_tabu": t["retries"],
            "wasted_machine_seconds_tabu": t["wasted_machine_seconds"],
            "max_attempts_tabu": t["max_attempts"],
            "event_log_hash_tabu": t["event_log_hash"],
        }
    return out


def bench_metro_hedging(seed=0):
    """Tail-tolerant hedging under fail-slow machines (DESIGN.md §13):
    the `fail_slow_tail` pack — deep slowdown windows crawling the ward
    edge pools at 3-8% speed, cloud healthy — replayed under tabu-replan
    with and without the deadline-aware hedging wrapper on identical
    traces and slowdown windows.

    Guarded: engine throughput of the hedged run (events/s) and two
    ratios `check_regression.py` holds as HARD ranking invariants at any
    tolerance — the hedged run must strictly beat the unhedged run on
    both the life-critical miss rate (`critical_improvement_hedge`) and
    the p99 response (`p99_improvement_hedge`). The search backend is
    pinned to the Python path so the committed numbers are
    call-order-independent (metro.engine's determinism note)."""
    from repro.launch.serve import run_metro

    def one(hedged):
        return run_metro(seed=seed, scenario="fail_slow_tail",
                         policies=("tabu",), verbose=False,
                         jax_threshold=10 ** 9, hedge=hedged)["tabu"]

    base, hedged = one(False), one(True)
    return {
        "seed": seed,
        "jobs": hedged["completions"] + hedged["shed"],
        "events_per_s": hedged["events_per_s"],
        "critical_miss_unhedged": base["critical_miss_rate"],
        "critical_miss_hedged": hedged["critical_miss_rate"],
        "critical_improvement_hedge": _ratio(
            base["critical_miss_rate"], hedged["critical_miss_rate"],
            base["completions"]),
        "p99_unhedged": base["p99"],
        "p99_hedged": hedged["p99"],
        "p99_improvement_hedge": base["p99"] / hedged["p99"],
        "p999_unhedged": base["p999"],
        "p999_hedged": hedged["p999"],
        "hedges": hedged["hedges"],
        "hedge_wins": hedged["hedge_wins"],
        "hedge_rate": hedged["hedge_rate"],
        "hedge_waste": hedged["hedge_waste"],
        "event_log_hash_unhedged": base["event_log_hash"],
        "event_log_hash_hedged": hedged["event_log_hash"],
    }


def bench_metro_observability(seed=0):
    """Flight-recorder cost + parity (DESIGN.md §15): every chaos pack
    replayed twice under tabu-replan (hedged on `fail_slow_tail`, whose
    races exercise the hedge spans) — once untraced, once with the
    tracer armed — on identical traces/failures/windows.

    Guarded: per-pack ``crc_parity`` (the traced run's event log must
    hash bit-identically to the untraced run's — the tracer is a
    read-only observer; a HARD invariant in check_regression.py) and
    the aggregate ``events_per_s_retention`` (traced throughput as a
    fraction of untraced over all packs), which the gate holds above
    1/1.15: the armed recorder may cost at most 15%. The search backend
    is pinned to the Python path so both runs replay identical
    decisions (metro.engine's determinism note)."""
    import zlib

    from repro.metro import (HedgingPolicy, make_policy, simulate_metro,
                             traces)

    packs = CHAOS_PACKS + ("fail_slow_tail",)
    mpt = {CC: 2, ES: 2}
    out = {"seed": seed, "packs": {}}
    sec_untraced = sec_traced = events_total = 0.0
    spans_total = 0
    for pack in packs:
        sc = traces.make_scenario(pack, seed)
        hedged = pack == "fail_slow_tail"

        def one(traced):
            pol = make_policy("tabu", jax_threshold=10 ** 9)
            kw = {}
            if hedged:
                pol = HedgingPolicy(inner=pol)
                kw["hedge_factor"] = 1.5
            return simulate_metro(
                sc.traces, pol, machines_per_tier=mpt,
                failures=sc.failures, scale_events=sc.scales,
                network_events=sc.network, slowdowns=sc.slowdowns,
                trace=traced, **kw)

        one(False)      # warm-up: first replay of a pack pays cold-start
        base, traced = one(False), one(True)
        sb, st = base.summary(), traced.summary()
        parity = zlib.crc32(repr(base.event_log).encode()) \
            == zlib.crc32(repr(traced.event_log).encode())
        out["packs"][pack] = {
            "hedged": hedged,
            "jobs": st["completions"] + st["shed"],
            "events": st["events"],
            "spans": len(traced.trace.spans),
            "crc_parity": bool(parity),
            "events_per_s_untraced": sb["events_per_s"],
            "events_per_s_traced": st["events_per_s"],
            "retention": st["events_per_s"] / sb["events_per_s"],
        }
        events_total += st["events"]
        spans_total += len(traced.trace.spans)
        sec_untraced += sb["events"] / sb["events_per_s"]
        sec_traced += st["events"] / st["events_per_s"]
    out.update(
        events=int(events_total), spans=spans_total,
        crc_parity_all=all(p["crc_parity"] for p in out["packs"].values()),
        events_per_s_retention=sec_untraced / sec_traced)
    return out


def bench_online_fleet(seeds=3, wards=4, n=10, cloud_machines=2,
                       edge_machines=2):
    """Online fleet replanning vs the clairvoyant fixed point
    (`online.competitive_ratio_fleet`, DESIGN.md §9 follow-up): the
    price of event-by-event ward-aware replanning against
    `search_fleet`'s fleet-true plan on the same shared cloud, per seed
    over the contention benchmark's `metro_jobs` regime."""
    from repro.core import online
    from repro.core.problems import metro_jobs

    mpt = {CC: cloud_machines, ES: edge_machines}
    runs = []
    for s in range(seeds):
        ward_jobs = [metro_jobs(
            np.random.default_rng(8000 + s * wards + b), n=n, horizon=30.0)
            for b in range(wards)]
        runs.append(online.competitive_ratio_fleet(
            ward_jobs, machines_per_tier=mpt))
    ratios = [r["ratio"] for r in runs]
    return {"wards": wards, "n": n,
            "cloud_machines": cloud_machines,
            "edge_machines": edge_machines,
            "runs": runs,
            "mean_ratio": float(np.mean(ratios)),
            "max_ratio": float(np.max(ratios))}


def bench_scheduler_scale(with_online_scenarios: bool = False,
                          out_path: str | None = None):
    rng = np.random.default_rng(0)
    rows, csv = [], []
    report = {"bench": "scheduler_scale", "backend": jax.default_backend(),
              "head_to_head": [], "eval_throughput": {}, "quality": {},
              "online": {}, "batched": {}, "contention": {},
              "contention_interval": {}, "metro": {}, "metro_hedging": {},
              "metro_observability": {}}

    # 1) Algorithm-2 head-to-head across implementations and scales
    for row in bench_head_to_head():
        report["head_to_head"].append(row)
        n = row["n"]
        for name, m in row["methods"].items():
            if m["seconds"] is None:
                continue
            rows.append((f"tabu_{name}", n, m["seconds"], m["weighted"]))
            speed = m["speedup_vs_reference"]
            csv.append(
                f"sched_tabu_{name}_n{n},{m['seconds']*1e6:.0f},"
                f"weighted={m['weighted']:.0f}"
                + (f";speedup_vs_seed={speed:.1f}x" if speed else ""))

    # 2) JAX batched evaluation throughput (incl. multi-machine tiers)
    jobs = _random_jobs(rng, 50)
    rel, w, proc, trans = scheduler_jax.specs_to_arrays(jobs)
    assigns = jax.numpy.asarray(rng.integers(0, 3, size=(4096, 50)),
                                jax.numpy.int32)
    for mpt in ((1, 1), (4, 2)):
        scheduler_jax.evaluate_assignments(assigns, rel, w, proc, trans,
                                           machines_per_tier=mpt)  # warm
        t0 = time.perf_counter()
        m = scheduler_jax.evaluate_assignments(assigns, rel, w, proc, trans,
                                               machines_per_tier=mpt)
        jax.block_until_ready(m["weighted"])
        dt = time.perf_counter() - t0
        per = dt / 4096 * 1e6
        label = f"c{mpt[0]}e{mpt[1]}"
        rows.append((f"jax_eval_{label}", 4096, dt, per))
        csv.append(f"sched_jax_eval_4096x50_{label},{per:.2f},"
                   f"candidates_per_s={4096/dt:.0f}")
        report["eval_throughput"][label] = {
            "candidates": 4096, "n": 50, "seconds": dt,
            "candidates_per_s": 4096 / dt}

    # 2b) stochastic-search baseline honors the deployed fleet (the seed
    # implementation silently scored every candidate on an idle (1, 1)
    # fleet — regression-guarded by recording the fleet-true objective)
    jobs = _random_jobs(np.random.default_rng(7), 30)
    key = jax.random.PRNGKey(0)
    initial = np.asarray([MACHINES.index(t)
                          for t in scheduler.greedy_schedule(
                              jobs, machines_per_tier={CC: 2, ES: 3})],
                         np.int32)
    v, a = scheduler_jax.stochastic_search(
        jobs, key, initial, iters=50, machines_per_tier=(2, 3))
    exact = simulate(jobs, [MACHINES[int(i)] for i in a],
                     machines_per_tier={CC: 2, ES: 3})
    csv.append(f"sched_stochastic_c2e3_n30,0,"
               f"weighted={exact.weighted_sum:.0f};claimed={v:.0f}")
    report["quality"]["stochastic_c2e3_n30"] = {
        "weighted": exact.weighted_sum, "claimed": v}

    # 3) heuristic optimality gap on small instances
    gaps = []
    for seed in range(5):
        jobs = _random_jobs(np.random.default_rng(seed), 8)
        ours = scheduler.neighborhood_search(jobs)
        v, _ = scheduler_jax.exact_optimum_jax(jobs, objective="weighted")
        gaps.append(ours.weighted_sum / max(v, 1e-9) - 1.0)
    csv.append(f"sched_optimality_gap_n8,0,mean_gap={np.mean(gaps):.2%};"
               f"max_gap={np.max(gaps):.2%}")
    report["quality"]["optimality_gap_n8"] = {
        "mean": float(np.mean(gaps)), "max": float(np.max(gaps))}

    # 4) online (non-clairvoyant) competitive ratio — beyond paper
    from repro.core import online
    ratios_g, ratios_t = [], []
    for seed in range(8):
        jobs = _random_jobs(np.random.default_rng(seed + 100), 12)
        off = scheduler.neighborhood_search(jobs).weighted_sum
        ratios_g.append(online.online_schedule(jobs, replan="greedy")
                        .weighted_sum / max(off, 1e-9))
        ratios_t.append(online.online_schedule(jobs, replan="tabu")
                        .weighted_sum / max(off, 1e-9))
    csv.append(f"sched_online_competitive,0,"
               f"greedy={np.mean(ratios_g):.3f};"
               f"tabu_replan={np.mean(ratios_t):.3f}")
    report["online"] = {"greedy": float(np.mean(ratios_g)),
                        "tabu_replan": float(np.mean(ratios_t))}

    # 5) fleet-scale batched planning throughput (wards/sec)
    report["batched"] = bench_batched()
    b = report["batched"]
    rows.append(("batched_wards", b["wards"], b["seconds_batched"],
                 b["wards_per_s_batched"]))
    csv.append(
        f"sched_batched_B{b['wards']}_n{b['n']},"
        f"{b['seconds_batched']*1e6:.0f},"
        f"wards_per_s={b['wards_per_s_batched']:.0f};"
        f"speedup_vs_sequential={b['speedup_batched_vs_sequential']:.1f}x;"
        f"parity_mismatches={b['parity_mismatches']}")

    # 5b) cross-ward shared-cloud contention (DESIGN.md §9)
    report["contention"] = bench_contention()
    c = report["contention"]
    rows.append(("contention_wards", c["wards"], c["seconds"],
                 c["wards_per_s"]))
    csv.append(
        f"sched_contention_B{c['wards']}_n{c['n']},"
        f"{c['seconds']*1e6:.0f},"
        f"gap={c['contention_gap']:.3f}x;"
        f"gap_closed={c['gap_closed']:.0%};"
        f"sweeps={c['sweeps']};"
        f"wards_per_s={c['wards_per_s']:.1f}")

    # 5b2) the §12 interval-reservation path on the same fleet: batched
    # sweeps on CPU, gated against both the naive fleet and the §8 floor
    report["contention_interval"] = bench_contention_interval()
    ci = report["contention_interval"]
    rows.append(("contention_interval_wards", ci["wards"], ci["seconds"],
                 ci["wards_per_s"]))
    shapes = ci["compiled_shapes"]
    csv.append(
        f"sched_contention_interval_B{ci['wards']}_n{ci['n']},"
        f"{ci['seconds']*1e6:.0f},"
        f"gap_closed={ci['gap_closed']:.0%};"
        f"sweeps={ci['sweeps']};"
        f"wards_per_s={ci['wards_per_s']:.1f};"
        f"fraction_of_batched={ci['fraction_of_batched']:.2f};"
        f"parity_with_phantom={ci['parity_with_phantom']};"
        f"shape_cache_hits={shapes['hits']};"
        f"shape_cache_evictions={shapes['evictions']}")

    # 5c) streaming metro traffic: policy comparison + engine throughput
    # (DESIGN.md §10)
    report["metro"] = bench_metro()
    m = report["metro"]
    rows.append(("metro_events", m["events_tabu"], 0.0,
                 m["events_per_s"]))
    imp = m["miss_rate_improvement"]
    csv.append(
        f"sched_metro_B{m['wards']}_{m['hours']:g}h,0,"
        f"miss_greedy={m['miss_rate_greedy']:.3f};"
        f"miss_tabu={m['miss_rate_tabu']:.3f};"
        f"miss_fleet={m['miss_rate_fleet']:.3f};"
        f"improvement={'vacuous' if imp is None else f'{imp:.2f}x'};"
        f"events_per_s={m['events_per_s']:.0f}")

    # 5d) chaos scenario packs: crash/shed/degraded-network regimes
    # (DESIGN.md §11)
    report["metro_scenarios"] = bench_metro_scenarios()
    for pack, ms in report["metro_scenarios"].items():
        rows.append((f"metro_{pack}", ms["jobs"], 0.0,
                     ms["events_per_s"]))
        mi, ci = ms["miss_rate_improvement"], \
            ms["critical_improvement_shed"]
        csv.append(
            f"sched_metro_{pack},0,"
            f"jobs={ms['jobs']};"
            f"miss_greedy={ms['miss_rate_greedy']:.3f};"
            f"miss_tabu={ms['miss_rate_tabu']:.3f};"
            f"improvement={'vacuous' if mi is None else f'{mi:.2f}x'};"
            f"crit_shed={'vacuous' if ci is None else f'{ci:.2f}x'};"
            f"shed_rate={ms['shed_rate_shed']:.3f};"
            f"retries={ms['retries_tabu']};"
            f"events_per_s={ms['events_per_s']:.0f}")

    # 5e) deadline-aware hedging vs fail-slow stragglers (DESIGN.md §13)
    report["metro_hedging"] = bench_metro_hedging()
    mh = report["metro_hedging"]
    rows.append(("metro_hedging", mh["jobs"], 0.0, mh["events_per_s"]))
    chi = mh["critical_improvement_hedge"]
    csv.append(
        f"sched_metro_hedging,0,"
        f"jobs={mh['jobs']};"
        f"crit_unhedged={mh['critical_miss_unhedged']:.4f};"
        f"crit_hedged={mh['critical_miss_hedged']:.4f};"
        f"crit_improvement={'vacuous' if chi is None else f'{chi:.2f}x'};"
        f"p99_improvement={mh['p99_improvement_hedge']:.3f}x;"
        f"hedges={mh['hedges']};wins={mh['hedge_wins']};"
        f"hedge_waste={mh['hedge_waste']:.1f};"
        f"events_per_s={mh['events_per_s']:.0f}")

    # 5f) flight-recorder overhead + traced/untraced CRC parity
    # (DESIGN.md §15)
    report["metro_observability"] = bench_metro_observability()
    mo = report["metro_observability"]
    rows.append(("metro_observability", mo["events"], 0.0,
                 mo["events_per_s_retention"]))
    csv.append(
        f"sched_metro_observability,0,"
        f"packs={len(mo['packs'])};"
        f"spans={mo['spans']};"
        f"crc_parity={mo['crc_parity_all']};"
        f"events_per_s_retention={mo['events_per_s_retention']:.3f}")

    # 6) per-scenario online competitive ratios (slower; gated by --online)
    if with_online_scenarios:
        scen = bench_online_scenarios()
        report["online"]["scenarios"] = scen
        for name, fleets in scen.items():
            for fleet, ratios in fleets.items():
                csv.append(
                    f"sched_online_{name}_{fleet},0,"
                    f"greedy={ratios['greedy']['mean']:.3f};"
                    f"tabu_replan={ratios['tabu']['mean']:.3f}")
        fleet_cr = bench_online_fleet()
        report["online"]["fleet"] = fleet_cr
        csv.append(
            f"sched_online_fleet_B{fleet_cr['wards']}_n{fleet_cr['n']},0,"
            f"mean_ratio={fleet_cr['mean_ratio']:.3f};"
            f"max_ratio={fleet_cr['max_ratio']:.3f}")

    out_path = out_path or BENCH_JSON
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    csv.append(f"# scheduler report written to {out_path},0,")
    return rows, csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--online", action="store_true",
                    help="also run the (slower) per-scenario online "
                         "competitive-ratio section")
    args = ap.parse_args()
    for line in bench_scheduler_scale(with_online_scenarios=args.online)[1]:
        print(line)
