"""Scheduler benchmarks beyond the paper's scale: the JAX-vectorised
evaluator vs the Python simulator, and heuristic quality vs exact optimum
over random fleets."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import scheduler, scheduler_jax
from repro.core.simulator import MACHINES, JobSpec
from repro.core.tiers import CC, ED, ES


def _random_jobs(rng, n):
    jobs = []
    for i in range(n):
        jobs.append(JobSpec(
            name=f"J{i}", release=float(rng.integers(0, 50)),
            weight=float(rng.integers(1, 3)),
            proc={t: float(rng.integers(1, 30)) for t in MACHINES},
            trans={CC: float(rng.integers(0, 60)),
                   ES: float(rng.integers(0, 15)), ED: 0.0}))
    return jobs


def bench_scheduler_scale():
    rng = np.random.default_rng(0)
    rows, csv = [], []

    # 1) Python tabu search at the paper's scale and 10x
    for n in (10, 50, 100):
        jobs = _random_jobs(rng, n)
        t0 = time.perf_counter()
        s = scheduler.neighborhood_search(jobs, max_count=5)
        dt = time.perf_counter() - t0
        base = scheduler.per_job_optimal(jobs)
        gain = 1.0 - s.weighted_sum / base.weighted_sum
        rows.append(("tabu", n, dt, gain))
        csv.append(f"sched_tabu_n{n},{dt*1e6:.0f},"
                   f"gain_vs_perjob={gain:.2%}")

    # 2) JAX batched evaluation throughput
    jobs = _random_jobs(rng, 50)
    rel, w, proc, trans = scheduler_jax.specs_to_arrays(jobs)
    assigns = jax.numpy.asarray(rng.integers(0, 3, size=(4096, 50)),
                                jax.numpy.int32)
    scheduler_jax.evaluate_assignments(assigns, rel, w, proc, trans)  # warm
    t0 = time.perf_counter()
    m = scheduler_jax.evaluate_assignments(assigns, rel, w, proc, trans)
    jax.block_until_ready(m["weighted"])
    dt = time.perf_counter() - t0
    per = dt / 4096 * 1e6
    rows.append(("jax_eval", 4096, dt, per))
    csv.append(f"sched_jax_eval_4096x50,{per:.2f},candidates_per_s="
               f"{4096/dt:.0f}")

    # 3) heuristic optimality gap on small instances
    gaps = []
    for seed in range(5):
        jobs = _random_jobs(np.random.default_rng(seed), 8)
        ours = scheduler.neighborhood_search(jobs)
        v, _ = scheduler_jax.exact_optimum_jax(jobs, objective="weighted")
        gaps.append(ours.weighted_sum / max(v, 1e-9) - 1.0)
    csv.append(f"sched_optimality_gap_n8,0,mean_gap={np.mean(gaps):.2%};"
               f"max_gap={np.max(gaps):.2%}")

    # 4) online (non-clairvoyant) competitive ratio — beyond paper
    from repro.core import online
    ratios_g, ratios_t = [], []
    for seed in range(8):
        jobs = _random_jobs(np.random.default_rng(seed + 100), 12)
        off = scheduler.neighborhood_search(jobs).weighted_sum
        ratios_g.append(online.online_schedule(jobs, replan="greedy")
                        .weighted_sum / max(off, 1e-9))
        ratios_t.append(online.online_schedule(jobs, replan="tabu")
                        .weighted_sum / max(off, 1e-9))
    csv.append(f"sched_online_competitive,0,"
               f"greedy={np.mean(ratios_g):.3f};"
               f"tabu_replan={np.mean(ratios_t):.3f}")
    return rows, csv
