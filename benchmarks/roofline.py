"""§Roofline: three-term roofline per (arch x shape x mesh) from the
dry-run artifacts (experiments/dryrun/*.json).

    compute    = FLOPs / (chips x 197 TFLOP/s)
    memory     = bytes_moved / (chips x 819 GB/s)
    collective = collective_bytes_per_chip / 50 GB/s ICI

FLOPs/bytes use the analytic accounting (utils.flops + the byte model
below): XLA's cost_analysis counts while-loop bodies ONCE (verified — see
EXPERIMENTS.md §Dry-run), so the compiled numbers are recorded in the JSON
but are not usable as totals. collective_bytes comes from the partitioned
HLO text and IS per-chip (the SPMD program is per-device), with the same
while-loop caveat noted per row where scans carry collectives.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import INPUT_SHAPES, get_config
from repro.core.tiers import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_FLOPS
from repro.launch.dryrun import variant_for_shape
from repro.utils import flops as F

ADAM_BYTES = 16   # m, v f32 read+write amortised (8B read + 8B write)


def analytic_bytes(arch: str, shape_name: str,
                   kv_dtype: str = "native") -> float:
    """Bytes moved through HBM per step (global, all chips)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = variant_for_shape(get_config(arch), shape)
    kv_byte = 1 if kv_dtype == "int8" else 2
    pbytes = F.param_bytes(cfg)
    d = cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        layers = max(cfg.num_layers, 1)
        # params: fwd read + bwd read + grad write f32 + adam state traffic
        param_traffic = pbytes * 2 + F.param_count(cfg) * (4 + ADAM_BYTES)
        # activations: residual write+read per layer (+remat recompute read)
        act_traffic = tokens * d * 2 * layers * 3
        return param_traffic + act_traffic
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return pbytes + tokens * d * 2 * cfg.num_layers * 2
    # decode: every live param read once + KV/state read
    kv = 0.0
    win = cfg.attn_window or cfg.long_context_window
    ctx = min(win, shape.seq_len) if win else shape.seq_len
    n_attn, n_cross = F._attn_layers(cfg)
    kv += (2 * n_attn * cfg.num_kv_heads * cfg.head_dim * ctx
           * kv_byte * shape.global_batch)
    kv += (2 * n_cross * cfg.num_kv_heads * cfg.head_dim
           * cfg.cross_attn_states * kv_byte * shape.global_batch)
    # recurrent states
    d_inner = cfg.ssm_expand * d
    for k in tuple(cfg.group_pattern) * cfg.num_groups:
        if k == "mamba":
            kv += (cfg.ssm_num_heads * cfg.ssm_head_dim * cfg.ssm_state_dim
                   * 4 * 2 * shape.global_batch)
        elif k == "mlstm":
            ph = d_inner // max(1, cfg.ssm_num_heads)
            kv += cfg.ssm_num_heads * ph * ph * 4 * 2 * shape.global_batch
    active_bytes = pbytes * F.active_param_count(cfg) / F.param_count(cfg)
    return active_bytes + kv


def load_records(art_dir: str, mesh: str = "16x16"):
    recs = {}
    for fn in glob.glob(os.path.join(art_dir, f"*_{mesh}.json")):
        with open(fn) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"])] = r
    return recs


def roofline_row(rec: dict) -> dict:
    chips = rec["devices"]
    arch, shape_name = rec["arch"], rec["shape"]
    fl = rec["analytic_step_flops"]
    by = analytic_bytes(arch, shape_name,
                        rec.get("kv_cache_dtype", "native"))
    coll = rec["collectives"]["total_bytes"]
    t_c = fl / (chips * TPU_PEAK_FLOPS)
    t_m = by / (chips * TPU_HBM_BW)
    t_n = coll / TPU_ICI_BW            # HLO is per-chip already
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    dom = max(terms, key=terms.get)
    useful = rec["model_flops_6nd"] / fl if fl else 0.0
    return {
        "arch": arch, "shape": shape_name, "chips": chips,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom,
        "model_flops": rec["model_flops_6nd"],
        "analytic_flops": fl,
        "useful_ratio": useful,
        "hlo_flops_per_chip": rec["hlo_flops"],
        "temp_gb_per_chip": rec["memory"].get("temp_size_in_bytes", 0) / 1e9,
        "collective_gb_per_chip": coll / 1e9,
        "microbatches": rec.get("microbatches", 1),
        "long_context_variant": rec.get("long_context_variant", False),
    }


SUGGESTIONS = {
    "compute": "compute-bound: raise per-chip utilisation (larger "
               "microbatch, fused kernels); already near the best regime",
    "memory": "memory-bound: cut HBM traffic (quantised KV cache, "
              "wider batching to amortise weight reads)",
    "collective": "collective-bound: reshard to cut gathers (replicated "
                  "residual, EP all-to-all for MoE, overlap collectives "
                  "with compute)",
}


def bench_roofline(art_dir: str = "experiments/dryrun"):
    recs = load_records(art_dir)
    rows, csv = [], []
    for (arch, shape_name), rec in sorted(recs.items()):
        row = roofline_row(rec)
        rows.append(row)
        csv.append(
            f"roofline_{arch}_{shape_name},0,"
            f"dom={row['dominant']};compute_ms={row['compute_s']*1e3:.3f};"
            f"memory_ms={row['memory_s']*1e3:.3f};"
            f"collective_ms={row['collective_s']*1e3:.3f};"
            f"useful={row['useful_ratio']:.2f}")
    return rows, csv


def compare_baseline(base_dir: str = "experiments/dryrun_baseline",
                     opt_dir: str = "experiments/dryrun",
                     mesh: str = "16x16"):
    """§Perf before/after: collective bytes + temp per case, baseline
    (paper-faithful first-pass sharding) vs optimized stack."""
    base = load_records(base_dir, mesh)
    opt = load_records(opt_dir, mesh)
    csv = []
    for key in sorted(set(base) & set(opt)):
        b = base[key]["collectives"]["total_bytes"]
        o = opt[key]["collectives"]["total_bytes"]
        bt = base[key]["memory"].get("temp_size_in_bytes", 0)
        ot = opt[key]["memory"].get("temp_size_in_bytes", 0)
        csv.append(
            f"perf_delta_{key[0]}_{key[1]},0,"
            f"collective_GB={b/1e9:.2f}->{o/1e9:.2f}"
            f"(x{b/max(o,1):.1f});temp_GB={bt/1e9:.1f}->{ot/1e9:.1f}")
    return csv


def to_markdown(rows) -> str:
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) "
           "| dominant | useful 6ND/analytic | temp GB/chip | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["shape"], r["arch"])):
        note = "SWA variant" if r["long_context_variant"] else ""
        if r["microbatches"] > 1:
            note += f" mb={r['microbatches']}"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.3f} | "
            f"{r['memory_s']*1e3:.3f} | {r['collective_s']*1e3:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['temp_gb_per_chip']:.1f} | {note} |")
    return "\n".join(out)
