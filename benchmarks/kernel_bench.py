"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-times are NOT TPU-representative — they are recorded for regression
tracking; the oracle-path timings are the CPU-meaningful numbers."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lstm_cell import lstm_cell


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench_kernels():
    rows, csv = [], []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)

    q = jax.random.normal(ks[0], (1, 4, 512, 64))
    k = jax.random.normal(ks[1], (1, 2, 512, 64))
    v = jax.random.normal(ks[2], (1, 2, 512, 64))
    us = _time(ref.attention_blockwise, q, k, v, causal=True)
    csv.append(f"attn_blockwise_jnp_512,{us:.0f},B1H4L512D64")
    us = _time(flash_attention, q, k, v, causal=True, interpret=True, reps=1)
    csv.append(f"attn_pallas_interp_512,{us:.0f},interpret-mode(not TPU perf)")

    x = jax.random.normal(ks[0], (64, 76))
    h = jax.random.normal(ks[1], (64, 32))
    c = jax.random.normal(ks[2], (64, 32))
    wx = jax.random.normal(ks[0], (76, 4, 32)) * 0.1
    wh = jax.random.normal(ks[1], (32, 4, 32)) * 0.1
    b = jnp.zeros((4, 32))
    us = _time(ref.lstm_cell_reference, x, h, c, wx.reshape(76, 128),
               wh.reshape(32, 128), b.reshape(128))
    csv.append(f"lstm_cell_jnp_b64,{us:.0f},icu-sized")
    us = _time(lstm_cell, x, h, c, wx, wh, b, interpret=True, reps=1)
    csv.append(f"lstm_cell_pallas_interp_b64,{us:.0f},interpret-mode")

    xs = jax.random.normal(ks[0], (1, 512, 4, 16))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 512, 4)))
    a = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.5)
    bm = jax.random.normal(ks[0], (1, 512, 16))
    cm = jax.random.normal(ks[1], (1, 512, 16))
    d = jax.random.normal(ks[2], (4,))
    us = _time(ref.ssm_scan_reference, xs, dt, a, bm, cm, d)
    csv.append(f"ssm_scan_sequential_jnp_512,{us:.0f},oracle")
    return rows, csv
