"""Perf regression gate for the scheduler hot paths.

Runs a fresh `benchmarks/scheduler_scale.py` sweep and compares it against
the committed floors in BENCH_scheduler.json, the same way tests guard
correctness: exits nonzero when any guarded metric regresses by more than
``--tolerance`` (default 30%).

Guarded metrics (all RELATIVE, so they transfer across machine speeds,
except wards/sec which assumes the committed baseline ran on comparable
hardware — regenerate the baseline when the CI host changes):

  * head-to-head ``speedup_vs_reference`` per (n, method) — the
    incremental and jitted searches must stay fast relative to the seed
    reference implementation;
  * ``jax_vs_incremental`` per n (derived: incremental seconds / jax
    seconds) — the delta-evaluated jitted search must not fall back
    behind the incremental Python path (the PR-3 n=1000 regression fix);
  * batched ``speedup_batched_vs_sequential`` and
    ``wards_per_s_batched`` — fleet planning throughput (DESIGN.md §8);
  * batched ``parity_mismatches`` must be exactly 0 (not a perf floor: the
    batched search must return the per-instance search's objectives);
  * contention ``improvement_vs_naive``, ``gap_closed`` and
    ``wards_per_s`` — the fixed-point fleet search must keep recovering
    the shared-cloud double-booking gap at speed (DESIGN.md §9); plus two
    hard invariants whenever a fresh contention section exists: the
    benchmark fleet must exhibit a nonzero contention gap (> 1 — if it
    does not, the benchmark no longer measures anything) and the fleet
    search must strictly beat the naive plans on the fleet-true
    objective;
  * contention_interval ``improvement_vs_naive``, ``gap_closed``,
    ``wards_per_s`` and ``fraction_of_batched`` — the §12
    interval-reservation fleet path must hold both its absolute
    throughput and its ratio to the independent §8 batched floor; plus
    hard invariants whenever the fresh section exists:
    ``parity_with_phantom`` must be True (the interval background must
    reproduce the frozen-phantom plan bit-identically or strictly beat
    it fleet-true) and the compiled-shape cache must report zero
    evictions (the §12 bucketing contract keeps the benchmark inside a
    handful of compiled shapes);
  * metro ``events_per_s`` and ``miss_rate_improvement`` — the streaming
    traffic engine must keep its event throughput and the tabu-vs-greedy
    deadline miss-rate win (DESIGN.md §10); plus the hard invariant that
    the improvement stays strictly > 1 whenever a fresh metro section
    exists;
  * per chaos scenario pack (``metro_scenarios``, DESIGN.md §11):
    ``events_per_s``, the tabu-vs-greedy ``miss_rate_improvement`` and
    the shedding policy's ``critical_improvement_shed``; plus hard
    ranking invariants — whenever the committed baseline shows a policy
    winning a pack (improvement > 1), the fresh run must not show it
    losing (<= 1), whatever the tolerance;
  * metro_hedging (DESIGN.md §13): ``events_per_s`` of the hedged run
    plus two HARD ranking invariants whenever a fresh section exists —
    under the ``fail_slow_tail`` pack the hedged tabu run must strictly
    beat the unhedged run on BOTH the life-critical miss rate
    (``critical_improvement_hedge`` > 1; None is vacuous — the unhedged
    run missed nothing) and the p99 response
    (``p99_improvement_hedge`` > 1), at any tolerance;
  * metro_observability (DESIGN.md §15): ``events_per_s_retention`` —
    the armed flight recorder's throughput as a fraction of the
    untraced run over every chaos pack; plus hard invariants whenever a
    fresh section exists — per-pack ``crc_parity`` must be True (the
    tracer is a read-only observer: a traced run's event log must hash
    bit-identically to the untraced run's) and the retention must stay
    above 1/1.15 (recording may cost at most 15%), at any tolerance.

Wall-clock throughput floors (events/s, wards/s, speedups) are prone to
host-throttling flakes: ``--runs N`` re-measures ONLY the failed
wall-clock floors up to N-1 more times and gates on the best
observation. Invariant and quality floors stay single-shot — a ranking
loss or parity mismatch is not a flake.

Invocation (documented in ROADMAP.md):

    PYTHONPATH=src python benchmarks/check_regression.py \
        --baseline BENCH_scheduler.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

# metrics measured from wall-clock timings (rerunnable via --runs);
# everything else is deterministic quality and stays single-shot
_WALL_CLOCK_TOKENS = ("events_per_s", "wards_per_s", "speedup",
                      "jax_vs_incremental", "fraction_of_batched",
                      "retention")


def _is_wall_clock(key: str) -> bool:
    return any(tok in key for tok in _WALL_CLOCK_TOKENS)


def _head_to_head_metrics(report: dict) -> dict:
    """-> {metric name: value} of guarded relative head-to-head metrics."""
    out = {}
    for row in report.get("head_to_head", ()):
        n = row["n"]
        methods = row.get("methods", {})
        for name, m in methods.items():
            speed = m.get("speedup_vs_reference")
            if speed:
                out[f"n{n}/{name}/speedup_vs_reference"] = speed
        inc = (methods.get("incremental") or {}).get("seconds")
        jx = (methods.get("jax") or {}).get("seconds")
        if inc and jx:
            out[f"n{n}/jax_vs_incremental"] = inc / jx
    return out


def _batched_metrics(report: dict) -> dict:
    b = report.get("batched") or {}
    out = {}
    for key in ("speedup_batched_vs_sequential", "wards_per_s_batched"):
        if b.get(key):
            out[f"batched/{key}"] = b[key]
    return out


def _contention_metrics(report: dict) -> dict:
    c = report.get("contention") or {}
    out = {}
    for key in ("improvement_vs_naive", "gap_closed", "wards_per_s"):
        if c.get(key):
            out[f"contention/{key}"] = c[key]
    return out


def _contention_interval_metrics(report: dict) -> dict:
    c = report.get("contention_interval") or {}
    out = {}
    for key in ("improvement_vs_naive", "gap_closed", "wards_per_s",
                "fraction_of_batched"):
        if c.get(key):
            out[f"contention_interval/{key}"] = c[key]
    return out


def _metro_metrics(report: dict) -> dict:
    m = report.get("metro") or {}
    out = {}
    for key in ("events_per_s", "miss_rate_improvement"):
        if m.get(key):
            out[f"metro/{key}"] = m[key]
    return out


def _metro_scenario_metrics(report: dict) -> dict:
    out = {}
    for pack, m in sorted((report.get("metro_scenarios") or {}).items()):
        for key in ("events_per_s", "miss_rate_improvement",
                    "critical_improvement_shed"):
            if m.get(key):         # None improvements are vacuous: skip
                out[f"metro_scenarios/{pack}/{key}"] = m[key]
    return out


def _metro_hedging_metrics(report: dict) -> dict:
    m = report.get("metro_hedging") or {}
    out = {}
    for key in ("events_per_s", "critical_improvement_hedge",
                "p99_improvement_hedge"):
        if m.get(key):             # None improvement is vacuous: skip
            out[f"metro_hedging/{key}"] = m[key]
    return out


def _metro_observability_metrics(report: dict) -> dict:
    m = report.get("metro_observability") or {}
    out = {}
    if m.get("events_per_s_retention"):
        out["metro_observability/events_per_s_retention"] = \
            m["events_per_s_retention"]
    return out


_METRIC_FNS = (_head_to_head_metrics, _batched_metrics,
               _contention_metrics, _contention_interval_metrics,
               _metro_metrics, _metro_scenario_metrics,
               _metro_hedging_metrics, _metro_observability_metrics)


def compare(committed: dict, fresh: dict, tolerance: float = 0.30,
            best: dict | None = None) -> list:
    """-> list of human-readable regression strings (empty == pass).

    A metric regresses when fresh < committed * (1 - tolerance). Metrics
    present in only one report are skipped (the gate tightens as the
    committed baseline gains sections, and never blocks on new ones).
    `best` overlays best-of-N re-measurements per metric key — callers
    populate it only for wall-clock floors (--runs), so invariant and
    quality floors always gate on the single fresh run.
    """
    problems = []
    for metrics in _METRIC_FNS:
        com, fre = metrics(committed), metrics(fresh)
        for key, floor in com.items():
            got = fre.get(key)
            if got is None:
                continue
            if best and best.get(key, got) > got:
                got = best[key]
            if got < floor * (1.0 - tolerance):
                problems.append(
                    f"{key}: {got:.3g} < committed {floor:.3g} "
                    f"- {tolerance:.0%}")
    mism = (fresh.get("batched") or {}).get("parity_mismatches")
    if mism:
        problems.append(f"batched/parity_mismatches: {mism} != 0")
    cont = fresh.get("contention") or {}
    if cont:
        # hard invariants, not perf floors (DESIGN.md §9): the benchmark
        # fleet must actually overcommit the shared cloud, and the fleet
        # search must strictly beat the naive plans fleet-true
        if cont.get("contention_gap", 0.0) <= 1.0:
            problems.append(
                f"contention/contention_gap: {cont.get('contention_gap')} "
                f"<= 1 (benchmark fleet no longer double-books the cloud)")
        if not cont.get("fleet_true", 0.0) < cont.get(
                "naive_fleet_true", 0.0):
            problems.append(
                f"contention: fleet_true {cont.get('fleet_true')} does not "
                f"strictly beat naive_fleet_true "
                f"{cont.get('naive_fleet_true')}")
    ci = fresh.get("contention_interval") or {}
    if ci:
        # hard invariants (DESIGN.md §12): the interval background must
        # reproduce the frozen-phantom oracle's plan (or strictly beat
        # it fleet-true), and the bucketed dispatch cache must absorb
        # the benchmark's shape traffic without a single eviction
        if not ci.get("parity_with_phantom", False):
            problems.append(
                "contention_interval/parity_with_phantom: False "
                "(interval background diverged from the frozen-phantom "
                "construction without beating it fleet-true)")
        evs = (ci.get("compiled_shapes") or {}).get("evictions", 0)
        if evs:
            problems.append(
                f"contention_interval/compiled_shapes.evictions: {evs} "
                f"!= 0 (§12 bucketing no longer bounds shape churn)")
    metro = fresh.get("metro") or {}
    if metro:
        # hard invariant (DESIGN.md §10): committed tabu replanning must
        # STRICTLY beat greedy commit-and-hold on SLA deadline miss-rate
        # on the benchmark traffic — improvement <= 1 means the metro
        # subsystem's reason to exist has regressed, whatever the floors.
        # A None improvement means greedy itself missed nothing (the
        # traffic no longer stresses anyone), which is vacuous, not a
        # regression.
        imp = metro.get("miss_rate_improvement", 0.0)
        if imp is not None and not imp > 1.0:
            problems.append(
                f"metro/miss_rate_improvement: {imp} <= 1 (tabu replan "
                f"no longer beats greedy on deadline miss-rate)")
    # per-scenario ranking invariants (DESIGN.md §11): a policy the
    # committed baseline shows WINNING a chaos pack (ratio > 1) must not
    # show up losing it (<= 1) in the fresh run — tolerance never
    # excuses a rank flip. Fresh None stays vacuous (greedy perfect).
    com_sc = committed.get("metro_scenarios") or {}
    fre_sc = fresh.get("metro_scenarios") or {}
    for pack in sorted(set(com_sc) & set(fre_sc)):
        for field, label in (
                ("miss_rate_improvement", "tabu replan"),
                ("critical_improvement_shed",
                 "shedding's life-critical protection")):
            floor = com_sc[pack].get(field)
            got = fre_sc[pack].get(field)
            if floor is not None and floor > 1.0 \
                    and got is not None and not got > 1.0:
                problems.append(
                    f"metro_scenarios/{pack}/{field}: {got:.3g} <= 1 "
                    f"(committed {floor:.3g}; {label} no longer wins "
                    f"this pack)")
    # hedging ranking invariants (DESIGN.md §13): whenever a fresh
    # metro_hedging section exists, the hedged tabu run must STRICTLY
    # beat the unhedged run under fail_slow_tail on BOTH the
    # life-critical miss rate and p99 response — tolerance never excuses
    # either loss. A None critical improvement is vacuous (the unhedged
    # run missed no life-critical deadline: nothing to rescue).
    mh = fresh.get("metro_hedging") or {}
    if mh:
        for field, label in (
                ("critical_improvement_hedge", "life-critical miss rate"),
                ("p99_improvement_hedge", "p99 response")):
            got = mh.get(field)
            if got is not None and not got > 1.0:
                problems.append(
                    f"metro_hedging/{field}: {got:.3g} <= 1 (hedged tabu "
                    f"no longer beats unhedged on {label} under "
                    f"fail_slow_tail)")
    # observability invariants (DESIGN.md §15): the flight recorder is a
    # read-only observer — a traced run's event log must hash
    # bit-identically to the untraced run's on every pack — and the
    # armed recorder may cost at most 15% throughput (retention >
    # 1/1.15). Parity is never a flake; the retention bound IS
    # wall-clock, so it honors --runs best-of re-measurement.
    mo = fresh.get("metro_observability") or {}
    if mo:
        for pack in sorted(mo.get("packs") or {}):
            if not mo["packs"][pack].get("crc_parity", False):
                problems.append(
                    f"metro_observability/{pack}/crc_parity: False "
                    f"(traced event log diverged from the untraced run "
                    f"- the tracer mutated engine state)")
        key = "metro_observability/events_per_s_retention"
        ret = mo.get("events_per_s_retention", 0.0)
        if best and best.get(key, ret) > ret:
            ret = best[key]
        if not ret > 1.0 / 1.15:
            problems.append(
                f"{key}: {ret:.3g} <= {1.0 / 1.15:.3g} (armed flight "
                f"recorder costs more than 1.15x throughput)")
    return problems


def _remeasure(failed_keys) -> dict:
    """Re-run ONLY the benchmark sections behind the failed wall-clock
    floors; -> a partial report holding just those sections."""
    import scheduler_scale as ss

    sections, packs = set(), set()
    for key in failed_keys:
        head = key.split("/", 1)[0]
        if head == "metro_scenarios":
            packs.add(key.split("/")[1])
        else:
            sections.add("head_to_head" if head.startswith("n") else head)
    partial: dict = {}
    if "head_to_head" in sections:
        partial["head_to_head"] = ss.bench_head_to_head()
    if "batched" in sections:
        partial["batched"] = ss.bench_batched()
    if "contention" in sections:
        partial["contention"] = ss.bench_contention()
    if "contention_interval" in sections:
        partial["contention_interval"] = ss.bench_contention_interval()
    if "metro" in sections:
        partial["metro"] = ss.bench_metro()
    if "metro_hedging" in sections:
        partial["metro_hedging"] = ss.bench_metro_hedging()
    if "metro_observability" in sections:
        partial["metro_observability"] = ss.bench_metro_observability()
    if packs:
        partial["metro_scenarios"] = ss.bench_metro_scenarios(
            packs=sorted(packs))
    return partial


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_scheduler.json",
                    help="committed report with the floors to hold")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional regression (default 0.30)")
    ap.add_argument("--fresh", default=None,
                    help="compare an existing report instead of running "
                         "the benchmark (mainly for tests)")
    ap.add_argument("--runs", type=int, default=1,
                    help="measure failed WALL-CLOCK throughput floors up "
                         "to this many times total and gate on the best "
                         "observation (host-throttling flake armor); "
                         "invariant/quality floors stay single-shot")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        committed = json.load(f)
    if args.fresh:
        with open(args.fresh) as f:
            fresh = json.load(f)
    else:
        from scheduler_scale import bench_scheduler_scale
        out = os.path.join(tempfile.mkdtemp(prefix="bench_fresh_"),
                           "BENCH_scheduler.json")
        bench_scheduler_scale(out_path=out)
        with open(out) as f:
            fresh = json.load(f)
        print(f"fresh report: {out}")

    problems = compare(committed, fresh, tolerance=args.tolerance)
    best: dict = {}
    for attempt in range(2, max(1, args.runs) + 1):
        failed_wall = sorted({p.split(":", 1)[0] for p in problems
                              if _is_wall_clock(p.split(":", 1)[0])})
        if not failed_wall or args.fresh:
            break            # nothing rerunnable (or no benchmark to run)
        print(f"re-measuring {len(failed_wall)} wall-clock floor(s), "
              f"run {attempt}/{args.runs}: {', '.join(failed_wall)}")
        partial = _remeasure(failed_wall)
        for fn in _METRIC_FNS:
            for key, val in fn(partial).items():
                if key in failed_wall and val > best.get(key, 0.0):
                    best[key] = val
        problems = compare(committed, fresh, tolerance=args.tolerance,
                           best=best)

    if problems:
        print("PERF REGRESSION vs committed baseline:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"perf floors held (tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    sys.exit(main())
