"""Paper-table reproductions (Tables V & VII, Figures 5 & 6).

Each function returns (rows, csv_lines) where csv_lines follow the
harness convention ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.icu_lstm import DATA_SIZES, ICU_WORKLOADS
from repro.core import scheduler
from repro.core.allocator import allocate_single
from repro.core.cost_model import CalibratedCostModel, Job, Workload
from repro.core.lower_bound import paper_lower_bound
from repro.core.problems import table6_jobs
from repro.core.tiers import CC, ED, ES, paper_tiers

# Paper Table V: estimated response time (cloud, edge, device) at size 64;
# the table is exactly linear in size (WL*-k = 2^k * WL*-1), so these base
# rows ARE the paper's own calibration measurements.
TABLE5_BASE = {
    "short-of-breath-alerts": (2091.0, 1279.0, 1394.0),
    "life-death-prediction": (212.0, 109.0, 79.0),
    "patient-phenotype-classification": (3115.0, 2931.0, 3618.0),
}
TABLE5_CHOSEN = {
    "short-of-breath-alerts": ES,
    "life-death-prediction": ED,
    "patient-phenotype-classification": ES,
}
# Paper Table VII (our verified reading: the cloud/edge rows are swapped
# vs Table VI's transmission columns — DESIGN.md §1)
TABLE7_PAPER = {
    "ours (algorithm 2)": (150, 43),
    "all device": (366, 94),
    # paper "cloud"=291 == all-edge; paper "edge"=416 == all-cloud
    "all edge": (291, 74),
    "all cloud": (416, 100),
}


def _paper_calibrated_model():
    """CalibratedCostModel from the paper's own size-64 estimates.

    The paper does not publish its D/I split, so transmission at the device
    tier anchors the split: device has I only, and I scales with the
    published FLOPS ratios (Table III). D is the remainder."""
    tiers = paper_tiers()
    unit_proc, unit_trans = {}, {}
    for wl, (t_cc, t_es, t_ed) in TABLE5_BASE.items():
        i_ed = t_ed / 64.0
        i_cc = i_ed * tiers[ED].flops / tiers[CC].flops
        i_es = i_ed * tiers[ED].flops / tiers[ES].flops
        unit_proc[(wl, CC)], unit_proc[(wl, ES)] = i_cc, i_es
        unit_proc[(wl, ED)] = i_ed
        unit_trans[(wl, CC)] = t_cc / 64.0 - i_cc
        unit_trans[(wl, ES)] = t_es / 64.0 - i_es
        unit_trans[(wl, ED)] = 0.0
    return CalibratedCostModel(tiers, unit_proc, unit_trans)


def bench_table5():
    """Table V: Algorithm 1 estimates for all 18 workloads.

    derived = '<decisions-matching-paper>/18;max_rel_err=<v>'."""
    cm = _paper_calibrated_model()
    t0 = time.perf_counter()
    rows, match, max_err = [], 0, 0.0
    for wl_cfg in ICU_WORKLOADS:
        wl = Workload(wl_cfg.name, comp=wl_cfg.paper_flops, unit_bytes=1.0,
                      priority=wl_cfg.priority)
        for k, size in enumerate(DATA_SIZES):
            alloc = allocate_single(cm, Job(wl, size=size))
            est = alloc.per_tier_response
            paper = tuple(v * size / 64.0
                          for v in TABLE5_BASE[wl_cfg.name])
            err = max(abs(est[t] - p) / p for t, p in
                      zip((CC, ES, ED), paper))
            max_err = max(max_err, err)
            match += alloc.tier == TABLE5_CHOSEN[wl_cfg.name]
            rows.append((f"WL{ICU_WORKLOADS.index(wl_cfg)+1}-{k+1}",
                         alloc.tier, est[CC], est[ES], est[ED]))
    us = (time.perf_counter() - t0) / 18 * 1e6
    csv = [f"table5_alg1,{us:.1f},decisions={match}/18;"
           f"max_rel_err={max_err:.2e}"]
    return rows, csv


def bench_table7():
    """Table VII: multi-job strategy comparison on the Table VI job set."""
    jobs = table6_jobs()
    t0 = time.perf_counter()
    table = scheduler.strategy_table(jobs)
    us = (time.perf_counter() - t0) * 1e6
    opt = scheduler.exact_optimum(jobs, objective="unweighted")
    lb = paper_lower_bound(jobs, weighted=False)
    rows, csv = [], []
    for name, sched in table.items():
        paper = TABLE7_PAPER.get(name)
        rows.append((name, sched.unweighted_sum, sched.last_end, paper))
        tag = name.replace(" ", "_").replace("(", "").replace(")", "")
        d = f"whole={sched.unweighted_sum:.0f};last={sched.last_end:.0f}"
        if paper:
            d += f";paper={paper[0]}/{paper[1]}"
        csv.append(f"table7_{tag},{us:.1f},{d}")
    csv.append(f"table7_exact_optimum,{us:.1f},whole={opt.unweighted_sum:.0f}"
               f";lower_bound={lb:.0f}")
    return rows, csv


def bench_fig5_fig6():
    """Figures 5-6: per-layer response + processing/transmission breakdown
    for the largest size (WL*-6), from the paper-calibrated model."""
    cm = _paper_calibrated_model()
    rows, csv = [], []
    t0 = time.perf_counter()
    for wl_cfg in ICU_WORKLOADS:
        wl = Workload(wl_cfg.name, comp=wl_cfg.paper_flops, unit_bytes=1.0)
        job = Job(wl, size=DATA_SIZES[-1])
        per = cm.times(job)
        for tier in (CC, ES, ED):
            d, i = per[tier]
            rows.append((wl_cfg.name, tier, d, i))
        best = min(per, key=lambda t: sum(per[t]))
        short = wl_cfg.name.split("-")[0]
        csv.append(
            f"fig6_breakdown_{short},"
            f"{(time.perf_counter()-t0)*1e6:.1f},"
            f"best={best};trans_frac_edge="
            f"{per[ES][0]/(per[ES][0]+per[ES][1]):.2f}")
    return rows, csv
